"""mpwlint: repo-specific static analysis for the MPWide reproduction.

Two layers (see docs/lint.md):

  * Layer 1 — AST lint rules R1..R5 over ``src/`` (traced-purity,
    lock-discipline, typed errors, telemetry-key grammar, core determinism).
  * Layer 2 — semantic plan verifier S1..S4: imports the real planners and
    checks their contracts over adversarial config sweeps.

Run as ``python -m tools.mpwlint src/``.
"""
from tools.mpwlint.findings import Finding, load_baseline
from tools.mpwlint.engine import lint_paths
from tools.mpwlint.semantic import run_semantic

__all__ = ["Finding", "load_baseline", "lint_paths", "run_semantic"]
