"""Layer-1 AST rules.

Each rule is ``rule(ctx) -> list[Finding]`` over one parsed module.  The
rule ids and what they guard:

  R1  traced-purity      no host side effects (time/random/file IO/self
                         mutation) inside jit/custom_vjp/shard_map/scanned
                         functions — they run at trace time, not per step.
  R2  lock-discipline    in thread-spawning modules, attributes written
                         from more than one method must be written under a
                         declared Lock/RLock `with` block.
  R3  typed-errors       no bare `assert` in library code; ValueErrors in
                         core/ must name the offending value (no constant
                         message strings).
  R4  telemetry-keys     telemetry key literals follow the documented
                         grammar; every public MPW verb has a docs/api.md
                         row (checked in engine.py, reported under R4).
  R5  core-determinism   no wall-clock reads or unseeded RNG in core/
                         (run-twice determinism is what the chaos and
                         property suites replay against).
  R6  retry-policy       retry loops in src/ must route through
                         RetryPolicy: no literal while-retry that swallows
                         exceptions with a bare `continue` or open-codes
                         backoff with `time.sleep` — hand-rolled loops skip
                         the seeded jitter/deadline budget and break the
                         replayable incident timelines.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

from tools.mpwlint.findings import Finding


@dataclass
class ModuleContext:
    relpath: str                   # repo-relative posix path
    tree: ast.Module
    lines: list[str]
    parents: dict = field(default_factory=dict)

    @property
    def in_core(self) -> bool:
        return "/core/" in f"/{self.relpath}"

    def parent_chain(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)


def build_context(relpath: str, source: str) -> ModuleContext:
    tree = ast.parse(source)
    ctx = ModuleContext(relpath, tree, source.splitlines())
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            ctx.parents[child] = parent
    return ctx


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# R1: traced purity
# ---------------------------------------------------------------------------

_TRACE_WRAPPERS = {
    "jit", "jax.jit", "custom_vjp", "jax.custom_vjp", "custom_jvp",
    "jax.custom_jvp", "shard_map", "jax.experimental.shard_map.shard_map",
    "checkpoint", "jax.checkpoint", "remat", "jax.remat",
}

_WALL_CLOCK_ATTRS = {
    "time", "monotonic", "perf_counter", "process_time",
    "time_ns", "monotonic_ns", "perf_counter_ns",
}


def _is_trace_wrapper(expr: ast.AST) -> bool:
    name = dotted(expr)
    if name in _TRACE_WRAPPERS:
        return True
    if isinstance(expr, ast.Call):
        fn = dotted(expr.func)
        if fn in _TRACE_WRAPPERS:
            return True                      # e.g. @jax.custom_vjp(...) form
        if fn in ("partial", "functools.partial") and expr.args:
            return _is_trace_wrapper(expr.args[0])
    return False


def _traced_function_names(tree: ast.Module) -> set[str]:
    """Names of functions handed to tracers by *call*: lax.scan(f, ...),
    g = jax.jit(f), f.defvjp(fwd, bwd)."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted(node.func)
        if fn and (fn.endswith("lax.scan") or fn == "scan"):
            if node.args and isinstance(node.args[0], ast.Name):
                names.add(node.args[0].id)
        elif fn in _TRACE_WRAPPERS:
            for a in node.args:
                if isinstance(a, ast.Name):
                    names.add(a.id)
        elif isinstance(node.func, ast.Attribute) and node.func.attr == "defvjp":
            for a in node.args:
                if isinstance(a, ast.Name):
                    names.add(a.id)
    return names


def rule_r1(ctx: ModuleContext) -> list[Finding]:
    out: list[Finding] = []
    by_call = _traced_function_names(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        traced = node.name in by_call or any(
            _is_trace_wrapper(d) for d in node.decorator_list)
        if not traced:
            continue
        out.extend(_scan_traced_body(ctx, node))
    return out


def _scan_traced_body(ctx: ModuleContext, fn: ast.AST) -> list[Finding]:
    out: list[Finding] = []
    where = f"traced function `{fn.name}`"
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            root = (name or "").split(".")[0]
            if root in ("time", "random"):
                out.append(Finding(
                    "R1", ctx.relpath, node.lineno,
                    f"host call `{name}(...)` inside {where}",
                    "traced code runs once at trace time; hoist the host "
                    "side effect out of the traced function"))
            elif name == "open":
                out.append(Finding(
                    "R1", ctx.relpath, node.lineno,
                    f"file IO `open(...)` inside {where}",
                    "do file IO outside the traced function and pass "
                    "arrays in"))
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    out.append(Finding(
                        "R1", ctx.relpath, node.lineno,
                        f"mutation of `self.{t.attr}` inside {where}",
                        "traced functions must be pure; return the value "
                        "and assign it outside the trace"))
    return out


# ---------------------------------------------------------------------------
# R2: lock discipline in thread-spawning modules
# ---------------------------------------------------------------------------

_INIT_METHODS = {"__init__", "__post_init__"}


_THREADING_CTORS = {
    "threading.Thread", "Thread", "threading.Lock", "threading.RLock",
    "Lock", "RLock", "threading.Condition", "ThreadPoolExecutor",
    "concurrent.futures.ThreadPoolExecutor",
}


def _spawns_threads(tree: ast.Module) -> bool:
    """Modules that spawn threads OR declare locks: either way their class
    state is shared across threads (chaos.py owns no Thread — the mirror
    thread in replicate.py calls into it — but its IncidentLog lock marks
    the sharing)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = dotted(node.func)
            if fn in _THREADING_CTORS:
                return True
    return False


def _is_lock_expr(expr: ast.AST) -> bool:
    name = dotted(expr)
    if not name:
        return False
    last = name.split(".")[-1].lower()
    return "lock" in last or "mutex" in last


def _under_lock(ctx: ModuleContext, node: ast.AST) -> bool:
    for parent in ctx.parent_chain(node):
        if isinstance(parent, ast.With):
            if any(_is_lock_expr(item.context_expr) for item in parent.items):
                return True
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break                            # don't escape the method
    return False


def rule_r2(ctx: ModuleContext) -> list[Finding]:
    if not _spawns_threads(ctx.tree):
        return []
    out: list[Finding] = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        # attr -> {method name -> [write nodes]}
        writes: dict[str, dict[str, list]] = {}
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(meth):
                if not isinstance(node, (ast.Assign, ast.AugAssign,
                                         ast.AnnAssign)):
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        writes.setdefault(t.attr, {}).setdefault(
                            meth.name, []).append(node)
        for attr, by_meth in writes.items():
            if len(by_meth) < 2:
                continue                     # single-writer attrs are fine
            shared_with = sorted(by_meth)
            for meth_name, nodes in by_meth.items():
                if meth_name in _INIT_METHODS:
                    continue                 # construction precedes sharing
                for node in nodes:
                    if _under_lock(ctx, node):
                        continue
                    out.append(Finding(
                        "R2", ctx.relpath, node.lineno,
                        f"unguarded write to shared `{cls.name}.{attr}` in "
                        f"`{meth_name}` (also written in "
                        f"{', '.join(m for m in shared_with if m != meth_name)})",
                        "this module spawns threads; guard the write with "
                        "the instance's Lock/RLock (`with self._lock:`)"))
    return out


# ---------------------------------------------------------------------------
# R3: typed errors, no bare asserts
# ---------------------------------------------------------------------------

def rule_r3(ctx: ModuleContext) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert):
            out.append(Finding(
                "R3", ctx.relpath, node.lineno,
                "bare `assert` in library code",
                "asserts vanish under `python -O`; raise a typed exception "
                "(ValueError/RuntimeError) naming the offending values"))
        elif isinstance(node, ast.Raise) and ctx.in_core:
            exc = node.exc
            if (isinstance(exc, ast.Call) and dotted(exc.func) == "ValueError"
                    and exc.args and isinstance(exc.args[0], ast.Constant)
                    and isinstance(exc.args[0].value, str)
                    and not exc.keywords and len(exc.args) == 1):
                out.append(Finding(
                    "R3", ctx.relpath, node.lineno,
                    f"ValueError with a constant message "
                    f"{exc.args[0].value!r} in core/",
                    "name the offending shape/knob/key in the message "
                    "(use an f-string) so operators can act on it"))
    return out


# ---------------------------------------------------------------------------
# R4: telemetry-key grammar
# ---------------------------------------------------------------------------

# Templates: each f-string interpolation collapses to `{}`.  The grammar is
# the one docs/telemetry.md and PathTelemetry document:
#   {key}                dynamic key, opaque here
#   {key}/hop{i}:{leg}   per-hop legs
#   {key}/bkt{i}         per-bucket plans
#   {key}/intra {key}/wan  hierarchical split
#   {key}/delta          local-SGD cross-site delta syncs
#   ckpt...              checkpoint paths (constant prefix)
_KEY_TEMPLATES = {"{}", "{}/hop{}:{}", "{}/bkt{}", "{}/intra", "{}/wan",
                  "{}/delta", "serve/req{}/kv"}
_TEL_CALLS = {"note_plan", "record", "timed", "note_checksum_error", "path",
              "note_ship_retry"}
_TEL_KWARGS = {"tel_key", "tel_prefix"}

# Incident-kind vocabulary for `IncidentLog.add(step, kind, ...)` call sites.
# Sourced live from the library so the lint never drifts from the runtime
# check; the literal fallback keeps the rule alive if the import breaks.
_INCIDENT_KINDS_FALLBACK = (
    "inject", "detect", "replan", "retune", "requeue", "failover", "recover",
    "evict", "join", "leave", "resize", "catchup", "timeout", "shed",
    "reship", "reroute", "serve_failover", "degrade")


def _incident_kinds() -> frozenset:
    try:
        from repro.core.chaos import IncidentLog
        return frozenset(IncidentLog.KINDS)
    except Exception:
        return frozenset(_INCIDENT_KINDS_FALLBACK)


def _template(expr: ast.AST) -> Optional[str]:
    """Literal shape of a key expression; None when fully dynamic."""
    if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
        return expr.value
    if isinstance(expr, ast.JoinedStr):
        parts = []
        for v in expr.values:
            if isinstance(v, ast.FormattedValue):
                parts.append("{}")
            elif isinstance(v, ast.Constant):
                parts.append(str(v.value))
        return "".join(parts)
    return None


def _template_ok(tpl: str) -> bool:
    if tpl in _KEY_TEMPLATES:
        return True
    return tpl.startswith("ckpt")            # ckpt:* constant family


def rule_r4(ctx: ModuleContext) -> list[Finding]:
    out: list[Finding] = []
    kinds = _incident_kinds()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        key_exprs: list[ast.AST] = []
        fn = node.func
        callee = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if (isinstance(fn, ast.Attribute) and fn.attr == "add"
                and len(node.args) >= 3
                and isinstance(node.args[1], ast.Constant)
                and isinstance(node.args[1].value, str)
                and node.args[1].value not in kinds):
            out.append(Finding(
                "R4", ctx.relpath, node.args[1].lineno,
                f"incident kind literal {node.args[1].value!r} is not in the "
                f"IncidentLog vocabulary",
                "IncidentLog.add kinds must come from IncidentLog.KINDS "
                "(misspelled kinds raise at runtime only when the code path "
                "fires) — see docs/lint.md#r4"))
        if callee in _TEL_CALLS and node.args:
            key_exprs.append(node.args[0])
        for kw in node.keywords:
            if kw.arg in _TEL_KWARGS:
                key_exprs.append(kw.value)
        for expr in key_exprs:
            tpl = _template(expr)
            if tpl is None or _template_ok(tpl):
                continue
            out.append(Finding(
                "R4", ctx.relpath, expr.lineno,
                f"telemetry key literal {tpl!r} does not match the key "
                f"grammar",
                "keys must be `{key}`, `{key}/hop{i}:{leg}`, `{key}/bkt{i}`, "
                "`{key}/intra`, `{key}/wan`, `{key}/delta`, "
                "`serve/req{rid}/kv`, or a `ckpt*` constant — see "
                "docs/lint.md#r4"))
    return out


# ---------------------------------------------------------------------------
# R5: determinism in core/
# ---------------------------------------------------------------------------

def rule_r5(ctx: ModuleContext) -> list[Finding]:
    if not ctx.in_core:
        return []
    out: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func) or ""
        parts = name.split(".")
        if parts[0] == "time" and len(parts) == 2 \
                and parts[1] in _WALL_CLOCK_ATTRS:
            out.append(Finding(
                "R5", ctx.relpath, node.lineno,
                f"wall-clock read `{name}()` in core/",
                "core/ must be run-twice deterministic (the chaos and "
                "property suites replay it); model time or take it as an "
                "argument"))
        elif parts[0] == "datetime" and parts[-1] in ("now", "utcnow",
                                                      "today"):
            out.append(Finding(
                "R5", ctx.relpath, node.lineno,
                f"wall-clock read `{name}()` in core/",
                "pass timestamps in from the caller"))
        elif parts[0] == "random" and len(parts) == 2:
            out.append(Finding(
                "R5", ctx.relpath, node.lineno,
                f"unseeded stdlib RNG `{name}()` in core/",
                "use a seeded np.random.default_rng(seed) or jax PRNG key"))
        elif parts[:2] in (["np", "random"], ["numpy", "random"]):
            if parts[-1] == "default_rng" and (node.args or node.keywords):
                continue                     # seeded generator: fine
            out.append(Finding(
                "R5", ctx.relpath, node.lineno,
                f"unseeded numpy RNG `{name}()` in core/",
                "seed it: np.random.default_rng(seed)"))
    return out


# ---------------------------------------------------------------------------
# R6: retry loops must route through RetryPolicy
# ---------------------------------------------------------------------------

_RETRY_NAMES = {"RetryPolicy", "RetryState", "retry", "retry_policy", "policy"}
_RETRY_ATTRS = {"RetryPolicy", "RetryState", "retry", "retry_policy",
                "_retry", "retry_state"}


def _references_retry(fn: ast.AST) -> bool:
    """The function consults RetryPolicy/RetryState (or a retry-named
    binding of one) somewhere — its loop delegates attempt budgeting."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in _RETRY_NAMES:
            return True
        if isinstance(node, ast.Attribute) and node.attr in _RETRY_ATTRS:
            return True
    return False


def _owner_loop(ctx: ModuleContext, node: ast.AST) -> Optional[ast.AST]:
    """Nearest enclosing loop — the one a `continue` would re-enter."""
    for parent in ctx.parent_chain(node):
        if isinstance(parent, (ast.While, ast.For, ast.AsyncFor)):
            return parent
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return None
    return None


def rule_r6(ctx: ModuleContext) -> list[Finding]:
    if not ctx.relpath.startswith("src/"):
        return []
    out: list[Finding] = []
    for loop in ast.walk(ctx.tree):
        if not isinstance(loop, ast.While):
            continue
        fn = next((p for p in ctx.parent_chain(loop)
                   if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))),
                  None)
        if fn is not None and _references_retry(fn):
            continue                         # budgeted by RetryPolicy: fine
        has_try = any(isinstance(n, ast.Try) for n in ast.walk(loop))
        for node in ast.walk(loop):
            if isinstance(node, ast.Continue) and _owner_loop(ctx, node) is loop:
                in_handler = False
                for p in ctx.parent_chain(node):
                    if p is loop:
                        break
                    if isinstance(p, ast.ExceptHandler):
                        in_handler = True
                        break
                if in_handler:
                    out.append(Finding(
                        "R6", ctx.relpath, node.lineno,
                        "hand-rolled retry: `continue` from an `except` "
                        "handler inside a `while` loop",
                        "route the attempt budget through "
                        "core.retry.RetryPolicy (seeded backoff + jitter + "
                        "deadline) instead of looping until it works"))
            elif (isinstance(node, ast.Call) and has_try
                    and dotted(node.func) == "time.sleep"
                    and _owner_loop(ctx, node) is loop):
                out.append(Finding(
                    "R6", ctx.relpath, node.lineno,
                    "hand-rolled backoff: `time.sleep(...)` in a retrying "
                    "`while` loop",
                    "take delays from RetryPolicy.schedule() so backoff is "
                    "seeded, jittered, and deadline-bounded"))
    return out


RULES: dict[str, Callable[[ModuleContext], list[Finding]]] = {
    "R1": rule_r1,
    "R2": rule_r2,
    "R3": rule_r3,
    "R4": rule_r4,
    "R5": rule_r5,
    "R6": rule_r6,
}


# ---------------------------------------------------------------------------
# R4b: MPW facade verb audit (whole-repo, not per-module — engine calls it)
# ---------------------------------------------------------------------------

def audit_mpw_verbs(repo_root: Path) -> list[Finding]:
    """Every public MPW verb must have a `{verb}(` row in docs/api.md."""
    api_py = repo_root / "src" / "repro" / "core" / "api.py"
    api_md = repo_root / "docs" / "api.md"
    if not api_py.exists():
        return []
    if not api_md.exists():
        return [Finding("R4", "docs/api.md", 0,
                        "docs/api.md is missing but src/repro/core/api.py "
                        "defines the MPW facade",
                        "restore the API reference")]
    doc = api_md.read_text()
    tree = ast.parse(api_py.read_text())
    out: list[Finding] = []
    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and cls.name == "MPW"):
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if meth.name.startswith("_") or meth.name == "path":
                continue
            if f"{meth.name}(" not in doc:
                out.append(Finding(
                    "R4", "src/repro/core/api.py", meth.lineno,
                    f"MPW verb `{meth.name}` has no docs/api.md row",
                    "add a row to the facade table in docs/api.md"))
    return out
