import sys

from tools.mpwlint.cli import main

sys.exit(main())
