"""Layer 2: semantic plan verifier.

Imports the *real* planners from ``src/repro`` and checks their contracts
over adversarial config sweeps — the invariants every transfer correctness
argument in docs/architecture.md rests on:

  S1  chunk coverage       plan_chunks / plan_file_chunks cover exactly the
                           leaf/file bytes: contiguous, non-overlapping,
                           byte sums exact (incl. remainder absorption,
                           0-d leaves, empty files, pinned row geometry).
  S2  ring wire bound      wire_bytes_per_pod conforms to the 2(P-1)/P
                           bandwidth-optimal ring bound per algo x
                           compression x world size.
  S3  route soundness      route planning over fault schedules never yields
                           a cycle or a dead hop; unreachable pairs raise
                           instead of silently mis-routing.
  S4  bucket bit-identity  plan_buckets tiles the layers dim exactly;
                           aligned_chunks pins the full leaf's row geometry;
                           the int8 wire block never exceeds the segment
                           extent.

Every violation is reported as a Finding (rule S1..S4) against the planner
module, so the CLI and CI treat both layers uniformly.
"""
from __future__ import annotations

import itertools
import sys
from pathlib import Path

from tools.mpwlint.findings import Finding


def _ensure_src(repo_root: Path) -> None:
    src = str(repo_root / "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def _f(rule: str, path: str, msg: str, hint: str = "") -> Finding:
    return Finding(rule, path, 0, msg, hint)


# ---------------------------------------------------------------------------
# S1: chunk plans cover exactly the payload bytes
# ---------------------------------------------------------------------------

def check_chunk_coverage() -> list[Finding]:
    import numpy as np
    from repro.core.streams import (chunk_rows, leaf_bytes, normalize_dims,
                                    plan_chunks)

    out: list[Finding] = []
    path = "src/repro/core/streams.py"
    shapes = [(), (1,), (7,), (13,), (64, 48), (3, 5, 7), (1, 1), (2, 1023),
              (1024,), (5, 3, 2, 7)]
    leaves = [np.zeros(s, np.float32) for s in shapes]
    # adversarial dim choices: defaults, last dim, mixed None
    dim_choices = [None,
                   [(-1 if l.ndim else None) for l in leaves],
                   [(0 if i % 2 else None) if l.ndim else None
                    for i, l in enumerate(leaves)]]
    for dims_in, chunk_bytes in itertools.product(dim_choices,
                                                  [1, 64, 1000, 1 << 20]):
        dims = normalize_dims(leaves, dims_in)
        for pinned in (False, True):
            rows = ([chunk_rows(l, d, chunk_bytes)
                     for l, d in zip(leaves, dims)] if pinned else None)
            try:
                chunks = plan_chunks(leaves, dims, chunk_bytes, rows=rows)
            except Exception as e:      # noqa: BLE001 - report, don't crash
                out.append(_f("S1", path,
                              f"plan_chunks raised {type(e).__name__}: {e} "
                              f"(chunk_bytes={chunk_bytes}, pinned={pinned})"))
                continue
            for i, leaf in enumerate(leaves):
                mine = [c for c in chunks if c.leaf == i]
                nb = leaf_bytes(leaf)
                got = sum(c.nbytes for c in mine)
                if got != nb:
                    out.append(_f(
                        "S1", path,
                        f"chunk bytes {got} != leaf bytes {nb} for shape "
                        f"{leaf.shape} dim={dims[i]} "
                        f"chunk_bytes={chunk_bytes} pinned={pinned}",
                        "the last chunk must absorb the nb//n remainder"))
                if len(mine) > 1:
                    spans = sorted((c.start, c.start + c.size) for c in mine)
                    n = leaf.shape[mine[0].dim]
                    tiles = (spans[0][0] == 0 and spans[-1][1] == n and all(
                        a[1] == b[0] for a, b in zip(spans, spans[1:])))
                    if not tiles:
                        out.append(_f(
                            "S1", path,
                            f"chunk spans {spans} do not tile [0, {n}) for "
                            f"shape {leaf.shape} chunk_bytes={chunk_bytes}",
                            "chunks must be contiguous and non-overlapping"))
    return out


def check_file_chunk_coverage() -> list[Finding]:
    from repro.core.filetransfer import plan_file_chunks

    out: list[Finding] = []
    path = "src/repro/core/filetransfer.py"
    sizes = [0, 1, 7, 1 << 16, (1 << 16) + 1, 1023, 10 * (1 << 16) + 3,
             (1 << 20) - 1]
    for nbytes, chunk_bytes in itertools.product(sizes,
                                                 [1, 1 << 16, 1 << 20]):
        chunks = plan_file_chunks(nbytes, chunk_bytes)
        eff = max(1 << 16, chunk_bytes)
        total = sum(c.size for c in chunks)
        if total != max(0, nbytes):
            out.append(_f(
                "S1", path,
                f"file chunks cover {total} bytes, file has {nbytes} "
                f"(chunk_bytes={chunk_bytes})"))
        off = 0
        for c in chunks:
            if c.start != off or c.size > eff or c.size != c.nbytes:
                out.append(_f(
                    "S1", path,
                    f"file chunk {c} breaks the contiguous byte-range "
                    f"contract at offset {off} (nbytes={nbytes}, "
                    f"chunk_bytes={chunk_bytes})"))
                break
            off += c.size
    return out


# ---------------------------------------------------------------------------
# S2: ring wire-byte bound
# ---------------------------------------------------------------------------

def check_wire_bound() -> list[Finding]:
    from repro.core.ring import ALGOS, WIRE_FACTOR, wire_bytes_per_pod

    out: list[Finding] = []
    path = "src/repro/core/ring.py"
    tol = 1e-9
    for payload, world, algo, compress in itertools.product(
            [0.0, 1.0, 1000.0, float(1 << 20)], [1, 2, 3, 4, 8, 16],
            (*ALGOS, "shift"), WIRE_FACTOR):
        w = wire_bytes_per_pod(payload, world, algo=algo, compress=compress)
        wire = payload * WIRE_FACTOR[compress]
        if algo == "shift":
            expect = wire
        elif world <= 1:
            expect = 0.0
        elif algo in ("ring", "ring2") or compress == "none":
            expect = 2.0 * (world - 1) / world * wire
        else:
            expect = (world - 1.0) * wire
        ctx = (f"payload={payload} world={world} algo={algo} "
               f"compress={compress}")
        if abs(w - expect) > tol * max(1.0, expect):
            out.append(_f(
                "S2", path,
                f"wire_bytes_per_pod={w} != {expect} for {ctx}",
                "ring/psum+none must hit the 2(P-1)/P bound; gather-based "
                "compressed psum is (P-1); shift ships once"))
        # the ring algorithms must never exceed the bandwidth-optimal bound
        if algo in ("ring", "ring2") and \
                w > 2.0 * (max(world, 1) - 1) / max(world, 1) * wire + tol:
            out.append(_f(
                "S2", path,
                f"ring wire bytes {w} exceed the 2(P-1)/P bound for {ctx}"))
    return out


# ---------------------------------------------------------------------------
# S3: routes over fault schedules
# ---------------------------------------------------------------------------

def _alive_links(topo, step: int):
    return {(a, b) for (a, b), prof in topo._links.items()
            if prof.health(step).alive}


def _reachable(links: set, src: str, dst: str) -> bool:
    seen, frontier = {src}, [src]
    while frontier:
        u = frontier.pop()
        for (a, b) in links:
            if a == u and b not in seen:
                seen.add(b)
                frontier.append(b)
    return dst in seen


def check_route_soundness() -> list[Finding]:
    from repro.core.topology import cosmogrid_topology

    out: list[Finding] = []
    path = "src/repro/core/topology.py"
    # deterministic fault schedule on the CosmoGrid star + backup detour:
    # the light path dies mid-run, the Espoo leg flaps, Edinburgh degrades.
    def build():
        t = cosmogrid_topology(pods_per_site=2, backup_links=True)
        t.connect("amsterdam", "tokyo",
                  t.link("amsterdam", "tokyo").drop(5, 15))
        t.connect("amsterdam", "espoo",
                  t.link("amsterdam", "espoo").drop(8, 10).drop(18, None))
        t.connect("amsterdam", "edinburgh",
                  t.link("amsterdam", "edinburgh").degrade(0.25, (3, 12)))
        return t

    sites = ["amsterdam", "tokyo", "espoo", "edinburgh"]
    for step in range(0, 22):
        topo = build()
        alive = _alive_links(topo, step)
        for (a, b) in set(topo._links) - alive:
            topo.fail_link(a, b, bidirectional=False)
        for src, dst in itertools.permutations(sites, 2):
            for metric in ("hops", "latency", "width"):
                ctx = f"{src}->{dst} metric={metric} step={step}"
                try:
                    route = topo.route(src, dst, metric)
                except KeyError:
                    if _reachable(alive, src, dst):
                        out.append(_f(
                            "S3", path,
                            f"route raised KeyError but {ctx} is reachable "
                            f"over alive links"))
                    continue
                if len(set(route.sites)) != len(route.sites):
                    out.append(_f(
                        "S3", path,
                        f"route {route.sites} revisits a site ({ctx})",
                        "a routing cycle means the search relaxed a node "
                        "twice"))
                if route.sites[0] != src or route.sites[-1] != dst:
                    out.append(_f(
                        "S3", path,
                        f"route {route.sites} has wrong endpoints ({ctx})"))
                for hop_a, hop_b in zip(route.sites, route.sites[1:]):
                    if (hop_a, hop_b) not in alive:
                        out.append(_f(
                            "S3", path,
                            f"route {route.sites} crosses dead hop "
                            f"{hop_a}->{hop_b} ({ctx})",
                            "the search must skip links whose health(step) "
                            "is down"))
    # whole-site loss: the backup detour must carry tokyo<->edinburgh, and
    # espoo (star leaf) must be honestly unreachable.
    topo = build()
    topo.fail_site("amsterdam")
    try:
        route = topo.route("tokyo", "edinburgh", "hops")
        if "amsterdam" in route.sites:
            out.append(_f("S3", path,
                          "route crosses the failed amsterdam site"))
    except KeyError:
        out.append(_f("S3", path,
                      "tokyo->edinburgh must heal over the backup link "
                      "when amsterdam dies"))
    try:
        topo.route("tokyo", "espoo", "hops")
        out.append(_f("S3", path,
                      "tokyo->espoo routed despite espoo being cut off"))
    except KeyError:
        pass
    return out


# ---------------------------------------------------------------------------
# S4: bucket plans and bit-identity preconditions
# ---------------------------------------------------------------------------

def check_bucket_contracts() -> list[Finding]:
    import numpy as np
    from repro.core.buckets import aligned_chunks, plan_buckets
    from repro.core.ring import QBLOCK, _wire_block
    from repro.core.streams import chunk_rows, leaf_bytes, plan_chunks

    out: list[Finding] = []
    path = "src/repro/core/buckets.py"
    for nL, bucket_bytes in itertools.product([1, 2, 5, 12, 24],
                                              [1, 100, 10_000, 1 << 22]):
        leaves = [np.zeros((nL, 7, 3), np.float32),
                  np.zeros((nL, 64), np.float32),
                  np.zeros((11,), np.float32),       # rest leaf
                  np.zeros((), np.float32)]          # rest leaf, 0-d
        flags = [True, True, False, False]
        plan = plan_buckets(leaves, flags, bucket_bytes)
        ctx = f"nL={nL} bucket_bytes={bucket_bytes}"
        layer = [b for b in plan.buckets if not b.is_rest]
        rest = [b for b in plan.buckets if b.is_rest]
        spans = sorted((b.lo, b.hi) for b in layer)
        tiles = (not layer) or (spans[0][0] == 0 and spans[-1][1] == nL
                                and all(a[1] == b[0] for a, b in
                                        zip(spans, spans[1:])))
        if not tiles:
            out.append(_f("S4", path,
                          f"bucket spans {spans} do not tile [0, {nL}) "
                          f"({ctx})",
                          "the lowest bucket must absorb the remainder"))
        stacked = sum(leaf_bytes(l) for l, f in zip(leaves, flags) if f)
        restb = sum(leaf_bytes(l) for l, f in zip(leaves, flags) if not f)
        if sum(b.nbytes for b in layer) != stacked:
            out.append(_f("S4", path,
                          f"layer-bucket bytes != stacked bytes ({ctx})"))
        if sum(b.nbytes for b in rest) != restb:
            out.append(_f("S4", path,
                          f"rest-bucket bytes != rest bytes ({ctx})"))
        # bit-identity precondition: a bucket's chunk geometry along the
        # scatter dim must equal the full leaf's.
        dims = [1, 1, None, None]
        chunk_bytes = 256
        for b in layer:
            payload = [leaves[0][b.lo:b.hi], leaves[1][b.lo:b.hi]]
            idx = [0, 1]
            sub = aligned_chunks(leaves, payload, idx, dims, chunk_bytes)
            full = plan_chunks(leaves[:2], dims[:2], chunk_bytes,
                               rows=[chunk_rows(l, d, chunk_bytes)
                                     for l, d in zip(leaves[:2], dims[:2])])
            for li in idx:
                sub_geo = [(c.start, c.size) for c in sub if c.leaf == li]
                full_geo = [(c.start, c.size) for c in full if c.leaf == li]
                if sub_geo != full_geo:
                    out.append(_f(
                        "S4", path,
                        f"bucket [{b.lo},{b.hi}) chunk geometry {sub_geo} "
                        f"!= full-leaf geometry {full_geo} for leaf {li} "
                        f"({ctx})",
                        "aligned_chunks must pin chunk_rows of the FULL "
                        "leaf"))
            for c in sub:
                extent = c.size if c.size else 1
                if not (1 <= _wire_block(extent) <= max(1, extent)):
                    out.append(_f(
                        "S4", "src/repro/core/ring.py",
                        f"wire block {_wire_block(extent)} exceeds segment "
                        f"extent {extent} ({ctx})"))
    for m in [*range(1, 40), 63, 64, 65, QBLOCK - 1, QBLOCK, QBLOCK + 1,
              10 * QBLOCK]:
        if _wire_block(m) != max(1, min(QBLOCK, m)):
            out.append(_f("S4", "src/repro/core/ring.py",
                          f"_wire_block({m}) != max(1, min(QBLOCK, {m}))",
                          "short segments must become their own block"))
    return out


CHECKS = {
    "S1": (check_chunk_coverage, check_file_chunk_coverage),
    "S2": (check_wire_bound,),
    "S3": (check_route_soundness,),
    "S4": (check_bucket_contracts,),
}


def run_semantic(repo_root: Path) -> list[Finding]:
    _ensure_src(repo_root)
    out: list[Finding] = []
    for rule_id, checks in CHECKS.items():
        for check in checks:
            try:
                out.extend(check())
            except Exception as e:      # noqa: BLE001 - a crash IS a finding
                out.append(Finding(
                    rule_id, "tools/mpwlint/semantic.py", 0,
                    f"{check.__name__} crashed: {type(e).__name__}: {e}",
                    "the planner API drifted under the verifier; update "
                    "the contract or fix the planner"))
    return out
