"""Finding model, inline suppressions, and the committed baseline."""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, asdict
from pathlib import Path

# `# mpwlint: disable=R1` or `# mpwlint: disable=R1,R5` or `disable=all`,
# on the same physical line as the finding.
_SUPPRESS_RE = re.compile(r"#\s*mpwlint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclass(frozen=True)
class Finding:
    rule: str          # "R1".."R5", "S1".."S4"
    path: str          # repo-relative posix path
    line: int          # 1-based; 0 for whole-module / semantic findings
    message: str
    hint: str = ""     # how to fix it

    @property
    def key(self) -> str:
        """Baseline identity: line numbers shift, messages don't."""
        return f"{self.rule}|{self.path}|{self.message}"

    def to_dict(self) -> dict:
        return asdict(self)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: {self.rule}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


def suppressed_rules(source_line: str) -> set[str]:
    """Rule ids disabled by an inline ``# mpwlint: disable=...`` comment."""
    m = _SUPPRESS_RE.search(source_line)
    if not m:
        return set()
    return {tok.strip() for tok in m.group(1).split(",") if tok.strip()}


def is_suppressed(finding: Finding, lines: list[str]) -> bool:
    if not (1 <= finding.line <= len(lines)):
        return False
    rules = suppressed_rules(lines[finding.line - 1])
    return finding.rule in rules or "all" in rules


def load_baseline(path: Path) -> set[str]:
    """Committed waiver file: a JSON list of finding dicts (or keys)."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text() or "[]")
    entries = data.get("findings", data) if isinstance(data, dict) else data
    keys: set[str] = set()
    for e in entries:
        if isinstance(e, str):
            keys.add(e)
        else:
            keys.add(f"{e['rule']}|{e['path']}|{e['message']}")
    return keys


def write_baseline(path: Path, findings: list[Finding]) -> None:
    payload = {"findings": [f.to_dict() for f in findings]}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
