"""Layer-1 driver: walk files, run rules, apply inline suppressions."""
from __future__ import annotations

import subprocess
from pathlib import Path
from typing import Iterable, Optional

from tools.mpwlint.findings import Finding, is_suppressed
from tools.mpwlint.rules import RULES, audit_mpw_verbs, build_context


def discover_files(paths: Iterable[str], repo_root: Path) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        path = (repo_root / p) if not Path(p).is_absolute() else Path(p)
        if path.is_dir():
            files.extend(sorted(f for f in path.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif path.suffix == ".py" and path.exists():
            files.append(path)
    return files


def changed_files(repo_root: Path) -> Optional[set[str]]:
    """Repo-relative paths touched vs HEAD (+ untracked); None if git fails."""
    try:
        diff = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"],
            cwd=repo_root, capture_output=True, text=True, check=True)
        untracked = subprocess.run(
            ["git", "ls-files", "--others", "--exclude-standard"],
            cwd=repo_root, capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, OSError):
        return None
    names = set(diff.stdout.split()) | set(untracked.stdout.split())
    return {n for n in names if n.endswith(".py")}


def _rel(path: Path, repo_root: Path) -> str:
    try:
        return path.resolve().relative_to(repo_root.resolve()).as_posix()
    except ValueError:
        return path.resolve().as_posix()     # outside the repo: absolute


def lint_file(path: Path, repo_root: Path,
              rules: Optional[Iterable[str]] = None) -> list[Finding]:
    relpath = _rel(path, repo_root)
    source = path.read_text()
    try:
        ctx = build_context(relpath, source)
    except SyntaxError as e:
        return [Finding("R0", relpath, e.lineno or 0,
                        f"file does not parse: {e.msg}", "fix the syntax")]
    findings: list[Finding] = []
    for rule_id, rule in RULES.items():
        if rules is not None and rule_id not in rules:
            continue
        findings.extend(rule(ctx))
    return [f for f in findings if not is_suppressed(f, ctx.lines)]


def lint_paths(paths: Iterable[str], repo_root: Path,
               rules: Optional[Iterable[str]] = None,
               only: Optional[set[str]] = None) -> list[Finding]:
    """Run every Layer-1 rule over the python files under `paths`.

    `only` restricts to a set of repo-relative paths (--changed-only)."""
    findings: list[Finding] = []
    linted_api = False
    for f in discover_files(paths, repo_root):
        rel = _rel(f, repo_root)
        if only is not None and rel not in only:
            continue
        findings.extend(lint_file(f, repo_root, rules))
        linted_api = linted_api or rel == "src/repro/core/api.py"
    if linted_api and (rules is None or "R4" in rules):
        findings.extend(audit_mpw_verbs(repo_root))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
