"""Command line entry: `python -m tools.mpwlint src/ [options]`.

Exit code 0 when every finding is baselined (or there are none); 1 when a
non-baselined finding exists; 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.mpwlint.engine import changed_files, lint_paths
from tools.mpwlint.findings import load_baseline, write_baseline
from tools.mpwlint.semantic import run_semantic

DEFAULT_BASELINE = "tools/mpwlint/baseline.json"


def repo_root_of(start: Path) -> Path:
    for p in (start, *start.parents):
        if (p / ".git").exists() or (p / "ROADMAP.md").exists():
            return p
    return start


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m tools.mpwlint",
        description="MPWide-repro static analysis: AST rules R1-R5 plus "
                    "the semantic plan verifier S1-S4 (docs/lint.md).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed waiver file (repo-relative)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current findings")
    ap.add_argument("--changed-only", action="store_true",
                    help="lint only files changed vs HEAD (+ untracked); "
                    "the semantic verifier runs only when core/ changed")
    ap.add_argument("--no-semantic", action="store_true",
                    help="skip the Layer-2 plan verifier (AST rules only)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset, e.g. R1,R3")
    ap.add_argument("--output", default=None,
                    help="also write the JSON report to this file")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    repo_root = repo_root_of(Path.cwd())
    rules = ({r.strip() for r in args.rules.split(",") if r.strip()}
             if args.rules else None)

    only = None
    run_sem = not args.no_semantic
    if args.changed_only:
        only = changed_files(repo_root)
        if only is None:
            print("mpwlint: --changed-only needs git; linting everything",
                  file=sys.stderr)
        else:
            run_sem = run_sem and any("src/repro/core/" in p for p in only)

    findings = lint_paths(args.paths, repo_root, rules=rules, only=only)
    if run_sem and (rules is None or any(r.startswith("S") for r in rules)):
        sem = run_semantic(repo_root)
        if rules is not None:
            sem = [f for f in sem if f.rule in rules]
        findings.extend(sem)

    baseline_path = repo_root / args.baseline
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"mpwlint: wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(baseline_path)
    fresh = [f for f in findings if f.key not in baseline]
    n_baselined = len(findings) - len(fresh)

    report = {
        "findings": [f.to_dict() for f in fresh],
        "baselined": n_baselined,
        "count": len(fresh),
    }
    if args.output:
        Path(args.output).write_text(json.dumps(report, indent=2) + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in fresh:
            print(f.render())
        print(f"mpwlint: {len(fresh)} finding(s)"
              + (f", {n_baselined} baselined" if n_baselined else ""))
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
