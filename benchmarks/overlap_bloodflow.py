"""Paper §1.2.2: the bloodflow coupling hides an 11 ms-RTT WAN exchange so
only 6 ms per exchange is exposed (1.2% of runtime).

Analogue: gradient-accumulation sync overlap (core/overlap.py) — the
cross-pod sync of microbatch i runs during microbatch i+1's compute, so only
the last sync is exposed.
  (a) MODELED: the alpha-beta exposure model for the paper's UCL-HECToR link
      reproduces the 6 ms / 1.2% numbers.
  (b) MEASURED: overlap on/off wall-clock on fake CPU devices (relative
      effect only — CPU collectives don't overlap like real DMA engines).
"""
from __future__ import annotations

from benchmarks.common import UCL_HECTOR_RTT, run_multidev
from repro.core.autotune import model_transfer
from repro.core.path import LinkSpec


def modeled() -> str:
    # paper: boundary exchanges every 0.6 s of simulated flow; full
    # description: 11 ms message RTT; exposed 6 ms per exchange; 1.2% of
    # runtime.  One exchange ships small boundary-condition buffers (~100 KB)
    link = LinkSpec("ucl-hector", UCL_HECTOR_RTT / 2, 120e6)
    payload = 100e3
    naive, _ = model_transfer(payload, link, 1, compute_window=0.0)
    # latency hiding: issue the exchange at the start of the 0.5 s step;
    # exposure = what cannot overlap: the final one-way latency + tail
    _, exposed = model_transfer(payload, link, 1, compute_window=naive)
    step_s = 0.5
    parts = [
        "| quantity | paper | modeled |",
        "|---|---|---|",
        f"| naive exchange time | ~11 ms (RTT-bound) | {naive*1e3:.1f} ms |",
        f"| exposed per exchange (overlap) | 6 ms | {exposed*1e3:.1f} ms |",
        f"| coupling overhead of runtime | 1.2% | {exposed/step_s*100:.1f}% |",
    ]
    return "\n".join(parts)


_MEASURE = r"""
import time, json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step
from repro.models.registry import batch_concrete
from jax.sharding import NamedSharding

cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
out = {}
for m_micro, label in [(1, "no_overlap_m1"), (4, "overlap_m4")]:
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 16, "train"),
                   comm=CommConfig(mode="hierarchical", streams=8, chunk_mb=0.01),
                   train=TrainConfig(zero1=True, microbatches=m_micro))
    with jax.set_mesh(mesh):
        b = build_train_step(rc, mesh)
        state = jax.device_put(b.init_state(0), jax.tree.map(
            lambda s: NamedSharding(mesh, s), b.state_specs,
            is_leaf=lambda x: isinstance(x, P)))
        batch = jax.device_put(batch_concrete(cfg, "train", 16, 64),
                               jax.tree.map(lambda s: NamedSharding(mesh, s),
                                            b.batch_specs,
                                            is_leaf=lambda x: isinstance(x, P)))
        state, m = b.fn(state, batch); jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(5):
            state, m = b.fn(state, batch)
        jax.block_until_ready(m["loss"])
        out[label] = (time.perf_counter() - t0) / 5
print("RESULT:" + json.dumps(out))
"""


def run() -> str:
    res = run_multidev(_MEASURE, timeout=900)
    parts = ["## Bloodflow coupling — latency hiding (MPW_ISendRecv)", "",
             "### Modeled (paper's UCL-HECToR link)", "",
             modeled(), "",
             "### Measured (microbatch-pipelined sync, fake CPU devices)", "",
             "| config | step time |", "|---|---|",
             f"| m=1 (sync exposed) | {res['no_overlap_m1']*1e3:.0f} ms |",
             f"| m=4 (sync of mb i inside mb i+1) | {res['overlap_m4']*1e3:.0f} ms |",
             "",
             "m=4 runs 4x the compute per step; the relevant check is that "
             "overlap keeps the per-microbatch cost flat while the paper's "
             "exposure math above carries the WAN-regime result.", ""]
    return "\n".join(parts)


if __name__ == "__main__":
    print(run())
