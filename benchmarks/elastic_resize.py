"""Elastic membership: convergence-vs-WAN-bytes for local-SGD K, and the
cost of losing (then regaining) a site mid-run.

Two halves, mirroring `chaos_recovery`'s split:

* **K-curve (measured)** — real 4-site local-SGD training runs on the
  emulated CosmoGrid mesh at K ∈ {1, 4, 16}: equal-tolerance final loss at
  a fraction of the cross-site traffic (WAN bytes are the modeled
  gateway-ring bytes of `localsgd.reference_wan_bytes`; K=1 *is* the
  synchronous pipeline).
* **Site loss (control plane, no devices)** — the lease state machine on
  the CosmoGrid star: tokyo's only link drops at step S; steps-to-resume
  is fault -> evict/resize latency (the lease), and the modeled post-resize
  delta-sync throughput must be no worse than a 3-site fault-free baseline
  (it is *better* than the pre-fault 4-site world: the dead link was the
  slowest).

`benchmarks/run.py --json` exports RESULTS (section `elastic`); the
`*_speedup` / `*throughput*` keys feed `benchmarks/perf_gate.py`.
"""
from __future__ import annotations

import os

from repro.core import cosmogrid_topology
from repro.core.chaos import IncidentLog
from repro.core.localsgd import reference_wan_bytes
from repro.core.membership import SiteMembership

from benchmarks.common import run_multidev

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
STEPS = 16 if DRY else 48
KS = (1, 4, 16)
FAULT_AT, HEAL_AT = 6, 14
LOSS_TOL = 0.5

RESULTS: dict = {}
# the site-loss scenario's incident timeline, exported as a CI artifact
# (`python -m benchmarks.elastic_resize ELASTIC_timeline.json`)
TIMELINE: list = []

_K_CURVE = """
import json
import jax
from repro.configs import (get_config, smoke_config, RunConfig, ShapeConfig,
                           CommConfig, TrainConfig)
from repro.runtime import Trainer
from repro.core import cosmogrid_topology
from repro.data import DataConfig, make_pipeline

STEPS = %(steps)d
mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
out = {}
for k in %(ks)r:
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=4,
                                   chunk_mb=0.01, autotune=False,
                                   local_steps=k),
                   train=TrainConfig(zero1=True, warmup_steps=2,
                                     total_steps=max(50, STEPS)))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8), prefetch=0)
    t = cosmogrid_topology(backup_links=True)
    with jax.set_mesh(mesh):
        tr = Trainer(rc, mesh, route=t.route("amsterdam", "tokyo"),
                     site_groups=t.pod_groups())
        tr.init_or_restore()
        hist = tr.run(data, STEPS, log_every=0)
    out[f"final_loss_k{k}"] = float(hist[-1]["loss"])
out["n_params"] = cfg.param_count()
print("RESULT:" + json.dumps(out))
"""


def _k_curve() -> dict:
    meas = run_multidev(_K_CURVE % {"steps": STEPS, "ks": list(KS)},
                        timeout=1200)
    n_params = int(meas["n_params"])
    res: dict = {}
    bytes_k = {k: reference_wan_bytes(n_params, STEPS, k, n_sites=4)
               for k in KS}
    for k in KS:
        res[f"final_loss_k{k}"] = meas[f"final_loss_k{k}"]
        res[f"wan_bytes_k{k}"] = bytes_k[k]
    for k in KS[1:]:
        res[f"wan_byte_speedup_k{k}"] = bytes_k[KS[0]] / bytes_k[k]
        gap = abs(meas[f"final_loss_k{k}"] - meas["final_loss_k1"])
        if gap >= LOSS_TOL:
            raise AssertionError(
                f"K={k} final loss diverged from synchronous by {gap:.3f} "
                f"(tolerance {LOSS_TOL})")
        res[f"loss_gap_k{k}"] = gap
    return res, n_params


def _delta_sync_wan_s(topo, members: list, n_params: int, step: int) -> float:
    """Modeled seconds of one delta sync: every member site's delta share
    crosses its hub link; the sync completes when the slowest member does."""
    share = reference_wan_bytes(n_params, 1, 1, len(members))
    worst = 0.0
    for name in members:
        if name == "amsterdam":
            continue
        prof = topo.link("amsterdam", name)
        worst = max(worst, prof.transfer_s(share, step=step))
    return worst


def _site_loss(n_params: int) -> dict:
    """Lease state machine on the star: fault -> evict -> resize, and the
    modeled delta-sync throughput before/after the resize."""
    t = cosmogrid_topology()
    for a, b in (("amsterdam", "tokyo"), ("tokyo", "amsterdam")):
        t.connect(a, b, t.link(a, b).drop(FAULT_AT, until=HEAL_AT))
    log = IncidentLog()
    mem = SiteMembership(t, "amsterdam", lease_steps=2, rejoin_after=2,
                         log=log)
    pre_members = list(mem.members())
    pre_s = _delta_sync_wan_s(t, pre_members, n_params, step=0)
    for step in range(HEAL_AT + 4):
        mem.on_step(step)
    ev = {e.kind: e for e in log.events()}
    TIMELINE[:] = [[e.kind, e.subject, e.step] for e in log.events()]
    steps_to_resume = ev["evict"].step - FAULT_AT
    resized = [s for s in pre_members if s != "tokyo"]
    post_s = _delta_sync_wan_s(t, resized, n_params, step=ev["evict"].step + 1)
    # the 3-site fault-free baseline is the same member set on healthy links
    t3 = cosmogrid_topology()
    base_s = _delta_sync_wan_s(t3, resized, n_params, step=0)
    return {
        "steps_to_detect": ev["detect"].step - FAULT_AT,
        "steps_to_resume": steps_to_resume,
        "rejoin_step": ev["join"].step,
        "post_resize_throughput_ratio": base_s / post_s,
        "resize_speedup_vs_presize": pre_s / post_s,
    }


def run() -> str:
    curve, n_params = _k_curve()
    loss = _site_loss(n_params)
    if loss["post_resize_throughput_ratio"] < 0.999:
        raise AssertionError(
            f"post-resize throughput fell below the 3-site baseline: "
            f"{loss['post_resize_throughput_ratio']:.3f}")
    RESULTS.update(curve)
    RESULTS.update(loss)
    rows = "\n".join(
        f"| {k} | {curve[f'final_loss_k{k}']:.4f} | "
        f"{curve[f'wan_bytes_k{k}'] / 1e9:.2f} GB | "
        f"{curve[f'wan_bytes_k1'] / curve[f'wan_bytes_k{k}']:.0f}x |"
        for k in KS)
    return "\n".join([
        "## Elastic resize: local-SGD K-curve and site-loss recovery",
        "",
        f"{STEPS} steps, 4-site CosmoGrid, measured losses on the emulated "
        "mesh; WAN bytes are the modeled gateway-ring traffic.",
        "",
        "| K | final loss | WAN bytes | traffic reduction |",
        "|---|---|---|---|",
        rows,
        "",
        "Site loss (tokyo's only link drops at step "
        f"{FAULT_AT}, heals at {HEAL_AT}):",
        "",
        "| metric | value |",
        "|---|---|",
        f"| steps to detect (suspect) | {loss['steps_to_detect']} |",
        f"| steps to resume (evict + resize) | {loss['steps_to_resume']} |",
        f"| rejoin step (replica catch-up) | {loss['rejoin_step']} |",
        f"| post-resize throughput vs 3-site baseline | "
        f"{loss['post_resize_throughput_ratio']:.2f}x |",
        f"| post-resize speedup vs pre-fault 4-site | "
        f"{loss['resize_speedup_vs_presize']:.2f}x |",
    ])


if __name__ == "__main__":
    import json
    import sys

    print(run())
    if len(sys.argv) > 1:   # CI artifact: results + incident timeline
        with open(sys.argv[1], "w") as f:
            json.dump({"results": RESULTS, "timeline": TIMELINE}, f,
                      indent=2, default=float)
        print(f"\n_(timeline written to {sys.argv[1]})_")
