"""Bucketed backward overlap: exposed WAN seconds vs accumulate-then-sync.

The paper's headline trick is latency hiding (the bloodflow coupling leaves
6 ms of an 11 ms RTT exchange exposed).  `benchmarks/overlap_bloodflow.py`
shows the microbatch-pipelined version, which needs `microbatches > 1` and
still exposes one *whole-tree* sync.  This section quantifies what the
layer-bucketed scheduler (`repro/core/buckets.py`) buys:

  (a) MODELED — sweep `microbatches x bucket_mb` on the window-capped
      London-Poznan link: per-bucket transfers flush during the backward
      window and the optimizer consumes the tail bucket-by-bucket.
      Acceptance (asserted):
        * at m=1, bucketed overlap exposes <= 1/4 of accumulate-then-sync's
          modeled comm seconds;
        * exposure shrinks monotonically as bucket_mb decreases, until the
          per-bucket latency floor.
  (b) MEASURED — a real bucketed train step on fake CPU devices (2x2x2
      mesh): per-bucket `bkt{i}` telemetry + nonzero `overlapped_s` in
      `MPW.Report()` (asserted), relative step time vs unbucketed.

`benchmarks/run.py --json` exports RESULTS for the cross-PR perf gate.
"""
from __future__ import annotations

import os

from benchmarks.common import run_multidev
from repro.core.autotune import simulate_transfer_s, tune
from repro.core.overlap import modeled_exposure
from repro.core.path import WAN_LONDON_POZNAN

RESULTS: dict = {}

LINK = WAN_LONDON_POZNAN
BUCKET_SWEEP_MB = (0.0, 64.0, 32.0, 16.0, 8.0, 4.0, 1.0, 0.25)


def _dry() -> bool:
    return os.environ.get("WIDEJAX_BENCH_DRY") == "1"


def modeled() -> str:
    payload = (32 << 20) if _dry() else (256 << 20)
    world = 4
    t = tune(payload, LINK, world=world)
    knobs = dict(streams=t.streams, chunk_bytes=t.chunk_bytes, world=world)
    base_t = simulate_transfer_s(payload, LINK, streams=t.streams,
                                 chunk_bytes=t.chunk_bytes, world=world)
    # compute/comm ratio 1.5: the CosmoGrid regime — enough local work per
    # step to hide the WAN sync, if the scheduler can get it in flight
    window = 1.5 * base_t

    rows = ["| microbatches | bucket_mb | n_buckets | comm s | exposed s | "
            "overlap eff |", "|---|---|---|---|---|---|"]
    # keyed by parameters (not list position) so the CI perf gate compares
    # like with like even when the sweep grid changes across PRs
    RESULTS["modeled"] = {}
    sweep: dict[int, list] = {}
    for m in (1, 2, 4):
        sweep[m] = []
        for bmb in BUCKET_SWEEP_MB:
            r = modeled_exposure(payload, LINK, pacing=1.0,
                                 compute_window=window,
                                 bucket_bytes=int(bmb * (1 << 20)),
                                 microbatches=m, **knobs)
            eff = r["overlapped_s"] / r["comm_s"] if r["comm_s"] else 0.0
            rows.append(f"| {m} | {bmb:g} | {r['n_buckets']} "
                        f"| {r['comm_s']:.2f} | {r['exposed_s']:.3f} "
                        f"| {eff*100:.0f}% |")
            sweep[m].append((bmb, r["exposed_s"]))
            RESULTS["modeled"][f"m{m}_bucket{bmb:g}"] = dict(
                n_buckets=r["n_buckets"], comm_s=r["comm_s"],
                exposed_s=r["exposed_s"], overlap_efficiency=eff)

    # acceptance 1: at m=1 accumulate-then-sync exposes its whole comm time;
    # bucketed overlap must expose <= 1/4 of it
    base = dict(sweep[1])[0.0]
    best_exposed = min(e for b, e in sweep[1] if b > 0)
    assert best_exposed <= base / 4, (
        f"bucketed m=1 exposure {best_exposed:.3f}s not <= 1/4 of "
        f"accumulate-then-sync {base:.3f}s")

    # acceptance 2: exposure shrinks monotonically as bucket_mb decreases,
    # until the per-bucket latency floor (after which overheads win)
    curve = [e for b, e in sweep[1] if b > 0]          # descending bucket_mb
    floor = curve.index(min(curve))
    for a, b in zip(curve[:floor], curve[1:floor + 1]):
        assert b <= a * 1.001, f"exposure not monotone before floor: {curve}"

    RESULTS["m1"] = dict(base_exposed_s=base, bucketed_exposed_s=best_exposed,
                         exposure_speedup=base / max(best_exposed, 1e-12))
    rows += ["", f"m=1: accumulate-then-sync exposes {base:.2f}s; bucketed "
             f"floor {best_exposed:.3f}s — **{base/best_exposed:.0f}x less "
             f"exposed WAN time** ({RESULTS['m1']['exposure_speedup']:.0f}x "
             "speedup of the exposed fraction)."]
    return "\n".join(rows)


_MEASURE = r"""
import json, os, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step
from repro.models.registry import batch_concrete

steps = 1 if os.environ.get("WIDEJAX_BENCH_DRY") == "1" else 3
cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
out = {}
for label, bucket_mb in [("unbucketed", 0.0), ("bucketed", 0.05)]:
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=4,
                                   chunk_mb=0.01, bucket_mb=bucket_mb,
                                   autotune=False),
                   train=TrainConfig(zero1=True, microbatches=1))
    with jax.set_mesh(mesh):
        b = build_train_step(rc, mesh)
        state = jax.device_put(b.init_state(0), jax.tree.map(
            lambda s: NamedSharding(mesh, s), b.state_specs,
            is_leaf=lambda x: isinstance(x, P)))
        batch = jax.device_put(batch_concrete(cfg, "train", 8, 32),
                               jax.tree.map(lambda s: NamedSharding(mesh, s),
                                            b.batch_specs,
                                            is_leaf=lambda x: isinstance(x, P)))
        state, m = b.fn(state, batch); jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = b.fn(state, batch)
        jax.block_until_ready(m["loss"])
        out[label] = {"step_s": (time.perf_counter() - t0) / steps,
                      "loss": float(m["loss"]),
                      "n_buckets": len(b.bucket_plan.buckets) if b.bucket_plan else 0}

from repro.core.telemetry import get_telemetry
rep = get_telemetry().report()
out["bkt_keys"] = sorted(k for k in rep if k.startswith("train:interpod/bkt"))
s = rep["train:interpod"]
out["exposed_s"] = s.get("exposed_s", 0.0)
out["overlapped_s"] = s.get("overlapped_s", 0.0)
print("RESULT:" + json.dumps(out))
"""


def run() -> str:
    parts = ["## Bucketed backward overlap — exposed WAN time vs "
             "accumulate-then-sync", "",
             "### Modeled (London-Poznan window-capped link)", "",
             modeled(), ""]
    res = run_multidev(_MEASURE, timeout=900)
    assert res["bkt_keys"], "bucketed step recorded no per-bucket telemetry"
    assert res["overlapped_s"] > 0, (
        "train path must report nonzero overlapped_s")
    assert abs(res["unbucketed"]["loss"] - res["bucketed"]["loss"]) < 1e-4
    RESULTS["measured"] = res
    parts += [
        "### Measured (bucketed train step, fake CPU devices)", "",
        "| config | buckets | step time | loss |", "|---|---|---|---|",
        f"| unbucketed | - | {res['unbucketed']['step_s']*1e3:.0f} ms "
        f"| {res['unbucketed']['loss']:.4f} |",
        f"| bucketed (flush+tail) | {res['bucketed']['n_buckets']} "
        f"| {res['bucketed']['step_s']*1e3:.0f} ms "
        f"| {res['bucketed']['loss']:.4f} |", "",
        f"Per-bucket telemetry keys: `{'`, `'.join(res['bkt_keys'])}`; "
        f"train path models {res['overlapped_s']*1e3:.2f} ms overlapped vs "
        f"{res['exposed_s']*1e3:.2f} ms exposed.  (CPU emulation validates "
        "plumbing and numerics; the WAN-regime win is the modeled table.)",
        ""]
    return "\n".join(parts)


if __name__ == "__main__":
    print(run())
