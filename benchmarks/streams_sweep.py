"""Paper §1.3.1: "use >=32 streams on long-distance networks; up to 256
streams remain efficient; 1 stream for local programs".

  (a) MODELED: stream-count sweep of the window-capped throughput model on
      the paper's London-Poznan link, and of the autotuner's exposure model
      on the inter-pod link.
  (b) MEASURED: streamed_psum wall time vs stream count on fake CPU devices
      (overhead flatness check up to 256 streams).
"""
from __future__ import annotations

from benchmarks.common import TABLE1_LINKS, run_multidev, stream_throughput
from repro.core.autotune import tune
from repro.core.path import ICI, INTERPOD

SWEEP = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def modeled() -> str:
    link = TABLE1_LINKS[0]
    rows = ["| streams | London-Poznan modeled MB/s |", "|---|---|"]
    for s in SWEEP:
        rows.append(f"| {s} | {stream_throughput(link, s)/1e6:.0f} |")
    t_wan = tune(512 << 20, INTERPOD, world=2)
    t_loc = tune(512 << 20, ICI, world=16)
    rows += ["",
             f"autotuner (512 MB payload): inter-pod -> **{t_wan.streams} "
             f"streams** / {t_wan.chunk_bytes>>20} MB chunks; "
             f"intra-pod -> {t_loc.streams} streams "
             f"(paper: >=32 WAN, 1 local)."]
    return "\n".join(rows)


_MEASURE = r"""
import os, time, json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, streamed_psum
from repro.configs.base import CommConfig
mesh = jax.make_mesh((2,4), ("pod","data"), axis_types=(jax.sharding.AxisType.Auto,)*2)
dry = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
N = ((2 << 20) if dry else (64 << 20)) // 4
payload = {"g": jnp.ones((N,), jnp.float32)}
out = {}
for s in ([1, 32] if dry else [1, 8, 32, 128, 256]):
    path = WidePath(axis="pod", comm=CommConfig(streams=s, chunk_mb=max(0.25, 64/s)))
    def body(t):
        return streamed_psum(t, path, dims={"g": 0})
    f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                axis_names={"pod","data"}, check_vma=False))
    with jax.set_mesh(mesh):
        r = f(payload); jax.block_until_ready(r)
        t0 = time.perf_counter()
        for _ in range(3):
            r = f(payload)
        jax.block_until_ready(r)
        out[str(s)] = (time.perf_counter() - t0) / 3
print("RESULT:" + json.dumps(out))
"""


def run() -> str:
    res = run_multidev(_MEASURE, timeout=900)
    rows = ["| streams | measured chunked psum (CPU devs) |", "|---|---|"]
    for k, v in res.items():
        rows.append(f"| {k} | {v*1e3:.1f} ms |")
    return "\n".join([
        "## Streams sweep — multi-stream paths (1 -> 256)", "",
        "### Modeled", "", modeled(), "",
        "### Measured (chunked psum op-count overhead)", "",
        *rows, ""])


if __name__ == "__main__":
    print(run())
