"""Paper Fig. 1: the 2048-core cosmology run across 3 supercomputers is only
9% slower than the same run on one machine.

Analogue: the same training step on the single-pod mesh vs the multi-pod
mesh.  Two measurements:
  (a) MODELED from dry-run artifacts: roofline step time single vs multi for
      the same (arch × shape), with the cross-pod term added (WAN stage).
  (b) MEASURED: a reduced config trained on 8 fake CPU devices arranged as
      one "site" (1,4,2) vs two "sites" (2,2,2) — wall-clock per step.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import run_multidev

_MEASURE = r"""
import time, json
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step
from repro.models.registry import batch_concrete
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = smoke_config(get_config("llama3.2-3b"))
out = {}
for name, shape, axes in [("single_site", (4,2), ("data","model")),
                          ("three_sites", (2,2,2), ("pod","data","model"))]:
    mesh = jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,)*len(axes))
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=8, chunk_mb=0.01),
                   train=TrainConfig(zero1=True))
    with jax.set_mesh(mesh):
        b = build_train_step(rc, mesh)
        state = jax.device_put(b.init_state(0), jax.tree.map(
            lambda s: NamedSharding(mesh, s), b.state_specs,
            is_leaf=lambda x: isinstance(x, P)))
        batch = jax.device_put(batch_concrete(cfg, "train", 8, 64),
                               jax.tree.map(lambda s: NamedSharding(mesh, s),
                                            b.batch_specs,
                                            is_leaf=lambda x: isinstance(x, P)))
        state, m = b.fn(state, batch); jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(5):
            state, m = b.fn(state, batch)
        jax.block_until_ready(m["loss"])
        out[name] = (time.perf_counter() - t0) / 5
print("RESULT:" + json.dumps(out))
"""


def modeled(dryrun_json: str = "results/dryrun.json",
            arch: str = "llama3.2-3b", shape: str = "train_4k") -> str:
    if not os.path.exists(dryrun_json):
        return "_(dry-run results not present yet — run launch.dryrun)_"
    with open(dryrun_json) as f:
        data = json.load(f)
    rows = {}
    for r in data:
        if (r.get("arch"), r.get("shape"), r.get("status")) == (arch, shape, "ok"):
            rows[r["mesh"]] = r["roofline"]
    if "single" not in rows or "multi" not in rows:
        return f"_(need single+multi records for {arch}×{shape})_"
    s = max(rows["single"]["compute_s"], rows["single"]["memory_s"],
            rows["single"]["collective_s"])
    m = max(rows["multi"]["compute_s"], rows["multi"]["memory_s"],
            rows["multi"]["collective_s"])
    # the global batch is fixed (weak-scaling a la Fig 1's fixed simulation):
    # 512 chips do HALF the per-chip work of 256 chips, so the fair
    # distributed overhead compares multi against single/2
    ovh = (m / (s / 2) - 1.0) * 100
    return (f"| mesh | bound step time | per-chip work |\n|---|---|---|\n"
            f"| single-pod (256 chips) | {s*1e3:.1f} ms | 1x |\n"
            f"| multi-pod (512 chips, WAN stage) | {m*1e3:.1f} ms | 0.5x |\n\n"
            f"modeled distributed overhead at equal per-chip work: "
            f"**{ovh:+.1f}%** (paper Fig. 1: +9% across 3 supercomputers)")


def run() -> str:
    res = run_multidev(_MEASURE, timeout=900)
    s, t = res["single_site"], res["three_sites"]
    parts = ["## Fig. 1 — distributed vs single-site step time", "",
             "### Modeled (production meshes, from dry-run)", "",
             modeled(), "",
             "### Measured (8 fake CPU devices, reduced config)", "",
             f"| layout | step time |\n|---|---|\n"
             f"| one site (4x2) | {s*1e3:.0f} ms |\n"
             f"| two sites (2x2x2) | {t*1e3:.0f} ms |", "",
             f"measured overhead: {((t/s)-1)*100:+.1f}% "
             f"(paper: +9%; CPU-device noise applies)", ""]
    return "\n".join(parts)


if __name__ == "__main__":
    print(run())
