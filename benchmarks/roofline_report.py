"""§Roofline report: render the dry-run JSON into the per-cell table
(three terms, dominant bottleneck, useful-FLOPs fraction)."""
from __future__ import annotations

import json
import os
from collections import defaultdict


def render(dryrun_json: str = "results/dryrun.json", mesh: str = "single") -> str:
    if not os.path.exists(dryrun_json):
        return f"_missing {dryrun_json} — run `python -m repro.launch.dryrun --all`_"
    with open(dryrun_json) as f:
        data = json.load(f)
    # prefer the baseline config records (hierarchical / no compress)
    best = {}
    for r in data:
        if r.get("mesh") != mesh:
            continue
        key = (r["arch"], r["shape"])
        if r.get("status") == "skipped":
            best.setdefault(key, r)
            continue
        if r.get("status") != "ok":
            best.setdefault(key, r)
            continue
        if (r.get("comm_mode"), r.get("compress")) == ("hierarchical", "none"):
            best[key] = r
        else:
            best.setdefault(key, r)
    rows = [
        "| arch | shape | compute | memory | collective (ici+xpod) | bound | "
        "dominant | useful FLOPs | fits 16G? |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    doms = defaultdict(int)
    for (arch, shape), r in sorted(best.items()):
        if r.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | — | — | — | — | skipped | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | — | ERROR | — | — |")
            continue
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        fits = r["memory"]["per_device_total"] < 16 * 2**30
        doms[rf["dominant"]] += 1
        rows.append(
            f"| {arch} | {shape} | {rf['compute_s']*1e3:.1f} ms "
            f"| {rf['memory_s']*1e3:.1f} ms "
            f"| {rf['collective_s']*1e3:.1f} ms "
            f"| {bound*1e3:.1f} ms | {rf['dominant']} "
            f"| {rf['useful_flops_frac']*100:.0f}% "
            f"| {'yes' if fits else 'NO'} |")
    rows.append("")
    rows.append("dominant-term histogram: "
                + ", ".join(f"{k}={v}" for k, v in sorted(doms.items())))
    return "\n".join(rows)


def run() -> str:
    import os
    parts = [
        "## Roofline — baseline table (single-pod 16x16, 256 chips, paper-faithful config)", "",
        render("results/dryrun.json", mesh="single"), "",
        "## Roofline — baseline multi-pod (2x16x16, 512 chips)", "",
        render("results/dryrun.json", mesh="multi"), ""]
    if os.path.exists("results/dryrun_opt.json"):
        parts += [
            "## Roofline — OPTIMIZED (after EXPERIMENTS.md §Perf), single-pod", "",
            render("results/dryrun_opt.json", mesh="single"), "",
            "## Roofline — OPTIMIZED, multi-pod", "",
            render("results/dryrun_opt.json", mesh="multi"), ""]
    return "\n".join(parts)


if __name__ == "__main__":
    print(run())
