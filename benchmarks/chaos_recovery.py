"""Chaos recovery latency — detect / re-route / recover on the CosmoGrid star.

Drives the real `ChaosMonitor` control loop (detector thresholds, topology
replan, incident log) against a stub trainer — the response path is
identical to the `Trainer(chaos=...)` wiring but needs no devices, so the
benchmark measures pure control-plane latency in *steps*:

  * the amsterdam->tokyo lightpath drops mid-run;
  * the monitor's per-hop telemetry collapses to the watchdog timeout;
  * detection fires after the consecutive-anomaly window, the route
    replans over the edinburgh backup, and recovery is declared after the
    post-heal window.

A healed mpw-cp transfer over the same dead link reports the data-plane
cost: chunk requeue count and wire-byte overhead of the bytes burned on
the dead hop.

Acceptance (asserted below): detection within the detector window of the
injection, a replanned route that avoids the dead link, and a recovery
latency covering inject -> detect -> heal.  `benchmarks/run.py --json`
exports RESULTS (section `chaos_recovery`); run as a module with a path
argument to dump the incident timeline JSON (the CI chaos artifact).
"""
from __future__ import annotations

import os
import tempfile
from types import SimpleNamespace

from repro.configs.base import CommConfig
from repro.core import (
    ChaosDetector,
    ChaosMonitor,
    cosmogrid_topology,
    get_incident_log,
    get_telemetry,
    healing_transfer,
)
from repro.core.path import WidePath

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
STEPS = 12 if DRY else 40
FAULT_AT = 5 if DRY else 10
PAYLOAD = (1 << 19) if DRY else (1 << 21)

# machine-readable section results, exported by benchmarks/run.py --json
RESULTS: dict = {}
TIMELINE: list = []


class _StubTrainer:
    """The slice of the Trainer interface ChaosMonitor drives: a live
    route compiled to a WidePath, and the two healing responses."""

    def __init__(self, route):
        self.step = 0
        self.tuner = None
        self._rebuild(route)

    def _rebuild(self, route):
        self.route = route
        path = WidePath(axis="pod", name="bench-chaos")
        if route is not None:
            path = path.with_hops(route.as_hops())
        self.bundle = SimpleNamespace(path=path)

    def apply_route(self, new_route, log=print):
        self._rebuild(new_route)

    def failover_to_replica(self, log=print) -> str:
        self._rebuild(None)
        return "degraded"


def _control_loop() -> dict:
    log = get_incident_log()
    log.clear()
    t = cosmogrid_topology(backup_links=True)
    t.connect("amsterdam", "tokyo",
              t.link("amsterdam", "tokyo").drop(FAULT_AT))
    mon = ChaosMonitor(t, "amsterdam", "tokyo",
                       detector=ChaosDetector(window=2, min_baseline=2),
                       recover_after=2, payload_bytes=64 << 20)
    tr = _StubTrainer(t.route("amsterdam", "tokyo"))
    routed_healthy = 0
    for s in range(STEPS):
        tr.step = s
        mon.on_step(tr, log=lambda m: None)
        if tr.route is not None and all(
                not p.health(s).faulty for p in tr.route.profiles):
            routed_healthy += 1
    ev = {e.kind: e for e in log.events()}
    assert "inject" in ev and "detect" in ev and "replan" in ev, log.events()
    assert "recover" in ev, "no recovery within the run"
    assert "tokyo-edinburgh-backup" in ev["replan"].detail["route"]
    detect_steps = ev["detect"].step - ev["inject"].step
    assert detect_steps <= mon.detector.window
    recover_steps = int(ev["recover"].detail["latency_steps"])
    assert recover_steps > 0
    TIMELINE[:] = log.timeline()
    return {"time_to_detect_steps": detect_steps,
            "time_to_recover_steps": recover_steps,
            "routed_uptime_efficiency": routed_healthy / STEPS,
            "final_route": list(tr.route.sites)}


def _healed_transfer() -> dict:
    get_telemetry().reset()
    t = cosmogrid_topology(backup_links=True)
    t.connect("amsterdam", "tokyo", t.link("amsterdam", "tokyo").drop(0))
    eng = healing_transfer(t, "amsterdam", "tokyo",
                           comm=CommConfig(streams=4, chunk_mb=0.0625),
                           max_retries=1)
    with tempfile.TemporaryDirectory() as d:
        src, dst = os.path.join(d, "a"), os.path.join(d, "b")
        with open(src, "wb") as f:
            f.write(os.urandom(PAYLOAD))
        res = eng.copy(src, dst)
    assert res.reroutes == 1 and res.wire_bytes >= res.nbytes
    return {"heal_reroutes": res.reroutes,
            "heal_wire_overhead": res.wire_bytes / res.nbytes}


def run() -> str:
    ctl = _control_loop()
    xfer = _healed_transfer()
    RESULTS.update(ctl)
    RESULTS.update(xfer)
    lines = [
        "## Chaos recovery: lightpath drop on the CosmoGrid star",
        "",
        f"{STEPS} steps, fault injected at step {FAULT_AT}; detector "
        "window 2, post-heal window 2.",
        "",
        "| metric | value |",
        "|---|---|",
        f"| time to detect | {ctl['time_to_detect_steps']} steps |",
        f"| time to recover (inject -> healthy) | "
        f"{ctl['time_to_recover_steps']} steps |",
        f"| routed-uptime efficiency | "
        f"{ctl['routed_uptime_efficiency']:.2f} |",
        f"| healed route | {' -> '.join(ctl['final_route'])} |",
        f"| mpw-cp reroutes on dead link | {xfer['heal_reroutes']} |",
        f"| mpw-cp wire overhead (burned bytes) | "
        f"{xfer['heal_wire_overhead']:.2f}x |",
        "",
        "Incident timeline (also the CI chaos artifact):",
        "",
        get_incident_log().format_timeline(),
    ]
    return "\n".join(lines)


if __name__ == "__main__":
    import json
    import sys

    print(run())
    if len(sys.argv) > 1:
        with open(sys.argv[1], "w") as f:
            json.dump({"timeline": TIMELINE, "results": RESULTS}, f,
                      indent=2, default=float)
        print(f"\n(timeline written to {sys.argv[1]})")
