"""Online autotuner convergence: tuned vs. untuned vs. best-fixed-by-sweep.

The paper's autotuner exists because WAN path settings found by hand (or by
a one-shot model) drift from what the live link rewards; MPWide re-measures
and adapts.  This benchmark drives the :class:`OnlineTuner` against the
synthetic link simulator (`simulate_transfer_s`, the same alpha-beta +
TCP-window landscape the modeled benchmarks use, plus measurement noise):

  (a) SWEEP: exhaustively measure every fixed (streams, chunk_mb) grid cell
      — the oracle a human with unlimited patience would find;
  (b) ONLINE: start the tuner from the worst-practice config (1 stream, one
      payload-sized chunk — the scp baseline) and let it climb on noisy
      measurements, re-tuning every `window` samples;
  (c) report the convergence trajectory and the final config's cost against
      the sweep optimum (acceptance: within 10%).

Everything is deterministic (LCG noise), so the section is reproducible.
"""
from __future__ import annotations

from repro.core.autotune import (CHUNK_GRID_MB, STREAM_GRID, OnlineTuner,
                                 simulate_transfer_s)
from repro.core.path import WAN_LONDON_POZNAN
from repro.core.telemetry import get_telemetry

PAYLOAD = 64 << 20          # one gradient-sync payload
LINK = WAN_LONDON_POZNAN
JITTER = 0.05               # +-2.5% measurement noise
WINDOW = 5                  # samples per tuning decision
MAX_STEPS = 600             # host-side simulator: cheap even in --dry mode


def _measure(cfg: dict, seed: int, jitter: float = JITTER) -> float:
    return simulate_transfer_s(
        PAYLOAD, LINK, streams=cfg["streams"],
        chunk_bytes=cfg["chunk_mb"] * (1 << 20), pacing=cfg["pacing"],
        jitter=jitter, seed=seed)


def sweep() -> tuple[dict, float, list[str]]:
    """Best fixed (streams, chunk) over the full grid, noise-free."""
    best_cfg, best_t = None, float("inf")
    rows = ["| streams \\ chunk | " + " | ".join(f"{c}MiB" for c in CHUNK_GRID_MB) + " |",
            "|" + "---|" * (len(CHUNK_GRID_MB) + 1)]
    for s in STREAM_GRID:
        cells = [f"| {s} "]
        for c in CHUNK_GRID_MB:
            cfg = {"streams": s, "chunk_mb": c, "pacing": 1.0}
            t = _measure(cfg, seed=0, jitter=0.0)
            cells.append(f"| {t*1e3:.0f} ")
            if t < best_t:
                best_cfg, best_t = cfg, t
        rows.append("".join(cells) + "|")
    return best_cfg, best_t, rows


def online(start: dict) -> tuple[OnlineTuner, dict, list[tuple[int, dict, float]]]:
    tuner = OnlineTuner(streams=start["streams"], chunk_mb=start["chunk_mb"],
                        pacing=start["pacing"], window=WINDOW, warmup=1)
    cfg = tuner.config()
    tele = get_telemetry()
    traj: list[tuple[int, dict, float]] = []
    for i in range(MAX_STEPS):
        t = _measure(cfg, seed=i)
        tele.record("bench-online:lon-poz", t, nbytes=PAYLOAD, step=i)
        new = tuner.observe(t)
        if new is not None:
            traj.append((i, dict(new), t))
            cfg = new
        if tuner.converged:
            break
    return tuner, cfg, traj


def run() -> str:
    best_cfg, best_t, sweep_rows = sweep()
    untuned = {"streams": 1, "chunk_mb": float(CHUNK_GRID_MB[-1]), "pacing": 1.0}
    untuned_t = _measure(untuned, seed=0, jitter=0.0)
    tuner, final_cfg, traj = online(untuned)
    final_t = _measure(final_cfg, seed=0, jitter=0.0)
    ratio = final_t / best_t
    ok = ratio <= 1.10

    out = [
        "## Autotune convergence — online tuner vs. fixed-config sweep", "",
        f"Synthetic link: {LINK.name} (rtt {2*LINK.latency_s*1e3:.0f} ms, "
        f"capacity {LINK.bandwidth_Bps/1e6:.0f} MB/s, per-stream window "
        f"{int(LINK.window)>>10} KiB), payload {PAYLOAD>>20} MiB, "
        f"noise ±{JITTER*50:.1f}%.", "",
        "### (a) Sweep (noise-free transfer ms per fixed config)", "",
        *sweep_rows, "",
        f"Sweep optimum: **{best_cfg['streams']} streams / "
        f"{best_cfg['chunk_mb']} MiB chunks -> {best_t*1e3:.0f} ms**.", "",
        "### (b) Online trajectory (from the 1-stream scp-style baseline)", "",
        "| sample # | move to (streams, chunk MiB, pacing) | last measured |",
        "|---|---|---|",
    ]
    for i, cfg, t in traj:
        out.append(f"| {i} | ({cfg['streams']}, {cfg['chunk_mb']}, "
                   f"{cfg['pacing']}) | {t*1e3:.0f} ms |")
    out += [
        "",
        "### (c) Verdict", "",
        f"| config | transfer time | vs. sweep best |",
        f"|---|---|---|",
        f"| untuned (1 stream, {untuned['chunk_mb']:.0f} MiB) "
        f"| {untuned_t*1e3:.0f} ms | {untuned_t/best_t:.1f}x |",
        f"| online-tuned ({final_cfg['streams']} streams, "
        f"{final_cfg['chunk_mb']} MiB, pacing {final_cfg['pacing']}) "
        f"| {final_t*1e3:.0f} ms | {ratio:.2f}x |",
        f"| sweep best ({best_cfg['streams']} streams, "
        f"{best_cfg['chunk_mb']} MiB) | {best_t*1e3:.0f} ms | 1.00x |",
        "",
        f"Converged after {sum(1 for _ in tuner.history)} tuning windows "
        f"({'within' if ok else 'OUTSIDE'} the 10% acceptance band; "
        f"speedup over untuned: {untuned_t/final_t:.1f}x).", "",
        "### Telemetry report", "",
        get_telemetry().format_report(), "",
    ]
    if not ok:
        raise AssertionError(
            f"online tuner finished {ratio:.2f}x off the sweep optimum")
    return "\n".join(out)


if __name__ == "__main__":
    print(run())
