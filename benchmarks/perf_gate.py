"""Cross-PR perf-regression gate over ``benchmarks/run.py --json`` files.

Usage::

    python -m benchmarks.perf_gate BASELINE.json NEW.json [--threshold 0.10]

Walks each section's ``RESULTS`` export in both files and compares every
numeric value whose key names a higher-is-better performance figure
(``*GBps*``, ``*throughput*``, ``*speedup*``, ``*efficiency*``,
``*goodput*``).  Exits 1
if any figure regressed more than ``threshold`` (default 10%) against the
committed baseline.  Keys or sections present in only one file are skipped
— new benchmarks never fail the gate, and a section that *errored* in the
new run already fails ``run.py`` itself.

The committed baseline is ``BENCH_overlap.json`` (regenerate with
``PYTHONPATH=src python -m benchmarks.run --dry --json BENCH_overlap.json``
after an intentional perf change).
"""
from __future__ import annotations

import argparse
import json
import re
import sys

HIGHER_IS_BETTER = re.compile(r"gbps|throughput|speedup|efficiency|goodput",
                              re.I)


def _walk(node, path=()):
    """Yield (path tuple, numeric leaf) pairs."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, path + (str(k),))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, path + (str(i),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def compare(baseline: dict, new: dict, threshold: float) -> list[str]:
    """Regression messages (empty = gate passes)."""
    base_results = {p: v for p, v in _walk(baseline.get("sections", {}))
                    if "results" in p and HIGHER_IS_BETTER.search(p[-1])}
    new_results = dict(_walk(new.get("sections", {})))
    failures = []
    for path, base_v in sorted(base_results.items()):
        if path not in new_results or base_v <= 0:
            continue
        new_v = new_results[path]
        if new_v < base_v * (1.0 - threshold):
            failures.append(
                f"{'/'.join(path)}: {new_v:.4g} vs baseline {base_v:.4g} "
                f"({(1 - new_v / base_v) * 100:.1f}% regression)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    if bool(baseline.get("dry")) != bool(new.get("dry")):
        print("perf_gate: baseline and new run disagree on --dry; "
              "refusing to compare apples to oranges")
        return 1
    failures = compare(baseline, new, args.threshold)
    n_compared = len([p for p, _ in _walk(baseline.get("sections", {}))
                      if "results" in p and HIGHER_IS_BETTER.search(p[-1])])
    if failures:
        print(f"perf_gate: {len(failures)} modeled-throughput regression(s) "
              f"> {args.threshold*100:.0f}%:")
        for msg in failures:
            print("  " + msg)
        return 1
    print(f"perf_gate: OK ({n_compared} figures within "
          f"{args.threshold*100:.0f}% of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
