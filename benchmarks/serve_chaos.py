"""Serving-under-chaos benchmark: fault-tolerant serving (SLO-aware
shedding + retried/rerouted KV shipping) vs a no-handling baseline on the
CosmoGrid testbed while the amsterdam->tokyo light path drops mid-trace.

Both schedulers run the *same* seeded arrival trace with per-request
deadlines over the same fault schedule:

* **baseline** — no shedding, no retries: each KV ship pays the naive
  wait-out model (`modeled_ship_steps` with the fault clock: a dead hop
  burns the full socket watchdog), and hopeless requests are admitted
  anyway, clogging the serial prefill server until the deadline sweep
  times them out.
* **handling** — SLO-aware admission sheds requests whose modeled
  completion blows their deadline, and a `FaultAwareShipper` reships with
  a short watchdog, reroutes over the tokyo-edinburgh backup after
  ``max_reships``, and falls back to the primary once it heals.

The assertion (and the ``serve_chaos`` section of the perf gate) is that
handling beats baseline on both SLO attainment and goodput; the
``*_goodput*`` / ``*speedup*`` keys feed `benchmarks/perf_gate.py`.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.chaos import IncidentLog
from repro.core.kvship import kv_cache_bytes
from repro.core.serving import (ContinuousBatcher, FaultAwareShipper,
                                modeled_ship_steps)
from repro.core.topology import Fault, cosmogrid_topology
from repro.configs import get_config

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
SEED = 1312
N_REQUESTS = 48 if DRY else 256
MAX_SLOTS = 16
STEP_S = 0.5                     # coarse step: the backup link is slow
MEAN_GAP_STEPS = 3.0
PROMPT_LENS = (32, 64, 128)
OUTPUT_LENS = (4, 8, 16)
DEADLINE_STEPS = 80
DROP_START = 30                  # light path dies while ships are in flight
DROP_STOP = DROP_START + (150 if DRY else 600)

RESULTS: dict = {}


def make_trace(seed: int = SEED, n: int = N_REQUESTS) -> list:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(MEAN_GAP_STEPS, size=n)
    steps = np.floor(np.cumsum(gaps)).astype(int)
    plens = rng.choice(PROMPT_LENS, size=n)
    mnews = rng.choice(OUTPUT_LENS, size=n)
    return [(int(s), int(p), int(m), DEADLINE_STEPS)
            for s, p, m in zip(steps, plens, mnews)]


def _topology():
    topo = cosmogrid_topology(backup_links=True)
    prof = topo.link("amsterdam", "tokyo").with_fault(
        Fault("drop", start=DROP_START, stop=DROP_STOP))
    topo.connect("amsterdam", "tokyo", prof)
    return topo


def _kv_bytes(cfg):
    Dh = cfg.resolved_head_dim

    def kv(req) -> int:
        return kv_cache_bytes(cfg.num_layers, cfg.num_kv_heads, Dh,
                              req.prompt_len)
    return kv


def _prefill_steps(req) -> int:
    return max(1, req.prompt_len // 64)


def run() -> str:
    cfg = get_config("llama3.2-3b")
    kv = _kv_bytes(cfg)
    trace = make_trace()

    # -- baseline: no shedding, no retries, wait-for-heal -------------------
    # a dead hop blocks the ship (TCP hanging on the broken light path)
    # until the link heals, then transfers; no backup route is ever tried
    base_topo = _topology()
    base_route = base_topo.route("amsterdam", "tokyo")
    base_prof = base_route.profiles[0]

    def naive_ship(req, step) -> int:
        at = int(step)
        while not base_prof.health(at).alive and at < step + 100_000:
            at += 1
        return (at - int(step)) + modeled_ship_steps(
            kv(req), step_s=STEP_S, step=at, route=base_route)

    baseline = ContinuousBatcher(
        MAX_SLOTS, N_REQUESTS, prefill_steps=_prefill_steps,
        ship_steps=naive_ship, step_s=STEP_S, shed=False)
    base_stats = baseline.run(trace)

    # -- handling: shed + fault-aware reship/reroute ------------------------
    log = IncidentLog()
    topo = _topology()
    shipper = FaultAwareShipper(
        topo, "amsterdam", "tokyo", kv_bytes=kv, step_s=STEP_S,
        max_reships=1, timeout_s=1.0, log=log, seed=SEED)
    handling = ContinuousBatcher(
        MAX_SLOTS, N_REQUESTS, prefill_steps=_prefill_steps,
        step_s=STEP_S, shed=True, shipper=shipper, log=log,
        prefill_site="amsterdam", decode_site="tokyo")
    hand_stats = handling.run(trace)

    slo_speedup = (hand_stats["slo_attainment"]
                   / max(base_stats["slo_attainment"], 1e-12))
    goodput_speedup = (hand_stats["goodput_tok_s"]
                       / max(base_stats["goodput_tok_s"], 1e-12))
    if hand_stats["slo_attainment"] <= base_stats["slo_attainment"]:
        raise AssertionError(
            f"fault handling must beat the no-handling baseline on SLO "
            f"attainment: {hand_stats['slo_attainment']:.3f} vs "
            f"{base_stats['slo_attainment']:.3f}")
    if hand_stats["goodput_tok_s"] <= base_stats["goodput_tok_s"]:
        raise AssertionError(
            f"fault handling must beat the no-handling baseline on "
            f"goodput: {hand_stats['goodput_tok_s']:.1f} vs "
            f"{base_stats['goodput_tok_s']:.1f} tok/s")

    incidents = log.timeline()
    RESULTS.update({
        "n_requests": N_REQUESTS,
        "drop_window_steps": [DROP_START, DROP_STOP],
        "deadline_steps": DEADLINE_STEPS,
        "chaos_goodput_tok_s": hand_stats["goodput_tok_s"],
        "baseline_goodput_tok_s": base_stats["goodput_tok_s"],
        "chaos_goodput_speedup": goodput_speedup,
        "slo_attainment_speedup": slo_speedup,
        "chaos_slo_attainment": hand_stats["slo_attainment"],
        "baseline_slo_attainment": base_stats["slo_attainment"],
        "completed": hand_stats["completed"],
        "shed": hand_stats["shed"],
        "timed_out": hand_stats["timed_out"],
        "baseline_timed_out": base_stats["timed_out"],
        "reships": hand_stats["reships"],
        "reroutes": hand_stats["reroutes"],
        "incident_rows": len(incidents),
    })

    rows = [
        "| scheduler | SLO attainment | goodput tok/s | completed "
        "| timed out | shed |",
        "|---|---|---|---|---|---|",
    ]
    for name, s in (("fault handling (shed + reship/reroute)", hand_stats),
                    ("no handling (wait-out, no shed)", base_stats)):
        rows.append(
            f"| {name} | {s['slo_attainment']:.3f} "
            f"| {s['goodput_tok_s']:.1f} | {s['completed']} "
            f"| {s['timed_out']} | {s['shed']} |")
    rows.append("")
    rows.append(
        f"Light path down for steps [{DROP_START}, {DROP_STOP}); "
        f"{hand_stats['reships']} reships, {hand_stats['reroutes']} "
        f"reroutes, {len(incidents)} incident rows.  SLO attainment "
        f"{slo_speedup:.2f}x and goodput {goodput_speedup:.2f}x over the "
        f"no-handling baseline (both asserted > 1x).")
    return "\n".join(rows)


if __name__ == "__main__":
    print(run())
