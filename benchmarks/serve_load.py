"""Serving load benchmark: continuous batching vs run-to-completion fixed
batching under a seeded arrival trace, on the emulated London-Poznan WAN.

The trace is Poisson-ish (seeded exponential interarrivals quantized to the
decode step clock) with mixed prompt lengths and a long-tailed output-length
distribution — the regime where continuous batching wins: short requests
drain out of decode slots while a straggler keeps its own slot busy, and
admission refills the freed slots immediately.  The fixed-batch baseline
groups requests into consecutive batches and holds every slot until the
batch's slowest member finishes.

Everything is the deterministic virtual-clock model (`repro.core.serving`):
prefill cost scales with prompt length, and the continuous batcher *also*
pays the WAN KV-ship per request (`modeled_ship_steps` over the real link
model with per-request `kv_cache_bytes`) while the monolithic baseline
ships nothing — the >= 2x goodput claim asserted below holds despite that
handicap.

`benchmarks/run.py --json` exports RESULTS (section `serve_load`); the
``*goodput*`` / ``*speedup*`` keys feed `benchmarks/perf_gate.py`.
"""
from __future__ import annotations

import os

import numpy as np

from repro.configs import CommConfig, get_config
from repro.core.kvship import kv_cache_bytes
from repro.core.path import WAN_LONDON_POZNAN, WidePath
from repro.core.serving import (ContinuousBatcher, FixedBatchScheduler,
                                modeled_ship_steps)

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
SEED = 1312
N_REQUESTS = 64 if DRY else 512
MAX_SLOTS = 8
QUEUE_LIMIT = N_REQUESTS          # measure scheduling, not rejection
STEP_S = 25e-3                    # one decode step on the serving site
MEAN_GAP_STEPS = 2.0              # Poisson arrival intensity
PROMPT_LENS = (32, 64, 128, 256)
OUTPUT_LENS = (4, 8, 16, 96)      # long-tailed: stragglers hold slots
OUTPUT_P = (0.35, 0.30, 0.25, 0.10)

RESULTS: dict = {}


def make_trace(seed: int = SEED, n: int = N_REQUESTS) -> list:
    """Seeded (step, prompt_len, max_new) arrival trace."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(MEAN_GAP_STEPS, size=n)
    steps = np.floor(np.cumsum(gaps)).astype(int)
    plens = rng.choice(PROMPT_LENS, size=n)
    mnews = rng.choice(OUTPUT_LENS, size=n, p=OUTPUT_P)
    return [(int(s), int(p), int(m)) for s, p, m in zip(steps, plens, mnews)]


def _prefill_steps(req) -> int:
    # prompt tokens per decode-step-equivalent of prefill compute
    return max(1, req.prompt_len // 64)


def run() -> str:
    cfg = get_config("llama3.2-3b")
    path = WidePath(axis="pod", comm=CommConfig(streams=16, chunk_mb=0.25),
                    link=WAN_LONDON_POZNAN, name="kvship")
    Dh = cfg.resolved_head_dim

    def ship_steps(req) -> int:
        kv = kv_cache_bytes(cfg.num_layers, cfg.num_kv_heads, Dh,
                            req.prompt_len)
        return modeled_ship_steps(kv, path, STEP_S)

    trace = make_trace()
    cont = ContinuousBatcher(MAX_SLOTS, QUEUE_LIMIT,
                             prefill_steps=_prefill_steps,
                             ship_steps=ship_steps, step_s=STEP_S)
    cont_stats = cont.run(trace)
    fixed = FixedBatchScheduler(MAX_SLOTS, prefill_steps=_prefill_steps,
                                step_s=STEP_S)
    fixed_stats = fixed.run(trace)

    speedup = (cont_stats["goodput_tok_s"]
               / max(fixed_stats["goodput_tok_s"], 1e-12))
    if speedup < 2.0:
        raise AssertionError(
            f"continuous batching goodput speedup {speedup:.2f}x < 2.0x "
            f"over the fixed-batch baseline "
            f"({cont_stats['goodput_tok_s']:.1f} vs "
            f"{fixed_stats['goodput_tok_s']:.1f} tok/s)")

    RESULTS.update({
        "n_requests": N_REQUESTS,
        "max_slots": MAX_SLOTS,
        "step_s": STEP_S,
        "continuous_goodput_tok_s": cont_stats["goodput_tok_s"],
        "fixed_goodput_tok_s": fixed_stats["goodput_tok_s"],
        "goodput_speedup": speedup,
        "latency_p50_s": cont_stats["latency_p50_s"],
        "latency_p99_s": cont_stats["latency_p99_s"],
        "ttft_p50_s": cont_stats["ttft_p50_s"],
        "ttft_p99_s": cont_stats["ttft_p99_s"],
        "completed": cont_stats["completed"],
        "rejected": cont_stats["rejected"],
        "total_tokens": cont_stats["total_tokens"],
    })

    rows = [
        "| scheduler | goodput tok/s | p50 lat | p99 lat | p50 TTFT | p99 TTFT |",
        "|---|---|---|---|---|---|",
    ]
    for name, s in (("continuous (disagg, KV over WAN)", cont_stats),
                    ("fixed batch (monolithic)", fixed_stats)):
        rows.append(
            f"| {name} | {s['goodput_tok_s']:.1f} "
            f"| {s['latency_p50_s']:.2f}s | {s['latency_p99_s']:.2f}s "
            f"| {s['ttft_p50_s']:.2f}s | {s['ttft_p99_s']:.2f}s |")
    rows.append("")
    rows.append(f"Continuous batching goodput speedup: **{speedup:.2f}x** "
                f"(asserted >= 2x) over {N_REQUESTS} seeded requests, "
                f"{MAX_SLOTS} decode slots, KV ship on "
                f"{path.link.name} included.")
    return "\n".join(rows)


if __name__ == "__main__":
    print(run())
