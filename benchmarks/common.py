"""Shared benchmark helpers: WAN link models calibrated to the paper's
endpoints, and a TCP-window-aware throughput model.

The container is CPU-only, so WAN numbers are *modeled* (alpha-beta with
per-stream window caps — the mechanism MPWide exploits) and clearly labeled
as such; multi-device *measured* numbers run real collectives on fake CPU
devices in subprocesses (threads on one host: they validate behaviour and
relative effects, not absolute bandwidth).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from dataclasses import dataclass


@dataclass(frozen=True)
class WanLink:
    """One paper endpoint pair over 'regular internet'."""
    name: str
    rtt_s: float                 # round-trip time
    capacity_Bps: float          # attainable path capacity
    per_stream_window: float     # effective TCP window per stream (bytes)
    paper_scp: tuple = (None, None)     # MB/s each direction (Table 1)
    paper_mpwide: tuple = (None, None)
    paper_zeromq: tuple = (None, None)


# Calibrated to Table 1: capacity ~= observed MPWide throughput (MPWide
# saturates the attainable path); window chosen so 1 stream ~= scp rate.
TABLE1_LINKS = [
    WanLink("London-Poznan", 24e-3, 70e6 * 1.15, 256 << 10,
            (11, 16), (70, 70), (30, 110)),
    WanLink("Poznan-Gdansk", 10e-3, 115e6 * 1.15, 128 << 10,
            (13, 21), (115, 115), (64, None)),
    WanLink("Poznan-Amsterdam", 18e-3, 55e6 * 1.15, 256 << 10,
            (32, 9.1), (55, 55), None),
]

UCL_HECTOR_RTT = 11e-3           # bloodflow coupling round-trip


def stream_throughput(link: WanLink, streams: int, efficiency: float = 1.0
                      ) -> float:
    """Bytes/s for `streams` parallel windows over one path.

    Each stream is capped at window/RTT (the TCP bandwidth-delay-product
    limit MPWide's multi-stream paths evade); the path is capped at its
    capacity.  `efficiency` models per-tool overhead (scp crypto ~0.7).
    """
    per_stream = link.per_stream_window / link.rtt_s
    return min(link.capacity_Bps, streams * per_stream) * efficiency


def run_multidev(script: str, ndev: int = 8, timeout: int = 600) -> dict:
    """Run a python snippet under N fake CPU devices; it must print one JSON
    line starting with RESULT:."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], env=env, text=True,
                         capture_output=True, timeout=timeout, cwd=_repo_root())
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise RuntimeError(f"no RESULT in output:\n{out.stdout[-2000:]}\n{out.stderr[-2000:]}")


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fmt_mbs(x) -> str:
    return "-" if x is None else f"{x/1e6:.0f}" if x > 1e4 else f"{x:.0f}"
