"""Ring vs gather collectives — the bandwidth-optimal WAN stage.

  (a) MODELED: per-pod wire bytes and cross-pod throughput for the
      gather-based compressed all-reduce (`algo="psum"` + bf16/int8: every
      pod receives P-1 remote shards, linear in P) vs the ring
      reduce-scatter + all-gather (`algo="ring"`: 2(P-1)/P, the bandwidth
      lower bound), swept over P in {2,4,8} x compress in {none,bf16,int8}.
      Throughput is bandwidth-model (payload / (wire/bw)): under chunk
      pipelining the per-hop alphas of successive chunks overlap, so
      bandwidth is what the slow link exposes.
  (b) MEASURED (fake CPU devices): ring/ring2 numerics vs psum on a real
      4-pod collective, with the per-algorithm traffic plans (modeled wire
      bytes included) pulled from telemetry.

Acceptance (asserted below): int8 ring moves <= 2(P-1)/P * n/4 bytes per
pod, and models >=2x the gather path's cross-pod throughput at P=4 (>=4x
at P=8 — the ratio is P/2).

Set WIDEJAX_BENCH_DRY=1 (benchmarks/run.py --dry) for a tiny payload.
`benchmarks/run.py --json` exports RESULTS (modeled GB/s + wire bytes) for
cross-PR perf tracking.
"""
from __future__ import annotations

import os

from benchmarks.common import run_multidev
from repro.core.path import WAN_LONDON_POZNAN
from repro.core.ring import wire_bytes_per_pod

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
PAYLOAD = (1 << 16) if DRY else (64 << 20)   # f32 gradient bytes per pod

# machine-readable section results, exported by benchmarks/run.py --json
RESULTS: dict = {}


def modeled() -> str:
    link = WAN_LONDON_POZNAN
    bw = link.bandwidth_Bps
    rows = ["| P | compress | gather wire/pod | ring wire/pod | "
            "gather GB/s | ring GB/s | ring speedup |",
            "|---|---|---|---|---|---|---|"]
    RESULTS["modeled"] = []
    for P in (2, 4, 8):
        for compress in ("none", "bf16", "int8"):
            wg = wire_bytes_per_pod(PAYLOAD, P, algo="psum",
                                    compress=compress)
            wr = wire_bytes_per_pod(PAYLOAD, P, algo="ring",
                                    compress=compress)
            tg, tr = PAYLOAD / (wg / bw), PAYLOAD / (wr / bw)
            speedup = wg / wr
            rows.append(
                f"| {P} | {compress} | {wg / (1 << 20):.2f} MiB "
                f"| {wr / (1 << 20):.2f} MiB | {tg / 1e9:.3f} | {tr / 1e9:.3f} "
                f"| {speedup:.1f}x |")
            RESULTS["modeled"].append(dict(
                P=P, compress=compress, payload_bytes=PAYLOAD,
                gather_wire_bytes=wg, ring_wire_bytes=wr,
                gather_GBps=tg / 1e9, ring_GBps=tr / 1e9, speedup=speedup))
            # acceptance: the int8 ring is bandwidth-optimal and beats the
            # gather path by P/2 (>=2x at P=4, >=4x at P=8)
            if compress == "int8":
                assert wr <= 2 * (P - 1) / P * PAYLOAD / 4 + 1e-9, (P, wr)
                assert speedup >= P / 2 - 1e-9, (P, speedup)
    return "\n".join(rows + [
        "",
        f"Payload {PAYLOAD / (1 << 20):.2f} MiB f32 per pod over "
        f"{link.name} ({bw / 1e6:.0f} MB/s).  The gather-based compressed "
        "path receives P-1 remote shards per pod — wire bytes grow linearly "
        "in P and *cancel the compression win* by P=8 (7/4 > 1): compression "
        "plus gather can move MORE bytes than an uncompressed ring.  The "
        "ring stays at the 2(P-1)/P bound at every P, so int8-on-the-wire "
        "keeps its full 4x; `ring2` moves the same bytes in half the "
        "latency-step depth.  (int8 scale sideband: +4/256 = +1.6%, "
        "excluded from the model like headers.)",
    ])


_MEASURE = r"""
import json, os, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, streamed_psum, get_telemetry
from repro.configs.base import CommConfig

dry = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
N = ((1 << 16) if dry else (16 << 20)) // 4
mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
payload = {"g": (jnp.arange(N, dtype=jnp.float32) % 1000) / 1000.0 + 0.5}
out = {}
for algo in ("psum", "ring", "ring2"):
    for compress in ("none", "int8"):
        comm = CommConfig(streams=4, chunk_mb=max(0.0625, N * 4 / 4 / 2**20),
                          compress=compress, algo=algo)
        path = WidePath(axis="pod", comm=comm, name=f"rvg-{algo}-{compress}")
        def body(t):
            r = jax.lax.axis_index("pod").astype(jnp.float32)
            return streamed_psum(jax.tree.map(lambda x: x * (1 + r), t),
                                 path, dims={"g": 0})
        fn = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                                   out_specs=P(), axis_names={"pod"},
                                   check_vma=False))
        with jax.set_mesh(mesh):
            got = fn(payload); jax.block_until_ready(got)
            t0 = time.perf_counter()
            got = fn(payload); jax.block_until_ready(got)
            dt = time.perf_counter() - t0
        want = payload["g"] * 10.0
        err = float(jnp.max(jnp.abs(got["g"] - want) / want))
        plan = get_telemetry().path(path.key).plan
        out[f"{algo}/{compress}"] = {
            "err": err, "wall_s": dt, "n_chunks": plan.n_chunks,
            "payload_bytes": plan.payload_bytes,
            "wire_bytes": plan.wire_bytes, "algo": plan.algo}
print("RESULT:" + json.dumps(out))
"""


def measured() -> tuple[str, dict]:
    res = run_multidev(_MEASURE, ndev=8, timeout=900)
    for key, r in res.items():
        tol = 0.08 if "int8" in key else 1e-5
        assert r["err"] < tol, (key, r)          # numerics match psum's sum
    base = res["psum/none"]["wire_bytes"]
    rows = ["| algo | compress | modeled wire/pod | vs psum/none | "
            "rel err | wall (CPU devs) |",
            "|---|---|---|---|---|---|"]
    for key, r in res.items():
        rows.append(f"| {key.split('/')[0]} | {key.split('/')[1]} "
                    f"| {r['wire_bytes'] / (1 << 20):.3f} MiB "
                    f"| {r['wire_bytes'] / base:.2f}x | {r['err']:.1e} "
                    f"| {r['wall_s'] * 1e3:.1f} ms |")
    ratio = res["psum/int8"]["wire_bytes"] / res["ring/int8"]["wire_bytes"]
    assert ratio >= 2.0 - 1e-9, ratio            # acceptance at P=4
    rows += [
        "",
        f"All six engines produce the same global sum (int8 within "
        f"requantization tolerance); the int8 ring plans "
        f"**{ratio:.1f}x fewer wire bytes** than the int8 gather at P=4. "
        "CPU wall times validate numerics, not WAN bandwidth.",
    ]
    return "\n".join(rows), res


def run() -> str:
    measured_md, res = measured()
    RESULTS["measured"] = res
    return "\n".join([
        "## Ring vs gather — bandwidth-optimal WAN collectives "
        "(int8 on the wire at every hop)", "",
        "### Modeled (per-pod wire bytes & throughput, London-Poznan)", "",
        modeled(), "",
        "### Measured (real collectives, 8 fake CPU devices, P=4)", "",
        measured_md, "",
    ])


if __name__ == "__main__":
    print(run())
