"""Multi-hop relay & forwarder routing — the paper's 2->3->4-supercomputer
scaling (CosmoGrid, arXiv:1101.0605), reproduced over the topology subsystem.

  (a) MODELED: a CosmoGrid-style heterogeneous chain; store-and-forward relay
      time and effective end-to-end bandwidth as the run spans 2, 3, then 4
      sites; route planning (fastest vs widest) on the 4-site star where
      Tokyo<->Espoo has *no direct link* (the Forwarder scenario).
  (b) MEASURED (fake CPU devices): a >=2-hop Forwarder route executed with
      real collectives (numerics must match a direct shift), and the
      site-hierarchical cross-site psum vs the flat single-path baseline —
      with per-hop traffic plans pulled from telemetry and the per-hop
      MPW.Report() table.

Slow-hop byte accounting (the acceptance metric): a ring all-reduce among n
WAN participants moves 2(n-1)B bytes over the slow links in total.  Flat,
every pod is a WAN participant (n = P); site-hierarchical, the intra-site
reduction leaves one gateway per site (n = S < P) — the reduction a flat
psum cannot express.  (The measured collective executes a full-axis psum
with non-gateway contributions masked to zero; a real Forwarder deployment
simply never opens WAN sockets on non-gateway hosts.)

Set WIDEJAX_BENCH_DRY=1 (benchmarks/run.py --dry) for a tiny payload.
"""
from __future__ import annotations

import os

from benchmarks.common import run_multidev
from repro.core.topology import LinkProfile, Topology, cosmogrid_topology

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
PAYLOAD = (1 << 16) if DRY else (16 << 20)   # per-pod gradient bytes


def chain_topology() -> Topology:
    """A 4-site relay chain with heterogeneous hops: the CosmoGrid layout as
    a line amsterdam -> espoo -> edinburgh -> tokyo (each leg a different
    alpha/beta/window), for the 2->3->4-site scaling table."""
    t = Topology()
    for name in ("amsterdam", "espoo", "edinburgh", "tokyo"):
        t.add_site(name)
    t.connect("amsterdam", "espoo",
              LinkProfile("ams-espoo", 22e-3, 115e6, window=64 << 10,
                          streams=64))
    t.connect("espoo", "edinburgh",
              LinkProfile("espoo-edi", 18e-3, 90e6, window=64 << 10,
                          streams=64))
    t.connect("edinburgh", "tokyo",
              LinkProfile("edi-tokyo", 130e-3, 70e6, window=128 << 10,
                          streams=128))
    return t


def modeled() -> str:
    t = chain_topology()
    rows = ["| run spans | route | hops | relay time (16 MiB) | effective MB/s |",
            "|---|---|---|---|---|"]
    nbytes = 16 << 20
    for dst, nsites in (("espoo", 2), ("edinburgh", 3), ("tokyo", 4)):
        r = t.route("amsterdam", dst)
        s = r.modeled_s(nbytes)
        rows.append(f"| {nsites} sites | {r.describe()} | {r.n_hops} "
                    f"| {s*1e3:.0f} ms | {nbytes/s/1e6:.0f} |")
    star = cosmogrid_topology()
    fast = star.route("tokyo", "espoo", metric="latency")
    wide = star.route("tokyo", "espoo", metric="width")
    return "\n".join(rows + [
        "",
        "Store-and-forward: each relay holds the full message, so hops add — "
        "the 4-site chain pays every leg's alpha and its bottleneck's beta, "
        "exactly how the paper's 4-machine runs composed.",
        "",
        "Route planning on the 4-site star (no Tokyo<->Espoo link):",
        f"* fastest (min alpha): `{fast.describe()}`",
        f"* widest (max bottleneck bw): `{wide.describe()}`",
    ])


_MEASURE = r"""
import json, os
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.base import CommConfig
from repro.core import (MPW, Topology, LinkProfile, WidePath, streamed_psum,
                        get_telemetry)

dry = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
N = ((1 << 16) if dry else (16 << 20)) // 4
mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)

# two sites x two pods over the pod axis; one slow WAN hop between them
topo = Topology()
topo.add_site("amsterdam", n_pods=2)
topo.add_site("tokyo", n_pods=2)
topo.connect("amsterdam", "tokyo",
             LinkProfile("ams-tokyo", 135e-3, 1.25e9, window=4 << 20,
                         streams=16, chunk_mb=4.0))
groups = topo.pod_groups()
out = {"groups": groups}

mpw = MPW.Init()

# (1) >=2-hop forwarder route: relay around the 4-pod ring via 2 single-pod
# relays and check numerics against a direct 3-shift
star = Topology()
for n in ("a", "b", "c", "d"):
    star.add_site(n)
for x, y in (("a", "b"), ("b", "c"), ("c", "d")):
    star.connect(x, y, LinkProfile(f"{x}-{y}", 20e-3, 100e6, streams=32))
pid_fwd = mpw.CreateForwarder(star, "a", "d")
out["fwd_hops"] = len(mpw.path(pid_fwd).route)

def relay_body(x):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    got = mpw.Forward(pid_fwd, {"v": x + me})
    return got["v"]
f = jax.jit(jax.shard_map(relay_body, mesh=mesh, in_specs=(P(),),
                          out_specs=P("pod"), axis_names={"pod"},
                          check_vma=False))
with jax.set_mesh(mesh):
    r = f(jnp.zeros((4, 2)))
out["relay"] = [float(r[4 * i, 0]) for i in range(4)]

# (2) flat vs site-hierarchical cross-site psum of the same payload
flat_path = WidePath(axis="pod", name="flat",
                     comm=CommConfig(streams=16, chunk_mb=4.0))
hier_path = WidePath(axis="pod", name="hier",
                     comm=CommConfig(streams=16, chunk_mb=4.0))
payload = {"g": jnp.ones((N,), jnp.float32)}

def flat_body(t):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    return streamed_psum(jax.tree.map(lambda x: x * (1 + me), t), flat_path,
                         dims={"g": 0})
def hier_body(t):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    return streamed_psum(jax.tree.map(lambda x: x * (1 + me), t), hier_path,
                         dims={"g": 0}, site_groups=groups)
import time
for name, body in (("flat", flat_body), ("hier", hier_body)):
    fn = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                               out_specs=P(), axis_names={"pod"},
                               check_vma=False))
    with jax.set_mesh(mesh):
        got = fn(payload); jax.block_until_ready(got)
        t0 = time.perf_counter()
        got = fn(payload); jax.block_until_ready(got)
        dt = time.perf_counter() - t0
    out[f"{name}_val"] = float(got["g"][0])          # expect 1+2+3+4 = 10
    out[f"{name}_wall_s"] = dt
    get_telemetry().record(f"{name}:interpod" if name == "flat"
                           else "hier:interpod/wan", dt, nbytes=N * 4)

rep = mpw.Report()
out["plans"] = {k: v.get("plan") for k, v in rep.items()}
out["report_md"] = mpw.Report(formatted=True)
print("RESULT:" + json.dumps(out))
"""


def measured() -> tuple[str, dict]:
    res = run_multidev(_MEASURE, ndev=8, timeout=900)
    assert res["fwd_hops"] >= 2, "route must be a >=2-hop forwarder chain"
    assert res["relay"] == [1.0, 2.0, 3.0, 0.0], res["relay"]  # 3-hop shift
    assert res["flat_val"] == res["hier_val"] == 10.0, res

    n_pods = sum(len(g) for g in res["groups"])
    n_sites = len(res["groups"])
    B = res["plans"]["flat:interpod"]["payload_bytes"]
    flat_wan = 2 * (n_pods - 1) * B
    hier_wan = 2 * (n_sites - 1) * res["plans"]["hier:interpod/wan"]["payload_bytes"]
    ratio = flat_wan / hier_wan
    assert hier_wan < flat_wan, (hier_wan, flat_wan)

    rows = [
        f"4-pod ring as {n_sites} sites x {n_pods // n_sites} pods; per-pod "
        f"payload {B / (1 << 20):.2f} MiB; both engines reduce to the same "
        f"global sum (checked: {res['flat_val']:.0f}).",
        "",
        "| engine | WAN participants | slow-hop bytes (ring, 2(n-1)B) | wall (CPU devs) |",
        "|---|---|---|---|",
        f"| flat single-path psum | {n_pods} pods | {flat_wan / (1 << 20):.2f} MiB "
        f"| {res['flat_wall_s']*1e3:.1f} ms |",
        f"| site-hierarchical psum | {n_sites} gateways | {hier_wan / (1 << 20):.2f} MiB "
        f"| {res['hier_wall_s']*1e3:.1f} ms |",
        "",
        f"**{ratio:.1f}x fewer slow-hop bytes** with the intra-site "
        "reduction in front of the WAN crossing (CPU wall times validate "
        "numerics, not WAN bandwidth).",
        "",
        f"Forwarder route a->d resolved to {res['fwd_hops']} hops; relayed "
        "values match a direct 3-shift around the ring.",
        "",
        "### Per-hop telemetry (MPW.Report)",
        "",
        res["report_md"],
    ]
    return "\n".join(rows), res


def run() -> str:
    measured_md, _ = measured()
    return "\n".join([
        "## Multi-hop relay — topology routing & the Forwarder "
        "(paper's 2->3->4-site scaling)", "",
        "### Modeled (heterogeneous CosmoGrid-style chain)", "",
        modeled(), "",
        "### Measured (real collectives, 8 fake CPU devices)", "",
        measured_md, "",
    ])


if __name__ == "__main__":
    print(run())
