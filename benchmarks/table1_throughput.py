"""Paper Table 1: WAN throughput — scp vs MPWide vs ZeroMQ between London,
Poznan, Gdansk, Amsterdam.

Reproduction on a CPU-only container has two halves:
  (a) MODELED: the TCP-window/alpha-beta mechanism (a single stream is capped
      at window/RTT; MPWide's S parallel streams evade the cap until path
      capacity) against the paper's measured numbers.
  (b) MEASURED: real collectives moving the paper's 64 MB payload across
      "pods" of fake CPU devices for each transfer engine (flat single-op,
      MPWide streamed path, gateway Forwarder) — validating behaviour and
      relative op structure, not absolute WAN bandwidth.
"""
from __future__ import annotations

import os

from benchmarks.common import TABLE1_LINKS, fmt_mbs, run_multidev, stream_throughput

PAYLOAD_MB = 64   # paper: "we exchanged 64MB of data"
if os.environ.get("WIDEJAX_BENCH_DRY"):
    PAYLOAD_MB = 2   # smoke mode: validate op structure, not bandwidth


def modeled_table() -> str:
    rows = []
    rows.append("| endpoints | tool | paper MB/s | modeled MB/s |")
    rows.append("|---|---|---|---|")
    for link in TABLE1_LINKS:
        # scp: one stream + crypto overhead
        scp = stream_throughput(link, 1, efficiency=0.7) / 1e6
        # MPWide: 32 streams (paper's WAN guidance), negligible overhead
        mpw = stream_throughput(link, 32) / 1e6
        # ZeroMQ: single connection, default autotuned window (larger than
        # scp's, no crypto): modeled as one stream with a 4x window
        zmq = min(link.capacity_Bps,
                  4 * link.per_stream_window / link.rtt_s) / 1e6
        rows.append(f"| {link.name} | scp | {link.paper_scp[0]}/{link.paper_scp[1]} "
                    f"| {scp:.0f} |")
        rows.append(f"| {link.name} | **MPWide** | {link.paper_mpwide[0]}/"
                    f"{link.paper_mpwide[1]} | {mpw:.0f} |")
        if link.paper_zeromq:
            z0 = link.paper_zeromq[0]
            z1 = link.paper_zeromq[1] if link.paper_zeromq[1] else "-"
            rows.append(f"| {link.name} | ZeroMQ | {z0}/{z1} | {zmq:.0f} |")
    return "\n".join(rows)


_MEASURE_SNIPPET = r"""
import time, json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, wide_allreduce
from repro.configs.base import CommConfig
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
N = {nbytes} // 4
payload = {{"g": jnp.ones((N,), jnp.float32)}}
out = {{}}
for mode, streams in [("flat",1),("hierarchical",1),("hierarchical",32),
                      ("gateway",32)]:
    comm = CommConfig(mode=mode, streams=streams, chunk_mb=2.0)
    path = WidePath(axis="pod", comm=comm)
    def body(t):
        return wide_allreduce(t, path, data_axes=("data",), dims={{"g":0}})
    f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                axis_names={{"pod","data"}}, check_vma=False))
    with jax.set_mesh(mesh):
        r = f(payload); jax.block_until_ready(r)      # compile+warm
        t0 = time.perf_counter()
        for _ in range(3):
            r = f(payload)
        jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / 3
    out[f"{{mode}}/s{{streams}}"] = dt
print("RESULT:" + json.dumps(out))
"""


def measured_table(nbytes: int = PAYLOAD_MB << 20) -> str:
    res = run_multidev(_MEASURE_SNIPPET.format(nbytes=nbytes))
    rows = [f"| engine | wall time ({PAYLOAD_MB}MB allreduce, 8 fake CPU devs) |",
            "|---|---|"]
    for k, v in res.items():
        rows.append(f"| {k} | {v*1e3:.1f} ms |")
    return "\n".join(rows)


def run() -> str:
    parts = ["## Table 1 — WAN throughput (scp vs MPWide vs ZeroMQ)", "",
             "### Modeled (TCP-window mechanism, paper's endpoints)", "",
             modeled_table(), "",
             "MPWide's multi-stream paths saturate path capacity where a "
             "single window-capped stream (scp) cannot — the paper's 5-6x "
             "gain on London-Poznan reproduces as the window/RTT cap.", "",
             "### Measured (real collectives, CPU fake devices)", "",
             measured_table(), ""]
    return "\n".join(parts)


if __name__ == "__main__":
    print(run())
