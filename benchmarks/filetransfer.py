"""File transfer over WidePath (mpw-cp) — streams x compression x hops.

  (a) MODELED sweep on the London-Poznan WAN link: one real file is shipped
      through the FileTransfer engine under every (streams, chunking,
      compression) config; *bytes* are the real post-zlib wire bytes, and
      *seconds* are the engine's modeled link time (alpha-beta with
      per-stream TCP-window caps — the regime the paper's mpw-cp tunes).
      The scp-style baseline is 1 stream x whole-file: one TCP window's
      worth of in-flight data, exactly the paper's Table-1 scp rates.
  (b) 2-HOP route (CosmoGrid star, tokyo -> espoo via amsterdam): the same
      file relays store-and-forward through the Forwarder route via
      `MPW.FileCopy`, including an interrupt + resume pass — the per-hop
      wire bytes and the chunks *not* re-sent are read back from telemetry
      and the FileResult.

Acceptance (asserted below): multi-stream chunked transfer models >=2x the
single-stream whole-file throughput on the simulated WAN link, and the
2-hop copy round-trips bit-exact with a resume that re-sends no completed
chunk.

Set WIDEJAX_BENCH_DRY=1 (benchmarks/run.py --dry) for a tiny payload.
`benchmarks/run.py --json` exports RESULTS for cross-PR perf tracking.
"""
from __future__ import annotations

import os
import tempfile

from repro.configs.base import CommConfig
from repro.core import MPW, FileTransfer, WidePath, file_sha256
from repro.core.path import WAN_LONDON_POZNAN
from repro.core.topology import cosmogrid_topology

DRY = bool(os.environ.get("WIDEJAX_BENCH_DRY"))
PAYLOAD = (256 << 10) if DRY else (8 << 20)
CHUNK_MB = 0.0625 if DRY else 1.0

# machine-readable section results, exported by benchmarks/run.py --json
RESULTS: dict = {}


def _make_file(d: str) -> str:
    """Half incompressible, half text-like — so zlib shows a real ratio."""
    import random
    random.seed(7)
    src = os.path.join(d, "payload.bin")
    rnd = bytes(random.getrandbits(8) for _ in range(PAYLOAD // 2))
    txt = (b"step=%08d loss=0.123456 gnorm=1.000\n" *
           (PAYLOAD // 2 // 36 + 1))[:PAYLOAD - len(rnd)]
    with open(src, "wb") as f:
        f.write(rnd + txt)
    return src


def sweep(src: str, d: str) -> str:
    link = WAN_LONDON_POZNAN
    configs = [("scp-style (1 stream, whole file)", 1, PAYLOAD / (1 << 20), "none")]
    for streams in (1, 8, 32):
        for compress in ("none", "zlib"):
            configs.append((f"{streams} streams, chunked, {compress}",
                            streams, CHUNK_MB, compress))
    rows = ["| config | wire | modeled time | modeled MB/s | vs scp-style |",
            "|---|---|---|---|---|"]
    RESULTS["sweep"] = []
    base_tput = None
    tuned_tput = 0.0
    for i, (label, streams, chunk_mb, compress) in enumerate(configs):
        path = WidePath(axis="pod", link=link, name=f"ftbench{i}",
                        comm=CommConfig(streams=streams, chunk_mb=chunk_mb,
                                        compress=("int8" if compress == "zlib"
                                                  else "none")))
        eng = FileTransfer(path, record=False)
        dst = os.path.join(d, f"out{i}.bin")
        res = eng.copy(src, dst, resume=False)
        assert file_sha256(dst) == file_sha256(src), label  # bit-exact
        tput = res.nbytes / res.modeled_s
        if base_tput is None:
            base_tput = tput
        if streams == 32 and compress == "none":
            tuned_tput = tput
        rows.append(f"| {label} | {res.wire_bytes / (1 << 20):.2f} MiB "
                    f"| {res.modeled_s * 1e3:.0f} ms | {tput / 1e6:.1f} "
                    f"| {tput / base_tput:.1f}x |")
        RESULTS["sweep"].append(dict(
            label=label, streams=streams, chunk_mb=chunk_mb,
            compress=compress, wire_bytes=res.wire_bytes,
            modeled_s=res.modeled_s, MBps=tput / 1e6,
            speedup=tput / base_tput))
    # acceptance: multi-stream chunked beats single-stream whole-file >=2x
    assert tuned_tput >= 2.0 * base_tput, (tuned_tput, base_tput)
    RESULTS["chunked_multistream_speedup"] = tuned_tput / base_tput
    return "\n".join(rows + [
        "",
        f"Payload {PAYLOAD / (1 << 20):.2f} MiB over {link.name} "
        f"({link.bandwidth_Bps / 1e6:.0f} MB/s capacity, "
        f"{link.window / 1024:.0f} KiB per-stream window, "
        f"{link.latency_s * 1e3:.0f} ms one-way).  One stream moves at most "
        "window/RTT regardless of chunking — the scp regime; parallel "
        "streams stack windows until the path capacity caps them (the "
        "paper's >=32-stream guidance).  zlib wire bytes are measured on "
        "the real file; times are modeled (no real WAN in CI).",
    ])


def two_hop(src: str, d: str) -> str:
    topo = cosmogrid_topology()
    mpw = MPW.Init()
    pid = mpw.CreateForwarder(topo, "tokyo", "espoo")
    # the route profiles default to 8-16 MiB chunks; shrink so the payload
    # is a genuinely multi-chunk transfer (the resume demo needs chunks)
    mpw.setChunkSize(pid, int(CHUNK_MB * (1 << 20)))

    dst = os.path.join(d, "shipped.bin")
    res = mpw.FileCopy(pid, src, dst)
    assert file_sha256(dst) == file_sha256(src)        # bit-exact end to end

    # interrupt a fresh transfer after ~half the chunks, then resume
    class Interrupt(RuntimeError):
        pass

    eng = FileTransfer(mpw.path(pid))
    shipped = []

    def interrupter(chunk, hop, payload):
        if len(shipped) >= res.n_chunks // 2 and chunk.leaf not in shipped:
            raise Interrupt()
        if hop == eng.path.n_hops - 1:
            shipped.append(chunk.leaf)
        return payload

    eng.fault_hook = interrupter
    dst2 = os.path.join(d, "resumed.bin")
    interrupted = False
    try:
        eng.copy(src, dst2)
    except Interrupt:
        interrupted = True
    eng.fault_hook = None
    resumed = eng.copy(src, dst2)                      # picks up the sidecar
    assert file_sha256(dst2) == file_sha256(src)
    assert not interrupted or resumed.skipped >= res.n_chunks // 2, resumed

    hops = mpw.PathStats(pid)["hops"]
    rows = ["| leg | transfers | wire bytes | modeled mean |",
            "|---|---|---|---|"]
    for h in hops:
        rows.append(f"| {h['key'].split('/')[-1]} | {h['transfers']} "
                    f"| {h['total_bytes'] / (1 << 20):.2f} MiB "
                    f"| {h['window_mean_s'] * 1e3:.0f} ms |")
    RESULTS["two_hop"] = dict(
        n_chunks=res.n_chunks, wire_bytes=res.wire_bytes,
        modeled_s=res.modeled_s, resume_skipped=resumed.skipped,
        resume_sent=resumed.sent,
        hop_wire_bytes=[h["total_bytes"] for h in hops])
    mpw.Finalize()
    return "\n".join(rows + [
        "",
        f"tokyo -> espoo has no direct link: {res.n_chunks} chunks relayed "
        "store-and-forward via amsterdam (per-hop wire bytes above; hops "
        "add, per the Forwarder's receive/send buffer pair).  The "
        f"interrupted transfer resumed with {resumed.skipped} chunks "
        f"skipped and {resumed.sent} re-sent — the sidecar manifest is the "
        "restart state.",
    ])


def run() -> str:
    with tempfile.TemporaryDirectory() as d:
        src = _make_file(d)
        sweep_md = sweep(src, d)
        hop_md = two_hop(src, d)
    return "\n".join([
        "## File transfer over WidePath — mpw-cp / DataGather", "",
        "### Modeled streams x compression sweep (London-Poznan)", "",
        sweep_md, "",
        "### 2-hop Forwarder route (CosmoGrid star) with resume", "",
        hop_md, "",
    ])


if __name__ == "__main__":
    print(run())
