"""Benchmark driver: one section per paper table/figure + the roofline
report.  ``PYTHONPATH=src python -m benchmarks.run``"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig1,bloodflow,streams,autotune,roofline")
    args = ap.parse_args()
    sections = {
        "table1": ("benchmarks.table1_throughput", "Table 1 WAN throughput"),
        "fig1": ("benchmarks.fig1_steptime", "Fig 1 distributed overhead"),
        "bloodflow": ("benchmarks.overlap_bloodflow", "bloodflow latency hiding"),
        "streams": ("benchmarks.streams_sweep", "streams sweep"),
        "autotune": ("benchmarks.autotune_convergence", "online autotune convergence"),
        "roofline": ("benchmarks.roofline_report", "roofline report"),
    }
    chosen = args.only.split(",") if args.only else list(sections)
    failures = 0
    print("# WideJAX benchmarks (MPWide reproduction)\n")
    for name in chosen:
        mod_name, desc = sections[name]
        t0 = time.time()
        print(f"\n<!-- section {name}: {desc} -->\n")
        try:
            mod = __import__(mod_name, fromlist=["run"])
            print(mod.run())
            print(f"_({name} completed in {time.time()-t0:.0f}s)_")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"SECTION {name} FAILED:")
            traceback.print_exc(file=sys.stdout)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
