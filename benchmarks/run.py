"""Benchmark driver: one section per paper table/figure + the roofline
report.  ``PYTHONPATH=src python -m benchmarks.run``

``--dry`` runs every section in tiny/smoke mode (exported to sections as
WIDEJAX_BENCH_DRY=1: shrunk payloads and iteration counts) — the CI smoke
job uses it to catch benchmark drift at PR time without WAN-scale runtimes.

``--json PATH`` additionally writes machine-readable results: per-section
status/runtime plus whatever structured numbers a section exports via a
module-level ``RESULTS`` dict (modeled GB/s, wire bytes, ...) — the
cross-PR perf trajectory file (e.g. ``--json BENCH_3.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig1,bloodflow,overlap,streams,"
                         "autotune,multihop,ring,filetransfer,"
                         "chaos_recovery,elastic,serve_load,serve_chaos,roofline")
    ap.add_argument("--dry", action="store_true",
                    help="tiny payloads / few iterations (CI smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-section machine-readable results "
                         "(status, seconds, section RESULTS exports)")
    args = ap.parse_args()
    if args.dry:
        # sections and their multidev subprocesses read this
        os.environ["WIDEJAX_BENCH_DRY"] = "1"
    sections = {
        "table1": ("benchmarks.table1_throughput", "Table 1 WAN throughput"),
        "fig1": ("benchmarks.fig1_steptime", "Fig 1 distributed overhead"),
        "bloodflow": ("benchmarks.overlap_bloodflow", "bloodflow latency hiding"),
        "overlap": ("benchmarks.overlap_efficiency",
                    "bucketed backward overlap efficiency"),
        "streams": ("benchmarks.streams_sweep", "streams sweep"),
        "autotune": ("benchmarks.autotune_convergence", "online autotune convergence"),
        "multihop": ("benchmarks.multihop_relay", "multi-hop relay & forwarder routing"),
        "ring": ("benchmarks.ring_vs_gather", "ring vs gather collectives"),
        "filetransfer": ("benchmarks.filetransfer",
                         "WAN file transfer (mpw-cp) over WidePath"),
        "chaos_recovery": ("benchmarks.chaos_recovery",
                           "chaos detection & recovery latency"),
        "elastic": ("benchmarks.elastic_resize",
                    "local-SGD K-curve & elastic world resize"),
        "serve_load": ("benchmarks.serve_load",
                       "continuous-batching serving load vs fixed batches"),
        "serve_chaos": ("benchmarks.serve_chaos",
                        "fault-tolerant serving vs no-handling baseline "
                        "under a light-path drop"),
        "roofline": ("benchmarks.roofline_report", "roofline report"),
    }
    chosen = args.only.split(",") if args.only else list(sections)
    failures = 0
    report: dict = {"dry": bool(args.dry), "sections": {}}
    print("# WideJAX benchmarks (MPWide reproduction)"
          + (" — DRY/smoke mode" if args.dry else "") + "\n")
    for name in chosen:
        mod_name, desc = sections[name]
        t0 = time.time()
        print(f"\n<!-- section {name}: {desc} -->\n")
        entry: dict = {"description": desc, "ok": False}
        try:
            mod = __import__(mod_name, fromlist=["run"])
            print(mod.run())
            entry["ok"] = True
            print(f"_({name} completed in {time.time()-t0:.0f}s)_")
        except Exception as e:  # noqa: BLE001
            failures += 1
            entry["error"] = f"{type(e).__name__}: {e}"
            print(f"SECTION {name} FAILED:")
            traceback.print_exc(file=sys.stdout)
        entry["seconds"] = round(time.time() - t0, 3)
        results = getattr(sys.modules.get(mod_name), "RESULTS", None)
        if results:
            entry["results"] = results
        report["sections"][name] = entry
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=float)
        print(f"\n_(machine-readable results written to {args.json})_")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
