"""Shared fixtures.

NOTE: XLA_FLAGS / device counts are NOT set here (smoke tests must see the
real single CPU device).  Multi-device tests run in subprocesses via
`multidev` below.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- hypothesis degradation ---------------------------------------------------
# When hypothesis is missing (clean env), property tests must *skip*, not
# break collection.  Test modules fall back to these stand-ins:
#     try: from hypothesis import given, ...
#     except ImportError: from conftest import given, st
def given(*_args, **_kwargs):
    """Stand-in @given: marks the test skipped (hypothesis not installed)."""

    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)

    return deco


class _AnyStrategy:
    """Stand-in for hypothesis.strategies: accepts any strategy call."""

    def __getattr__(self, name):
        return lambda *a, **kw: None


st = _AnyStrategy()


@pytest.fixture(scope="session")
def multidev():
    """Run a snippet under N fake CPU devices; returns parsed RESULT json."""

    def run(script: str, ndev: int = 8, timeout: int = 900) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             text=True, capture_output=True, timeout=timeout,
                             cwd=REPO)
        for line in out.stdout.splitlines():
            if line.startswith("RESULT:"):
                return json.loads(line[len("RESULT:"):])
        raise AssertionError(
            f"no RESULT line (rc={out.returncode}):\n"
            f"STDOUT:\n{out.stdout[-3000:]}\nSTDERR:\n{out.stderr[-3000:]}")

    return run
