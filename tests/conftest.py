"""Shared fixtures.

NOTE: XLA_FLAGS / device counts are NOT set here (smoke tests must see the
real single CPU device).  Multi-device tests run in subprocesses via
`multidev` below.
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import zlib

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- hypothesis degradation ---------------------------------------------------
# When hypothesis is missing (clean env), property tests fall back to a small
# deterministic engine instead of skipping.  Test modules use:
#     try: from hypothesis import given, ...
#     except ImportError: from conftest import given, st
#
# The fallback supports the strategy kinds our suites actually use
# (sampled_from / integers / floats / booleans).  Each test runs a fixed
# number of examples: the two boundary corners first, then samples from an
# RNG seeded by the test name, so failures replay bit-identically.
class _Strategy:
    """Deterministic stand-in for a hypothesis strategy."""

    def __init__(self, boundaries, sample):
        self.boundaries = list(boundaries)
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


class _St:
    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return _Strategy([xs[0], xs[-1]], lambda rng: rng.choice(xs))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    def __getattr__(self, name):
        # Unknown strategy kind: given() sees the non-_Strategy value and
        # degrades that one test to a reasoned skip.
        return lambda *a, **kw: None


st = _St()

_RANDOM_EXAMPLES = 4  # per test, after the two boundary corners


def given(*args, **kwargs):
    """Stand-in @given: runs boundary + seeded random examples."""

    def deco(fn):
        if args or not kwargs or any(
                not isinstance(s, _Strategy) for s in kwargs.values()):
            return pytest.mark.skip(
                reason="hypothesis not installed; strategy not covered by "
                       "the deterministic fallback engine")(fn)
        names = list(kwargs)

        def runner():
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            cases = [{n: kwargs[n].boundaries[pick] for n in names}
                     for pick in (0, -1)]
            cases += [{n: kwargs[n].sample(rng) for n in names}
                      for _ in range(_RANDOM_EXAMPLES)]
            seen = set()
            for case in cases:
                key = repr(sorted(case.items()))
                if key in seen:
                    continue
                seen.add(key)
                try:
                    fn(**case)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example: {fn.__name__}({case!r})") from e

        # Deliberately NOT functools.wraps: __wrapped__ would make pytest
        # introspect fn's signature and demand fixtures for B/S/....
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


@pytest.fixture(scope="session")
def multidev():
    """Run a snippet under N fake CPU devices; returns parsed RESULT json."""

    def run(script: str, ndev: int = 8, timeout: int = 900) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             text=True, capture_output=True, timeout=timeout,
                             cwd=REPO)
        for line in out.stdout.splitlines():
            if line.startswith("RESULT:"):
                return json.loads(line[len("RESULT:"):])
        raise AssertionError(
            f"no RESULT line (rc={out.returncode}):\n"
            f"STDOUT:\n{out.stdout[-3000:]}\nSTDERR:\n{out.stderr[-3000:]}")

    return run
