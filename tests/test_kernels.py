"""Pallas kernels vs pure-jnp oracles: hypothesis sweeps over shapes/dtypes.

Kernels execute with interpret=True (the kernel body runs in Python on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("kernels", max_examples=20, deadline=None)
    settings.load_profile("kernels")
except ImportError:  # property tests skip; deterministic tests still run
    from conftest import given, st  # noqa: F401

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([16, 33, 64, 128]),
    kh=st.sampled_from([(4, 4), (4, 2), (6, 3), (8, 1)]),
    D=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    window=st.sampled_from([None, 24]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_ref(B, S, kh, D, causal, window, dtype):
    H, KH = kh
    q = _rand(0, (B, S, H, D), dtype)
    k = _rand(1, (B, S, KH, D), dtype)
    v = _rand(2, (B, S, KH, D), dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="pallas_interpret", block_q=32, block_k=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@given(
    S=st.sampled_from([32, 96]),
    impl=st.sampled_from(["kvscan", "causal_blocked"]),
    window=st.sampled_from([None, 16]),
)
def test_jnp_attention_impls_match_ref(S, impl, window):
    q = _rand(3, (2, S, 4, 32), jnp.float32)
    k = _rand(4, (2, S, 2, 32), jnp.float32)
    v = _rand(5, (2, S, 2, 32), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    got = ops.flash_attention(q, k, v, causal=True, window=window, impl=impl,
                              block_q=32, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_suffix():
    """Sq=1 against a longer KV (decode-style alignment)."""
    q = _rand(6, (2, 1, 4, 32), jnp.float32)
    k = _rand(7, (2, 77, 2, 32), jnp.float32)
    v = _rand(8, (2, 77, 2, 32), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                              block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fully_masked_rows_are_zero_not_nan():
    """Window smaller than the gap: padded rows must not produce NaN."""
    q = _rand(9, (1, 8, 2, 16), jnp.float32)
    k = _rand(10, (1, 8, 2, 16), jnp.float32)
    v = _rand(11, (1, 8, 2, 16), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=1,
                              impl="pallas_interpret", block_q=8, block_k=8)
    assert np.isfinite(np.asarray(got)).all()


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@given(R=st.integers(1, 70), d=st.sampled_from([32, 128, 384]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_rmsnorm_matches_ref(R, d, dtype):
    x = _rand(12, (R, d), dtype)
    w = _rand(13, (d,), jnp.float32)
    want = ref.rmsnorm_ref(x, w)
    got = ops.rmsnorm(x, w, impl="pallas_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# int8 quant
# ---------------------------------------------------------------------------

@given(R=st.integers(1, 40), nb=st.integers(1, 4),
       scale=st.floats(1e-3, 1e3))
def test_quant_roundtrip_error_bound(R, nb, scale):
    n = nb * 256
    x = _rand(14, (R, n), jnp.float32) * scale
    q, s = ops.quant_int8(x, impl="pallas_interpret")
    y = ops.dequant_int8(q, s, impl="pallas_interpret")
    # blockwise absmax quantization error <= amax/127 per block (+eps)
    xb = np.asarray(x).reshape(R, nb, 256)
    bound = np.abs(xb).max(-1, keepdims=True) / 127 * 1.001 + 1e-8
    err = np.abs(np.asarray(y).reshape(R, nb, 256) - xb)
    assert (err <= bound).all()


@given(R=st.integers(1, 20))
def test_quant_kernel_matches_ref_exactly(R):
    x = _rand(15, (R, 512), jnp.float32)
    qk, sk = ops.quant_int8(x, impl="pallas_interpret")
    qr, sr = ref.quant_int8_ref(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quant_zero_block():
    x = jnp.zeros((3, 256), jnp.float32)
    q, s = ops.quant_int8(x, impl="pallas_interpret")
    y = ops.dequant_int8(q, s, impl="pallas_interpret")
    assert np.asarray(y).sum() == 0 and np.isfinite(np.asarray(s)).all()
