"""Property-based invariants for the chunk planners and int8 quantization.

Runs under hypothesis when installed; otherwise the deterministic fallback
engine in conftest.py drives boundary + seeded-random examples.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("props", max_examples=25, deadline=None)
    settings.load_profile("props")
except ImportError:  # deterministic fallback engine (see conftest.py)
    from conftest import given, st  # noqa: F401

from repro.core.filetransfer import plan_file_chunks
from repro.core.streams import assign_streams, leaf_bytes, plan_chunks
from repro.kernels import ops


# ---------------------------------------------------------------------------
# plan_chunks
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 97), d=st.sampled_from([1, 3, 32, 129]),
       chunk_kb=st.sampled_from([1, 4, 64]),
       dtype=st.sampled_from([np.float32, np.int8]))
def test_plan_chunks_exact_byte_accounting(n, d, chunk_kb, dtype):
    x = np.zeros((n, d), dtype)
    chunks = plan_chunks([x], [0], chunk_kb << 10)
    # chunk bytes sum exactly to the leaf's bytes (telemetry GB/s depends
    # on this), and row coverage is contiguous and gapless
    assert sum(c.nbytes for c in chunks) == leaf_bytes(x)
    pos = 0
    for c in chunks:
        assert c.leaf == 0 and c.dim == 0
        assert c.start == pos and c.size >= 1
        pos += c.size
    assert pos == n


@given(n=st.integers(2, 80), rows=st.integers(1, 16))
def test_plan_chunks_pinned_rows_geometry(n, rows):
    x = np.zeros((n, 7), np.float32)
    chunks = plan_chunks([x], [0], 1 << 30, rows=[rows])
    # pinned rows override the byte budget: every chunk but the last has
    # exactly `rows` rows, and the remainder lands in the last chunk
    assert all(c.size == rows for c in chunks[:-1])
    assert chunks[-1].size == n - rows * (len(chunks) - 1)
    assert sum(c.nbytes for c in chunks) == leaf_bytes(x)


@given(d=st.sampled_from([1, 5, 64]), chunk_kb=st.sampled_from([1, 16]))
def test_plan_chunks_multi_leaf_mixed_dims(d, chunk_kb):
    leaves = [np.zeros((40, d), np.float32),
              np.zeros((3,), np.float32),
              np.zeros((8, d), np.float32)]
    chunks = plan_chunks(leaves, [0, None, 0], chunk_kb << 10)
    for i, x in enumerate(leaves):
        mine = [c for c in chunks if c.leaf == i]
        assert mine, f"leaf {i} got no chunks"
        assert sum(c.nbytes for c in mine) == leaf_bytes(x)
    # a dim=None leaf is never split
    assert len([c for c in chunks if c.leaf == 1]) == 1


# ---------------------------------------------------------------------------
# plan_file_chunks
# ---------------------------------------------------------------------------

@given(nbytes=st.integers(1, 1 << 21), chunk=st.sampled_from(
    [1, 1 << 16, (1 << 16) + 1, 1 << 20]))
def test_plan_file_chunks_covers_every_byte(nbytes, chunk):
    chunks = plan_file_chunks(nbytes, chunk)
    floor = max(1 << 16, chunk)  # planner clamps tiny chunk sizes
    off = 0
    for i, c in enumerate(chunks):
        assert c.leaf == i and c.start == off
        assert 1 <= c.size <= floor and c.size == c.nbytes
        off += c.size
    assert off == nbytes


def test_plan_file_chunks_empty_file_single_marker():
    chunks = plan_file_chunks(0, 1 << 20)
    assert len(chunks) == 1 and chunks[0].size == 0


# ---------------------------------------------------------------------------
# assign_streams
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 64), streams=st.integers(1, 12),
       chunk_kb=st.sampled_from([1, 8]))
def test_assign_streams_partitions_all_chunks(n, streams, chunk_kb):
    x = np.zeros((n, 64), np.float32)
    chunks = plan_chunks([x], [0], chunk_kb << 10)
    buckets = assign_streams(chunks, streams)
    assert 1 <= len(buckets) <= streams
    assert all(buckets), "no empty buckets"
    got = sorted((c.leaf, c.start) for b in buckets for c in b)
    want = sorted((c.leaf, c.start) for c in chunks)
    assert got == want  # every chunk assigned exactly once
    # LPT bound: no stream exceeds the ideal share by more than one chunk
    loads = [sum(c.nbytes for c in b) for b in buckets]
    total = sum(c.nbytes for c in chunks)
    biggest = max(c.nbytes for c in chunks)
    assert max(loads) <= total / len(buckets) + biggest


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

@given(R=st.integers(1, 24), nb=st.integers(1, 3),
       scale=st.floats(1e-3, 1e3))
def test_quant_int8_roundtrip_bound_ref(R, nb, scale):
    n = nb * 256
    rng = np.random.default_rng(R * 1000 + nb)
    x = jnp.asarray(rng.standard_normal((R, n)).astype(np.float32) * scale)
    q, s = ops.quant_int8(x, impl="ref")
    y = ops.dequant_int8(q, s, impl="ref")
    # symmetric int8: roundoff within half a quantization step per block
    step = np.asarray(s, np.float32).reshape(R, nb, 1)
    err = np.abs(np.asarray(y) - np.asarray(x)).reshape(R, nb, 256)
    assert (err <= step * 0.5 + 1e-7).all()


@given(extra=st.integers(1, 255))
def test_quant_int8_rejects_ragged_trailing_dim(extra):
    x = jnp.zeros((2, 256 + extra), jnp.float32)
    with pytest.raises(ValueError, match="trailing dim"):
        ops.quant_int8(x, block=256)
