"""Property-based invariants for the chunk planners and int8 quantization.

Runs under hypothesis when installed; otherwise the deterministic fallback
engine in conftest.py drives boundary + seeded-random examples.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("props", max_examples=25, deadline=None)
    settings.load_profile("props")
except ImportError:  # deterministic fallback engine (see conftest.py)
    from conftest import given, st  # noqa: F401

from repro.core.filetransfer import plan_file_chunks
from repro.core.streams import assign_streams, leaf_bytes, plan_chunks
from repro.kernels import ops


# ---------------------------------------------------------------------------
# plan_chunks
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 97), d=st.sampled_from([1, 3, 32, 129]),
       chunk_kb=st.sampled_from([1, 4, 64]),
       dtype=st.sampled_from([np.float32, np.int8]))
def test_plan_chunks_exact_byte_accounting(n, d, chunk_kb, dtype):
    x = np.zeros((n, d), dtype)
    chunks = plan_chunks([x], [0], chunk_kb << 10)
    # chunk bytes sum exactly to the leaf's bytes (telemetry GB/s depends
    # on this), and row coverage is contiguous and gapless
    assert sum(c.nbytes for c in chunks) == leaf_bytes(x)
    pos = 0
    for c in chunks:
        assert c.leaf == 0 and c.dim == 0
        assert c.start == pos and c.size >= 1
        pos += c.size
    assert pos == n


@given(n=st.integers(2, 80), rows=st.integers(1, 16))
def test_plan_chunks_pinned_rows_geometry(n, rows):
    x = np.zeros((n, 7), np.float32)
    chunks = plan_chunks([x], [0], 1 << 30, rows=[rows])
    # pinned rows override the byte budget: every chunk but the last has
    # exactly `rows` rows, and the remainder lands in the last chunk
    assert all(c.size == rows for c in chunks[:-1])
    assert chunks[-1].size == n - rows * (len(chunks) - 1)
    assert sum(c.nbytes for c in chunks) == leaf_bytes(x)


@given(d=st.sampled_from([1, 5, 64]), chunk_kb=st.sampled_from([1, 16]))
def test_plan_chunks_multi_leaf_mixed_dims(d, chunk_kb):
    leaves = [np.zeros((40, d), np.float32),
              np.zeros((3,), np.float32),
              np.zeros((8, d), np.float32)]
    chunks = plan_chunks(leaves, [0, None, 0], chunk_kb << 10)
    for i, x in enumerate(leaves):
        mine = [c for c in chunks if c.leaf == i]
        assert mine, f"leaf {i} got no chunks"
        assert sum(c.nbytes for c in mine) == leaf_bytes(x)
    # a dim=None leaf is never split
    assert len([c for c in chunks if c.leaf == 1]) == 1


# ---------------------------------------------------------------------------
# plan_file_chunks
# ---------------------------------------------------------------------------

@given(nbytes=st.integers(1, 1 << 21), chunk=st.sampled_from(
    [1, 1 << 16, (1 << 16) + 1, 1 << 20]))
def test_plan_file_chunks_covers_every_byte(nbytes, chunk):
    chunks = plan_file_chunks(nbytes, chunk)
    floor = max(1 << 16, chunk)  # planner clamps tiny chunk sizes
    off = 0
    for i, c in enumerate(chunks):
        assert c.leaf == i and c.start == off
        assert 1 <= c.size <= floor and c.size == c.nbytes
        off += c.size
    assert off == nbytes


def test_plan_file_chunks_empty_file_single_marker():
    chunks = plan_file_chunks(0, 1 << 20)
    assert len(chunks) == 1 and chunks[0].size == 0


# ---------------------------------------------------------------------------
# assign_streams
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 64), streams=st.integers(1, 12),
       chunk_kb=st.sampled_from([1, 8]))
def test_assign_streams_partitions_all_chunks(n, streams, chunk_kb):
    x = np.zeros((n, 64), np.float32)
    chunks = plan_chunks([x], [0], chunk_kb << 10)
    buckets = assign_streams(chunks, streams)
    assert 1 <= len(buckets) <= streams
    assert all(buckets), "no empty buckets"
    got = sorted((c.leaf, c.start) for b in buckets for c in b)
    want = sorted((c.leaf, c.start) for c in chunks)
    assert got == want  # every chunk assigned exactly once
    # LPT bound: no stream exceeds the ideal share by more than one chunk
    loads = [sum(c.nbytes for c in b) for b in buckets]
    total = sum(c.nbytes for c in chunks)
    biggest = max(c.nbytes for c in chunks)
    assert max(loads) <= total / len(buckets) + biggest


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

@given(R=st.integers(1, 24), nb=st.integers(1, 3),
       scale=st.floats(1e-3, 1e3))
def test_quant_int8_roundtrip_bound_ref(R, nb, scale):
    n = nb * 256
    rng = np.random.default_rng(R * 1000 + nb)
    x = jnp.asarray(rng.standard_normal((R, n)).astype(np.float32) * scale)
    q, s = ops.quant_int8(x, impl="ref")
    y = ops.dequant_int8(q, s, impl="ref")
    # symmetric int8: roundoff within half a quantization step per block
    step = np.asarray(s, np.float32).reshape(R, nb, 1)
    err = np.abs(np.asarray(y) - np.asarray(x)).reshape(R, nb, 256)
    assert (err <= step * 0.5 + 1e-7).all()


@given(extra=st.integers(1, 255))
def test_quant_int8_rejects_ragged_trailing_dim(extra):
    x = jnp.zeros((2, 256 + extra), jnp.float32)
    with pytest.raises(ValueError, match="trailing dim"):
        ops.quant_int8(x, block=256)


# ---------------------------------------------------------------------------
# elastic membership (epochs, quorum) and local-SGD delta sync
# ---------------------------------------------------------------------------

from repro.core import cosmogrid_topology  # noqa: E402
from repro.core.chaos import IncidentLog  # noqa: E402
from repro.core.localsgd import (LocalSGDController,  # noqa: E402
                                 reference_delta_merge)
from repro.core.membership import QuorumPolicy, SiteMembership  # noqa: E402


def _fresh_membership(**kw):
    t = cosmogrid_topology(backup_links=True)
    return SiteMembership(t, "amsterdam", log=IncidentLog(), **kw)


@given(seed=st.integers(0, 40))
def test_membership_epoch_strictly_monotonic(seed):
    """Arbitrary seeded join/leave/evict sequences: the epoch never moves
    backwards, and every *applied* transition bumps it by exactly one."""
    rng = np.random.default_rng(seed)
    mem = _fresh_membership(lease_steps=2)
    others = [s.name for s in mem.topo.sites if s.name != "amsterdam"]
    last = mem.epoch
    for step in range(30):
        site = others[int(rng.integers(len(others)))]
        op = int(rng.integers(3))
        if op == 0:
            applied = mem.evict(site, step)
        elif op == 1:
            applied = mem.join(site, step)
        else:
            applied = mem.leave(site, step)
        assert mem.epoch == last + (1 if applied else 0)
        assert mem.epoch >= last
        last = mem.epoch


@given(seed=st.integers(0, 40))
def test_quorum_never_satisfied_by_evicted_sites(seed):
    """Evicted sites raise the quorum bar (total) but never clear it
    (live): has_quorum() tracks live members only, under any evict order."""
    rng = np.random.default_rng(seed)
    mem = _fresh_membership(quorum=QuorumPolicy(min_sites=1, fraction=0.75))
    others = [s.name for s in mem.topo.sites if s.name != "amsterdam"]
    total = len(mem.members())
    assert mem.has_quorum()
    for step, site in enumerate(rng.permutation(others)):
        mem.evict(str(site), step)
        live = len(mem.members())
        assert str(site) not in mem.members()
        assert mem.has_quorum() == mem.quorum.satisfied(live, total)
    # 1 live of 4 at fraction 0.75: the three evicted sites cannot help
    assert not mem.has_quorum()


@given(k=st.integers(1, 16), steps=st.sampled_from([1, 7, 32, 200]))
def test_localsgd_k1_is_the_synchronous_path(k, steps):
    """K=1 *is* the synchronous pipeline: the controller is disabled (the
    Trainer never builds a delta sync — bit-identity by construction) and
    every step is a sync step; K>1 syncs after every K-th local step."""
    c = LocalSGDController(k)
    syncs = [s for s in range(steps) if c.is_sync_step(s)]
    if k == 1:
        assert not c.enabled and syncs == list(range(steps))
    else:
        assert c.enabled and syncs == list(range(k - 1, steps, k))
        assert len(syncs) == steps // k


@given(seed=st.integers(0, 30), nsites=st.sampled_from([2, 3, 4, 5]))
def test_delta_merge_zero_anchor_is_the_plain_average(seed, nsites):
    """With a zero anchor (what the trainer uses for a full resync) the
    delta merge IS the member-param average, bit-for-bit — and with the
    membership stable this is exactly what a synchronous param average
    computes, so K=1-equivalence holds at the merge level too."""
    rng = np.random.default_rng(seed)
    anchor = np.zeros(33, np.float32)
    params = {f"s{i}": rng.standard_normal(33).astype(np.float32)
              for i in range(nsites)}
    members = [f"s{i}" for i in range(nsites - 1)]  # last site not a member
    merged = reference_delta_merge(anchor, params, members)
    sync = np.mean([params[m] for m in members], axis=0)
    for m in members:
        assert merged[m].tobytes() == sync.astype(np.float32).tobytes()
    # non-members pass through bit-untouched
    out = merged[f"s{nsites - 1}"]
    assert out.tobytes() == params[f"s{nsites - 1}"].tobytes()


# ---------------------------------------------------------------------------
# serving scheduler invariants (ISSUE 9)
# ---------------------------------------------------------------------------

from repro.core.serving import ContinuousBatcher, DONE, REJECTED  # noqa: E402


def _random_trace(seed: int, n: int) -> list:
    rng = np.random.default_rng(seed)
    steps = np.cumsum(rng.integers(0, 4, size=n))
    return [(int(s), int(rng.integers(1, 64)), int(rng.integers(1, 12)))
            for s in steps]


@given(seed=st.integers(0, 40), max_slots=st.sampled_from([1, 2, 4]),
       queue_limit=st.sampled_from([0, 2, 16]))
def test_scheduler_slots_never_double_booked(seed, max_slots, queue_limit):
    """At every virtual step: occupancy <= max_slots, no rid in two slots,
    and every slotted rid is live (a freed slot never keeps a stale rid)."""
    b = ContinuousBatcher(max_slots, queue_limit, prefill_steps=2,
                          ship_steps=1)
    trace = _random_trace(seed, 24)
    i = 0
    guard = 0
    while i < len(trace) or b.active() > 0:
        now = b.now()
        while i < len(trace) and trace[i][0] <= now:
            b.submit(trace[i][1], trace[i][2], step=now)
            i += 1
        b.step_once()
        slots = b.active_slots()
        assert len(slots) == max_slots
        rids = [r for r in slots if r is not None]
        assert len(rids) == len(set(rids)), f"slot double-booked: {slots}"
        for rid in rids:
            assert b._reqs[rid].state not in (DONE, REJECTED)
        guard += 1
        assert guard < 10_000


@given(seed=st.integers(0, 40), max_slots=st.sampled_from([1, 3]),
       queue_limit=st.sampled_from([0, 1, 8]))
def test_scheduler_every_request_terminates(seed, max_slots, queue_limit):
    """No starvation: under random arrivals every admitted request reaches
    DONE, every rejected one is terminal at submit time, and completed
    requests generated exactly their asked-for output length."""
    b = ContinuousBatcher(max_slots, queue_limit, prefill_steps=1,
                          ship_steps=2)
    trace = _random_trace(seed, 20)
    admitted, rejected = [], []
    i = 0
    while i < len(trace) or b.active() > 0:
        now = b.now()
        while i < len(trace) and trace[i][0] <= now:
            rid = b.submit(trace[i][1], trace[i][2], step=now)
            (admitted if rid is not None else rejected).append(trace[i])
            i += 1
        b.step_once()
    b.drain()
    stats = b.stats()
    assert stats["completed"] == len(admitted)
    assert stats["rejected"] == len(rejected)
    # per-request output lengths honored even though sequences finish at
    # different steps: token totals are the sum of the admitted max_new
    assert stats["total_tokens"] == sum(t[2] for t in admitted)
    for tr in b._reqs.values():
        assert tr.state in (DONE, REJECTED)
        if tr.state == DONE:
            assert tr.tokens == tr.req.max_new
            assert tr.slot is None


@given(n=st.integers(1, 30), queue_limit=st.sampled_from([0, 4]))
def test_scheduler_admission_bounded_by_queue_limit(n, queue_limit):
    """A burst of n simultaneous submits admits at most queue_limit beyond
    what slots drain; rejections are immediate and terminal."""
    b = ContinuousBatcher(2, queue_limit, prefill_steps=1, ship_steps=0)
    rids = [b.submit(8, 2) for _ in range(n)]
    admitted = [r for r in rids if r is not None]
    assert len(admitted) == min(n, queue_limit)
    assert b.stats()["rejected"] == n - len(admitted)
    b.drain()
    assert b.stats()["completed"] == len(admitted)


# ---------------------------------------------------------------------------
# fault-tolerant serving invariants (ISSUE 10)
# ---------------------------------------------------------------------------

from repro.core.serving import (FaultAwareShipper, Request, SHED,  # noqa: E402
                                TIMEOUT, _TERMINAL)
from repro.core.topology import Fault, cosmogrid_topology  # noqa: E402


def _deadline_trace(seed: int, n: int) -> list:
    rng = np.random.default_rng(seed)
    steps = np.cumsum(rng.integers(0, 4, size=n))
    return [(int(s), int(rng.integers(1, 64)), int(rng.integers(1, 12)),
             int(rng.integers(2, 40)))
            for s in steps]


@given(seed=st.integers(0, 40), max_slots=st.sampled_from([1, 2, 4]),
       shed=st.sampled_from([True, False]))
def test_serving_deadline_never_exceeded(seed, max_slots, shed):
    """Every DONE request finished strictly inside its deadline; every
    TIMEOUT fired at exactly arrival + deadline (never later)."""
    b = ContinuousBatcher(max_slots, 16, prefill_steps=2, ship_steps=3,
                          shed=shed)
    trace = _deadline_trace(seed, 20)
    i = 0
    while i < len(trace) or b.active() > 0:
        now = b.now()
        while i < len(trace) and trace[i][0] <= now:
            s, p, m, d = trace[i]
            b.submit(p, m, step=now, deadline_steps=d)
            i += 1
        b.step_once()
    for tr in b._reqs.values():
        assert tr.state in _TERMINAL
        d = tr.req.deadline_steps
        if tr.state == DONE:
            assert tr.t_done - tr.req.arrival < d
        elif tr.state == TIMEOUT:
            assert tr.t_done == tr.req.arrival + d


@given(seed=st.integers(0, 40), max_slots=st.sampled_from([1, 2]))
def test_serving_terminal_requests_never_occupy_slots(seed, max_slots):
    """After a request sheds or times out, it never holds a decode slot and
    never emits another timeline event."""
    b = ContinuousBatcher(max_slots, 16, prefill_steps=2, ship_steps=4)
    trace = _deadline_trace(seed, 16)
    terminal_at: dict[int, int] = {}
    i = 0
    while i < len(trace) or b.active() > 0:
        now = b.now()
        while i < len(trace) and trace[i][0] <= now:
            s, p, m, d = trace[i]
            b.submit(p, m, step=now, deadline_steps=d)
            i += 1
        b.step_once()
        for rid, tr in b._reqs.items():
            if tr.state in (SHED, TIMEOUT) and rid not in terminal_at:
                terminal_at[rid] = tr.t_done
            if tr.state in (SHED, TIMEOUT):
                assert rid not in b.active_slots()
    for kind, tag, step in b.timeline():
        rid = int(tag[3:])
        if rid in terminal_at:
            assert step <= terminal_at[rid], \
                f"req{rid} emitted {kind!r}@{step} after terminal " \
                f"at {terminal_at[rid]}"


@given(seed=st.integers(0, 20), start=st.sampled_from([2, 5, 9]))
def test_serving_reship_schedule_deterministic(seed, start):
    """Two same-seed FaultAwareShipper runs produce identical ShipOutcomes
    (steps, reships, reroutes, event rows) for the same request stream."""
    def outcomes():
        topo = cosmogrid_topology(backup_links=True)
        topo.connect("amsterdam", "tokyo",
                     topo.link("amsterdam", "tokyo").with_fault(
                         Fault("drop", start=start, stop=start + 30)))
        sh = FaultAwareShipper(topo, "amsterdam", "tokyo",
                               kv_bytes=16 << 20, step_s=0.5, max_reships=2,
                               timeout_s=0.5, seed=seed)
        outs = []
        for rid, at in enumerate(range(0, 40, 4)):
            sh.on_step(at)
            outs.append(sh.ship(Request(rid, at, 8, 2), at))
        return outs
    assert outcomes() == outcomes()
