"""The scan-aware HLO analyzer must reproduce known FLOP counts exactly —
it is the measurement instrument for §Roofline/§Perf, so it gets its own
correctness suite."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return H.analyze(c.as_text()).flops, c


def test_plain_matmul():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    flops, _ = _flops(lambda x, y: x @ y, a, b)
    assert flops == pytest.approx(2 * 32 * 48 * 16)


def test_scan_multiplies_body():
    L, B, D = 9, 4, 32
    def f(ws, x):
        def step(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(step, x, ws)[0].sum()
    flops, c = _flops(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                      jax.ShapeDtypeStruct((B, D), jnp.float32))
    assert flops == pytest.approx(2 * B * D * D * L, rel=1e-6)
    # and XLA's own analysis undercounts (documents why the analyzer exists)
    assert H.xla_cost(c)["flops"] < flops


def test_grad_of_scan():
    L, B, D = 5, 2, 16
    def f(ws, x):
        def step(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(step, x, ws)[0].sum()
    flops, _ = _flops(jax.grad(f), jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                      jax.ShapeDtypeStruct((B, D), jnp.float32))
    assert flops == pytest.approx(3 * 2 * B * D * D * L, rel=1e-6)


def test_remat_counted():
    L, B, D = 6, 2, 16
    def f(ws, x):
        @jax.checkpoint
        def blk(x, w):
            return jnp.tanh(x @ w)
        def step(x, w):
            return blk(x, w), None
        return jax.lax.scan(step, x, ws)[0].sum()
    flops, _ = _flops(jax.grad(f), jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                      jax.ShapeDtypeStruct((B, D), jnp.float32))
    assert flops == pytest.approx(4 * 2 * B * D * D * L, rel=1e-6)


def test_nested_scan():
    Lo, Li, D = 3, 4, 8
    def f(ws, x):
        def inner(x, w):
            return x @ w, None
        def outer(x, ws_i):
            return jax.lax.scan(inner, x, ws_i)[0], None
        return jax.lax.scan(outer, x, ws)[0].sum()
    flops, _ = _flops(f, jax.ShapeDtypeStruct((Lo, Li, D, D), jnp.float32),
                      jax.ShapeDtypeStruct((D, D), jnp.float32))
    assert flops == pytest.approx(2 * D * D * D * Lo * Li, rel=1e-6)


def test_dynamic_slice_bytes_not_inflated():
    """Reading one (D,D) slice per iteration must cost ~slice bytes, not the
    whole stacked array per iteration."""
    L, D = 50, 64
    def f(ws, x):
        def step(x, w):
            return x + w, None
        return jax.lax.scan(step, x, ws)[0].sum()
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                         jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    cost = H.analyze(c.as_text())
    stacked = L * D * D * 4
    # total bytes should be O(L * slice) = O(stacked), far below L * stacked
    assert cost.bytes < 10 * stacked, cost.bytes


def test_collective_accounting():
    import re
    hlo = """
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
}
"""
    cost = H.analyze(hlo)
    # ring AR of 4KB over 2 ranks: 2*(1/2)*4096 = 4096 link bytes
    assert cost.coll_ici == pytest.approx(4096)
    assert cost.coll_cross == 0
