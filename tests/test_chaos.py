"""Deterministic chaos suite: fault injection -> detection -> self-healing.

Every scenario is driven by seeded, step-stamped fault schedules
(`LinkProfile.drop / degrade / partition`), so timelines replay
bit-identically: tests assert *golden* incident sequences, not
distributions.  Covers the three data planes the paper's deployments
exercised: training collectives (re-route + re-tune, and whole-site
failover to the checkpoint replica), wide-area file transfer (mpw-cp
chunk requeue on a detour), and the relay/degrade path (throughput
collapse in a window, recovery after).
"""
from __future__ import annotations

import hashlib
import math
import os
import tempfile

import pytest

from repro.configs.base import CommConfig
from repro.core import (
    ChaosDetector,
    cosmogrid_topology,
    get_incident_log,
    get_telemetry,
    healing_transfer,
)
from repro.core.autotune import simulate_hop_s
from repro.core.filetransfer import ChecksumError
from repro.core.topology import Fault, LinkProfile, Topology


@pytest.fixture(autouse=True)
def _clean_slate():
    get_incident_log().clear()
    get_telemetry().reset()
    yield
    get_incident_log().clear()


def _wan(name="wan", faults=()):
    return LinkProfile(name, 50e-3, 1e8, window=64 << 10, streams=16,
                       chunk_mb=1.0, faults=tuple(faults))


# ---------------------------------------------------------------------------
# fault schedules & link health
# ---------------------------------------------------------------------------

def test_fault_schedule_health_folding():
    prof = _wan().drop(5, until=9).degrade(0.25, (2, 4), error_rate=0.1)
    # healthy before anything starts
    h0 = prof.health(0)
    assert h0.alive and h0.bandwidth_factor == 1.0 and not h0.faulty
    # degrade window only
    h3 = prof.health(3)
    assert h3.alive and h3.bandwidth_factor == 0.25
    assert h3.error_rate == pytest.approx(0.1) and h3.faulty
    # drop window: dead regardless of the degrade
    assert not prof.health(5).alive
    assert not prof.health(8).alive
    # drop `until` is exclusive; everything healed at 9
    assert prof.health(9).alive and not prof.health(9).faulty


def test_fault_active_and_partition_sites():
    f = Fault("drop", start=4)
    assert not f.active(3) and f.active(4) and f.active(10**6)
    prof = _wan().partition("tokyo", at_step=2)
    assert prof.health(1).partitioned == ()
    assert prof.health(2).partitioned == ("tokyo",)
    assert not prof.health(2).alive


def test_degrade_validates_factor():
    with pytest.raises(ValueError):
        _wan().degrade(0.0, (0, 5))
    with pytest.raises(ValueError):
        _wan().degrade(1.5, (0, 5))


def test_transfer_s_applies_schedule_only_with_step():
    prof = _wan(faults=[Fault("drop", start=0)])
    nb = 64 << 20
    # step=None is the schedule-blind planner view (route costing)
    assert math.isfinite(prof.transfer_s(nb))
    assert prof.transfer_s(nb, step=0) == math.inf
    slow = _wan(faults=[Fault("degrade", start=0, factor=0.1)])
    assert slow.transfer_s(nb, step=0) > 5 * slow.transfer_s(nb)


def test_health_seed_is_deterministic_per_schedule():
    a = _wan().degrade(0.5, (0, 4), seed=7).health(1)
    b = _wan().degrade(0.5, (0, 4), seed=7).health(1)
    c = _wan().degrade(0.5, (0, 4), seed=8).health(1)
    assert a.seed == b.seed
    assert a.seed != c.seed


# ---------------------------------------------------------------------------
# topology: down links, detours, site loss
# ---------------------------------------------------------------------------

def test_topology_reroutes_around_failed_link():
    t = cosmogrid_topology(backup_links=True)
    assert t.route("amsterdam", "tokyo").sites == ("amsterdam", "tokyo")
    t.fail_link("amsterdam", "tokyo")
    assert t.is_down("amsterdam", "tokyo")
    assert t.is_down("tokyo", "amsterdam")          # bidirectional default
    detour = t.route("amsterdam", "tokyo")
    assert detour.sites == ("amsterdam", "edinburgh", "tokyo")
    assert detour.profiles[-1].name == "tokyo-edinburgh-backup"
    t.restore_link("amsterdam", "tokyo")
    assert not t.down_links()
    assert t.route("amsterdam", "tokyo").sites == ("amsterdam", "tokyo")


def test_topology_site_loss_disconnects():
    t = cosmogrid_topology(backup_links=True)
    hit = t.fail_site("tokyo")
    assert all("tokyo" in pair for pair in hit)
    with pytest.raises(KeyError):
        t.route("amsterdam", "tokyo")
    # the rest of the grid still routes
    assert t.route("amsterdam", "espoo").n_hops == 1


def test_plain_cosmogrid_has_no_backup():
    t = cosmogrid_topology()
    assert t.link("tokyo", "edinburgh") is None
    t.fail_link("amsterdam", "tokyo")
    with pytest.raises(KeyError):
        t.route("amsterdam", "tokyo")
    with pytest.raises(KeyError):
        t.fail_link("amsterdam", "nowhere")


# ---------------------------------------------------------------------------
# detector
# ---------------------------------------------------------------------------

def test_detector_fires_on_collapse_after_window():
    det = ChaosDetector(collapse=8.0, window=2, min_baseline=2)
    assert not det.observe("k", 1.0)
    assert not det.observe("k", 1.1)
    assert det.baseline("k") == pytest.approx(1.05)
    assert not det.observe("k", 50.0)     # 1st anomaly: inside the window
    assert det.observe("k", 50.0)         # 2nd consecutive: fires
    # latched: no re-fire until reset
    assert not det.observe("k", 50.0)
    det.reset("k")
    assert det.baseline("k") is None


def test_detector_timeout_fires_before_baseline_exists():
    det = ChaosDetector(window=2, min_baseline=2, abs_timeout_s=30.0)
    assert not det.observe("dead", 30.0)  # no baseline yet: timeout still bad
    assert det.observe("dead", 30.0)


def test_detector_ignores_mild_degrade():
    det = ChaosDetector(collapse=8.0, window=1, min_baseline=2)
    for s in (1.0, 1.0):
        det.observe("k", s)
    # 3x slower is the tuner's problem, not a re-route trigger
    assert not det.observe("k", 3.0)
    assert not det.observe("k", 3.0)


def test_detector_anomaly_streak_must_be_consecutive():
    det = ChaosDetector(collapse=8.0, window=3, min_baseline=2)
    for s in (1.0, 1.0):
        det.observe("k", s)
    assert not det.observe("k", 20.0)
    assert not det.observe("k", 20.0)
    assert not det.observe("k", 1.0)      # healthy sample resets the streak
    assert not det.observe("k", 20.0)
    assert not det.observe("k", 20.0)
    assert det.observe("k", 20.0)


def test_detector_rearms_after_heal_and_fires_on_second_fault():
    """Two sequential faults on one path: the latch must not go blind.

    Fault 1 fires and latches; `rearm_after` consecutive healthy samples
    un-latch the key; fault 2 — a later, distinct incident — fires again.
    An anomalous sample mid-heal resets the healthy streak, so a link
    that is still broken never re-arms."""
    det = ChaosDetector(collapse=8.0, window=2, min_baseline=2,
                        rearm_after=3)
    det.observe("hop", 1.0)
    det.observe("hop", 1.1)
    assert not det.observe("hop", 50.0)
    assert det.observe("hop", 50.0)        # fault 1 fires
    assert not det.observe("hop", 50.0)    # latched: same incident
    assert not det.observe("hop", 1.0)     # healing: streak 1
    assert not det.observe("hop", 1.0)     # streak 2
    assert not det.observe("hop", 50.0)    # relapse: streak back to 0
    assert not det.observe("hop", 1.0)
    assert not det.observe("hop", 1.0)
    assert not det.observe("hop", 1.0)     # 3rd consecutive: re-armed
    assert not det.observe("hop", 50.0)    # fault 2, 1st anomaly
    assert det.observe("hop", 50.0)        # fault 2 fires — not blind


# ---------------------------------------------------------------------------
# incident log
# ---------------------------------------------------------------------------

def test_incident_log_golden_timeline():
    log = get_incident_log()
    log.add(4, "inject", "a->b", {"kind": "drop", "link": "wan"})
    log.add(5, "detect", "a->b", {"signal": "timeout"})
    log.add(5, "replan", "a->c", {"route": "a --[x]--> c"})
    log.add(7, "recover", "a->b", {"latency_steps": 3})
    assert [(e.kind, e.step) for e in log.events()] == [
        ("inject", 4), ("detect", 5), ("replan", 5), ("recover", 7)]
    assert log.recovery_latencies() == [("a->b", 3)]
    rows = log.timeline()
    assert rows[0] == {"step": 4, "event": "inject", "subject": "a->b",
                       "detail": {"kind": "drop", "link": "wan"}}
    md = log.format_timeline()
    assert md.splitlines()[0] == "| step | event | subject | detail |"
    assert "| 4 | inject | a->b | kind=drop link=wan |" in md
    with pytest.raises(ValueError):
        log.add(0, "explode", "a->b")
    log.clear()
    assert log.format_timeline() == "(no incidents)"


# ---------------------------------------------------------------------------
# relay/degrade: modeled hop seconds collapse inside the window and recover
# ---------------------------------------------------------------------------

def test_simulate_hop_s_degrade_window_and_recovery():
    # low-alpha link: throughput is bandwidth-limited (not window/RTT-capped),
    # so the degrade factor shows up ~proportionally in modeled seconds
    prof = LinkProfile("metro", 1e-3, 1e8, window=64 << 10, streams=16,
                       chunk_mb=1.0).degrade(0.05, (3, 6))
    nb = 64 << 20
    healthy = simulate_hop_s(nb, prof, 0)
    collapsed = simulate_hop_s(nb, prof, 4)
    healed = simulate_hop_s(nb, prof, 7)
    assert collapsed > 5 * healthy            # achieved-GB/s collapse
    assert healed == pytest.approx(healthy, rel=0.3)
    # a detector watching this hop fires only during the window
    det = ChaosDetector(collapse=4.0, window=2, min_baseline=2,
                        abs_timeout_s=30.0)
    fired_at = None
    for step in range(10):
        if det.observe("hop", simulate_hop_s(nb, prof, step)) \
                and fired_at is None:
            fired_at = step
    assert fired_at == 4                      # window start 3 + window of 2


def test_simulate_hop_s_dead_link_is_the_watchdog_timeout():
    prof = _wan().drop(2)
    assert simulate_hop_s(1 << 20, prof, 1, timeout_s=30.0) < 30.0
    assert simulate_hop_s(1 << 20, prof, 2, timeout_s=30.0) == 30.0


# ---------------------------------------------------------------------------
# file transfer: heal around a dead hop, byte accounting, determinism
# ---------------------------------------------------------------------------

def _run_healing_copy(tmpdir, seed=123):
    """One healed 1 MiB copy over a dead lightpath; returns (result, kinds)."""
    log = get_incident_log()
    log.clear()
    t = cosmogrid_topology(backup_links=True)
    t.connect("amsterdam", "tokyo", t.link("amsterdam", "tokyo").drop(0))
    eng = healing_transfer(t, "amsterdam", "tokyo",
                           comm=CommConfig(streams=4, chunk_mb=0.0625),
                           max_retries=1)
    src = os.path.join(tmpdir, "src.bin")
    dst = os.path.join(tmpdir, "dst.bin")
    data = bytes((seed + i * 31) % 256 for i in range(1 << 20))
    with open(src, "wb") as f:
        f.write(data)
    res = eng.copy(src, dst)
    with open(dst, "rb") as f:
        assert f.read() == data
    assert res.sha256 == hashlib.sha256(data).hexdigest()
    return res, [(e.kind, e.subject) for e in log.events()]


def test_file_transfer_heals_around_dead_link(tmp_path):
    res, kinds = _run_healing_copy(str(tmp_path))
    assert res.reroutes == 1
    assert res.nbytes == 1 << 20
    # bytes burned on the dead hop still count: wire >= payload
    assert res.wire_bytes >= res.nbytes
    assert res.reroute_history[0]["failed_hop"] == 0
    for kind in ("inject", "detect", "replan", "requeue"):
        assert (kind, "amsterdam->tokyo") in kinds, (kind, kinds)
    # detect cites checksum exhaustion, replan cites the detour
    log = get_incident_log()
    assert log.events("detect")[0].detail["signal"] == "checksum"
    assert "edinburgh" in log.events("replan")[0].detail["route"]


def test_file_transfer_healing_is_deterministic(tmp_path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    a.mkdir()
    b.mkdir()
    res1, kinds1 = _run_healing_copy(str(a))
    res2, kinds2 = _run_healing_copy(str(b))
    assert kinds1 == kinds2
    assert (res1.reroutes, res1.retries, res1.wire_bytes, res1.sha256) == \
           (res2.reroutes, res2.retries, res2.wire_bytes, res2.sha256)


def test_file_transfer_no_detour_propagates_checksum_error(tmp_path):
    t = cosmogrid_topology()                 # star: no backup to tokyo
    t.connect("amsterdam", "tokyo", t.link("amsterdam", "tokyo").drop(0))
    eng = healing_transfer(t, "amsterdam", "tokyo",
                           comm=CommConfig(streams=2, chunk_mb=0.0625),
                           max_retries=1)
    src = os.path.join(str(tmp_path), "src.bin")
    with open(src, "wb") as f:
        f.write(b"x" * (1 << 18))
    with pytest.raises(ChecksumError):
        eng.copy(src, os.path.join(str(tmp_path), "dst.bin"))
    kinds = [e.kind for e in get_incident_log().events()]
    assert "detect" in kinds and "replan" not in kinds


# ---------------------------------------------------------------------------
# training: mid-run link drop -> re-route + re-tune, loss parity (tentpole
# acceptance scenario), and whole-site loss -> replica failover
# ---------------------------------------------------------------------------

_TRAIN_REROUTE = r"""
import json
import jax
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime import Trainer
from repro.core import (cosmogrid_topology, ChaosMonitor, ChaosDetector,
                        get_incident_log, MPW)
from repro.data import DataConfig, make_pipeline

STEPS, FAULT_AT = 10, 4

def build():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=4,
                                   chunk_mb=0.01, autotune=False),
                   train=TrainConfig(zero1=True, warmup_steps=2,
                                     total_steps=50))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8), prefetch=0)
    return rc, data

mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)

# control run: fault-free, stays on the lightpath
t0 = cosmogrid_topology(backup_links=True)
rc, data = build()
with jax.set_mesh(mesh):
    ctr = Trainer(rc, mesh, route=t0.route("amsterdam", "tokyo"),
                  site_groups=t0.pod_groups())
    ctr.init_or_restore()
    ref = ctr.run(data, STEPS, log_every=0)

# chaos run: the lightpath dies at FAULT_AT
log = get_incident_log(); log.clear()
t1 = cosmogrid_topology(backup_links=True)
t1.connect("amsterdam", "tokyo", t1.link("amsterdam", "tokyo").drop(FAULT_AT))
mon = ChaosMonitor(t1, "amsterdam", "tokyo",
                   detector=ChaosDetector(window=2, min_baseline=2),
                   recover_after=2)
rc2, data2 = build()
with jax.set_mesh(mesh):
    tr = Trainer(rc2, mesh, route=t1.route("amsterdam", "tokyo"),
                 site_groups=t1.pod_groups(), chaos=mon)
    tr.init_or_restore()
    hist = tr.run(data2, STEPS, log_every=0)

out = {}
out["timeline"] = [[e.kind, e.subject, e.step] for e in log.events()]
out["final_route"] = list(tr.route.sites) if tr.route else None
ref_l = [h["loss"] for h in ref]; got_l = [h["loss"] for h in hist]
out["n_steps"] = [len(ref_l), len(got_l)]
out["loss_diff"] = max(abs(a - b) for a, b in zip(ref_l, got_l))
rep = MPW.Init().Report(formatted=True)
out["report_incidents"] = ("**Incidents**" in rep) and ("| inject |" in rep)
out["incidents_rows"] = len(MPW.Init().Incidents())
out["recovery"] = log.recovery_latencies()
out["window"] = mon.detector.window
print("RESULT:" + json.dumps(out))
"""


def test_chaos_training_reroute_and_loss_parity(multidev):
    """Acceptance scenario: mid-run drop of the amsterdam-tokyo lightpath on
    the 4-site CosmoGrid testbed causes detect -> replan (via edinburgh) ->
    re-tune within the detection window, with loss parity vs the fault-free
    run and a golden incident timeline ending in a nonzero-latency recover."""
    res = multidev(_TRAIN_REROUTE)
    # golden timeline: inject at the fault step, detect one window later,
    # replan+retune the same step, recover after the post-heal window
    assert [(k, s) for k, _, s in res["timeline"]] == [
        ("inject", 4), ("detect", 5), ("replan", 5), ("retune", 5),
        ("recover", 7)], res["timeline"]
    assert all(sub == "amsterdam->tokyo" for _, sub, _ in res["timeline"])
    inject_step = res["timeline"][0][2]
    detect_step = res["timeline"][1][2]
    assert detect_step - inject_step <= res["window"]
    assert res["final_route"] == ["amsterdam", "edinburgh", "tokyo"]
    # the detour only changes chunk scheduling, never collective math
    assert res["n_steps"] == [10, 10]
    assert res["loss_diff"] <= 1e-6
    assert res["report_incidents"]
    assert res["incidents_rows"] == 5
    [(subject, latency)] = res["recovery"]
    assert subject == "amsterdam->tokyo" and latency > 0


_TRAIN_FAILOVER = r"""
import json, os, shutil, tempfile
import jax
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime import Trainer
from repro.core import (cosmogrid_topology, ChaosMonitor, ChaosDetector,
                        get_incident_log)
from repro.data import DataConfig, make_pipeline

cfg = smoke_config(get_config("qwen1.5-0.5b"))
rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
               comm=CommConfig(mode="hierarchical", streams=4, chunk_mb=0.01,
                               autotune=False),
               train=TrainConfig(zero1=True, warmup_steps=2, total_steps=50))
data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8), prefetch=0)
mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)

log = get_incident_log(); log.clear()
t = cosmogrid_topology()          # no backup: tokyo loss is unroutable
t.connect("amsterdam", "tokyo",
          t.link("amsterdam", "tokyo").partition("tokyo", at_step=7))
mon = ChaosMonitor(t, "amsterdam", "tokyo",
                   detector=ChaosDetector(window=2, min_baseline=2),
                   recover_after=2)
tmp = tempfile.mkdtemp()
primary, replica = os.path.join(tmp, "ck"), os.path.join(tmp, "rep")
with jax.set_mesh(mesh):
    tr = Trainer(rc, mesh, route=t.route("amsterdam", "tokyo"),
                 site_groups=t.pod_groups(), ckpt_dir=primary,
                 replica_dir=replica, ckpt_every=5, chaos=mon)
    tr.init_or_restore()
    tr.run(data, 6, log_every=0)       # healthy segment; ckpt 5 + replica
    # the site's storage dies with the site: only the replica mirror is left
    shutil.rmtree(primary)
    hist = tr.run(data, 6, log_every=0)
tr.close()
out = {}
out["timeline"] = [[e.kind, e.subject, e.step] for e in log.events()]
out["route_after"] = tr.route.sites if tr.route else None
out["steps"] = [h["step"] for h in hist]
fo = log.events("failover")[0]
out["failover"] = dict(fo.detail)
out["recovery"] = log.recovery_latencies()
out["losses_finite"] = all(h["loss"] == h["loss"] for h in hist)
shutil.rmtree(tmp)
print("RESULT:" + json.dumps(out))
"""


def test_chaos_training_failover_to_replica(multidev):
    """Whole-site loss on a star topology has no detour: the trainer falls
    back to the replica checkpoint mirror, mid-step-safe (the rollback is
    visible as repeated step numbers in the history)."""
    res = multidev(_TRAIN_FAILOVER)
    kinds = [k for k, _, _ in res["timeline"]]
    assert kinds == ["inject", "detect", "failover", "recover"], res
    steps = {k: s for k, _, s in res["timeline"]}
    assert steps["inject"] == 7
    assert steps["detect"] - steps["inject"] <= 2
    assert res["route_after"] is None
    # restored from the replica: resumed at the last replicated step (run()
    # ends each segment with a blocking save + replicate_now, so that's the
    # first segment's final step, not the last ckpt_every multiple)
    assert res["failover"]["outcome"] == "restored"
    assert res["failover"]["resume_step"] == 6
    # rollback visible: the second segment revisits pre-fault step numbers
    assert min(res["steps"]) <= 6
    [(_, latency)] = res["recovery"]
    assert latency > 0
    assert res["losses_finite"]
