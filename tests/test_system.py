"""End-to-end behaviour: a small model trained on the learnable synthetic
stream must actually learn (loss well below the unigram floor), checkpoints
must be exact, and serving must be self-consistent with training weights."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (CommConfig, RunConfig, ShapeConfig, TrainConfig,
                           get_config, smoke_config)
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.param import tree_init
from repro.runtime import Trainer


@pytest.mark.slow
def test_training_learns_synthetic_recurrence(tmp_path):
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    mesh = make_local_mesh(data=1, model=1)
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=2, chunk_mb=1.0),
                   train=TrainConfig(lr=3e-3, warmup_steps=10, total_steps=80,
                                     zero1=True))
    data = iter(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                       global_batch=8, noise=0.0)))
    with jax.set_mesh(mesh):
        tr = Trainer(rc, mesh, ckpt_dir=str(tmp_path / "ck"), ckpt_every=40)
        tr.init_or_restore()
        hist = tr.run(data, 80, log_every=0)
        tr.close()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 1.0, f"no learning: {first:.3f} -> {last:.3f}"
    assert last < 4.6, f"loss should approach the recurrence floor, got {last:.3f}"


def test_checkpoint_exact_roundtrip_through_trainer(tmp_path):
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    mesh = make_local_mesh(data=1, model=1)
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                   comm=CommConfig(), train=TrainConfig(total_steps=10))
    data = iter(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                       global_batch=4)))
    with jax.set_mesh(mesh):
        tr = Trainer(rc, mesh, ckpt_dir=str(tmp_path / "ck"), ckpt_every=100)
        tr.init_or_restore()
        tr.run(data, 3, log_every=0)
        saved = jax.tree.map(lambda x: np.asarray(x), tr.state)
        tr.manager.save(tr.step, tr.state)
        tr2 = Trainer(rc, mesh, ckpt_dir=str(tmp_path / "ck"))
        assert tr2.init_or_restore() == "restored"
        assert tr2.step == 3
        restored = jax.tree.map(lambda x: np.asarray(x), tr2.state)
        for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(a, b)
        tr.close()
        tr2.close()


def test_greedy_decode_consistency():
    """Argmax over model.logits at the last position == decode_step output
    after feeding the same prefix through the cache."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    model = build_model(cfg)
    params = tree_init(model.param_defs(), seed=1)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(2, 12)).astype(np.int32)
    full = model.logits(params, {"tokens": jnp.asarray(toks)})
    cache = tree_init(model.cache_defs(2, 16), seed=0)
    step_logits = None
    for i in range(12):
        step_logits, cache = model.decode_step(
            params, cache, jnp.int32(i), jnp.asarray(toks[:, i:i + 1]))
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(step_logits[:, 0]),
                               atol=5e-2, rtol=5e-2)
    assert (np.argmax(np.asarray(full[:, -1]), -1)
            == np.argmax(np.asarray(step_logits[:, 0]), -1)).all()
