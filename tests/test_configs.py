"""Config registry invariants: published dims, param counts, smoke reduction
preserves family structure, spec divisibility rules."""
from __future__ import annotations

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cell_applicable, get_config, list_archs, smoke_config
from repro.models import build_model
from repro.models.param import is_pd_leaf, spec_for, tree_fsdp_dims, tree_specs

import jax

EXPECTED = {
    "pixtral-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                        num_kv_heads=8, d_ff=14336, vocab_size=131072),
    "h2o-danube-3-4b": dict(num_layers=24, d_model=3840, num_heads=32,
                            num_kv_heads=8, d_ff=10240, vocab_size=32000,
                            sliding_window=4096),
    "llama3.2-3b": dict(num_layers=28, d_model=3072, num_heads=24,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "qwen1.5-0.5b": dict(num_layers=24, d_model=1024, num_heads=16,
                         num_kv_heads=16, d_ff=2816, vocab_size=151936,
                         qkv_bias=True),
    "qwen2.5-14b": dict(num_layers=48, d_model=5120, num_heads=40,
                        num_kv_heads=8, d_ff=13824, vocab_size=152064,
                        qkv_bias=True),
    "dbrx-132b": dict(num_layers=40, d_model=6144, num_heads=48,
                      num_kv_heads=8, d_ff=10752, vocab_size=100352),
    "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32,
                                 num_kv_heads=8, d_ff=6400, vocab_size=32064),
    "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                        num_kv_heads=32, d_ff=8192, vocab_size=32000),
    "mamba2-780m": dict(num_layers=48, d_model=1536, num_heads=0, d_ff=0,
                        vocab_size=50280),
    "whisper-medium": dict(num_layers=24, d_model=1024, num_heads=16,
                           num_kv_heads=16, d_ff=4096, vocab_size=51865,
                           encoder_layers=24),
}

PARAM_BILLIONS = {
    "pixtral-12b": (11.0, 13.5), "h2o-danube-3-4b": (3.5, 4.5),
    "llama3.2-3b": (2.8, 3.7), "qwen1.5-0.5b": (0.4, 0.55),
    "qwen2.5-14b": (13.5, 16.0), "dbrx-132b": (125, 138),
    "phi3.5-moe-42b-a6.6b": (39, 45), "zamba2-1.2b": (1.0, 1.4),
    "mamba2-780m": (0.7, 0.9), "whisper-medium": (0.7, 1.1),
}


def test_all_archs_registered():
    assert len(list_archs()) == 10
    assert set(EXPECTED) == set(list_archs())


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_published_dims(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", sorted(PARAM_BILLIONS))
def test_param_counts_in_range(arch):
    lo, hi = PARAM_BILLIONS[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert 5.5 <= phi.active_param_count() / 1e9 <= 7.5     # a6.6b
    dbrx = get_config("dbrx-132b")
    assert 33 <= dbrx.active_param_count() / 1e9 <= 40      # 36B active


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_smoke_preserves_family(arch):
    full, small = get_config(arch), smoke_config(get_config(arch))
    assert small.family == full.family
    assert (small.moe is None) == (full.moe is None)
    assert (small.ssm is None) == (full.ssm is None)
    assert bool(small.sliding_window) == bool(full.sliding_window)
    assert bool(small.qkv_bias) == bool(full.qkv_bias)
    assert bool(small.encoder_layers) == bool(full.encoder_layers)
    if full.num_heads and full.num_kv_heads != full.num_heads:
        assert small.num_kv_heads < small.num_heads  # GQA stays grouped
    assert small.param_count() < 5e6


def test_cell_matrix_counts():
    ok = skip = 0
    for a in list_archs():
        for s in SHAPES.values():
            good, why = cell_applicable(get_config(a), s)
            ok += good
            skip += not good
            if not good:
                assert s.name == "long_500k" and why
    assert (ok, skip) == (33, 7)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_specs_divisible_on_production_mesh(arch):
    """Every param spec must be valid for a 16-way TP, 16-way FSDP mesh —
    dims not divisible must have been left unsharded."""
    cfg = get_config(arch)
    defs = build_model(cfg).param_defs()
    specs = tree_specs(defs, fsdp_axes=("data",), fsdp_size=16, tp_size=16)
    flat_defs = jax.tree.leaves(defs, is_leaf=is_pd_leaf)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    axis_size = {"model": 16, "data": 16}
    for pd, spec in zip(flat_defs, flat_specs):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            div = 1
            for a in axes:
                div *= axis_size[a]
            assert pd.shape[dim] % div == 0, (arch, pd.shape, spec)
