"""FileTransfer (mpw-cp over WidePath): bit-exact round-trips, resume
after interrupt without re-sending completed chunks, checksum-mismatch
requeue, multi-hop per-hop telemetry, the MPW File* facade verbs, and the
checkpoint restart-from-replica path."""
from __future__ import annotations

import os
import random

import pytest

from repro.configs.base import CommConfig
from repro.core import MPW, FileTransfer, WidePath, file_sha256, get_telemetry
from repro.core.filetransfer import (
    PART_SUFFIX,
    SIDECAR_SUFFIX,
    ChecksumError,
    plan_file_chunks,
)
from repro.core.path import WAN_LONDON_POZNAN
from repro.core.topology import cosmogrid_topology


def _make_file(path: str, nbytes: int = 300_000, seed: int = 0) -> bytes:
    random.seed(seed)
    data = bytes(random.getrandbits(8) for _ in range(nbytes // 2))
    data += b"compressible " * ((nbytes - len(data)) // 13 + 1)
    data = data[:nbytes]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(data)
    return data


def _path(streams: int = 4, chunk_mb: float = 0.0625,
          compress: str = "none", name: str = "t") -> WidePath:
    return WidePath(axis="pod", link=WAN_LONDON_POZNAN, name=name,
                    comm=CommConfig(streams=streams, chunk_mb=chunk_mb,
                                    compress=compress))


# -- chunk planning -----------------------------------------------------------

def test_plan_file_chunks_covers_every_byte():
    chunks = plan_file_chunks(300_000, 1 << 16)
    assert [c.start for c in chunks] == [0, 65536, 131072, 196608, 262144]
    assert sum(c.nbytes for c in chunks) == 300_000
    assert chunks[-1].size == 300_000 - 262144


def test_plan_file_chunks_empty_file():
    (c,) = plan_file_chunks(0, 1 << 20)
    assert c.nbytes == 0


# -- round trips --------------------------------------------------------------

def test_roundtrip_multichunk_bit_exact(tmp_path):
    src, dst = str(tmp_path / "a/src.bin"), str(tmp_path / "b/dst.bin")
    _make_file(src)
    res = FileTransfer(_path(), record=False).copy(src, dst)
    assert res.n_chunks == 5 and res.sent == 5 and res.skipped == 0
    assert file_sha256(dst) == file_sha256(src) == res.sha256
    # mirror diffs compare mtime: the copy must preserve it
    assert abs(os.path.getmtime(src) - os.path.getmtime(dst)) < 1e-6
    # no droppings
    assert not os.path.exists(dst + PART_SUFFIX)
    assert not os.path.exists(dst + SIDECAR_SUFFIX)


def test_roundtrip_empty_file(tmp_path):
    src, dst = str(tmp_path / "e.bin"), str(tmp_path / "e.out")
    open(src, "wb").close()
    res = FileTransfer(_path(), record=False).copy(src, dst)
    assert res.nbytes == 0 and os.path.getsize(dst) == 0


def test_zlib_wire_compression_is_lossless(tmp_path):
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)
    res = FileTransfer(_path(compress="int8"), record=False).copy(src, dst)
    assert res.wire_bytes < res.nbytes          # the text half compresses
    assert file_sha256(dst) == file_sha256(src)  # and it is lossless


def test_copy_tree_directory_manifest(tmp_path):
    src, dst = str(tmp_path / "tree"), str(tmp_path / "mirror")
    _make_file(os.path.join(src, "a.bin"), 70_000)
    _make_file(os.path.join(src, "sub/b.bin"), 70_001, seed=1)
    results = FileTransfer(_path(), record=False).copy_tree(src, dst)
    assert len(results) == 2
    assert file_sha256(os.path.join(dst, "sub/b.bin")) == \
        file_sha256(os.path.join(src, "sub/b.bin"))


# -- resume -------------------------------------------------------------------

class _Interrupt(RuntimeError):
    pass


def test_resume_after_interrupt_skips_completed_chunks(tmp_path):
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)
    n_before_stop = 3
    seen: list[int] = []

    def interrupter(chunk, hop, payload):
        if len(seen) >= n_before_stop and chunk.leaf not in seen:
            raise _Interrupt()
        seen.append(chunk.leaf)
        return payload

    # streams=1: one ordered bucket, so exactly 3 chunks complete
    eng = FileTransfer(_path(streams=1), record=False,
                       fault_hook=interrupter)
    with pytest.raises(_Interrupt):
        eng.copy(src, dst)
    assert os.path.exists(dst + PART_SUFFIX)     # partial survives
    assert os.path.exists(dst + SIDECAR_SUFFIX)  # with its manifest

    eng.fault_hook = None
    res = eng.copy(src, dst)                     # resume
    assert res.skipped == n_before_stop          # nothing re-sent
    assert res.sent == res.n_chunks - n_before_stop
    assert file_sha256(dst) == file_sha256(src)
    assert not os.path.exists(dst + SIDECAR_SUFFIX)  # cleaned on completion


def test_resume_restarts_when_source_changed(tmp_path):
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)
    seen: list[int] = []

    def interrupter(chunk, hop, payload):
        if len(seen) >= 2 and chunk.leaf not in seen:
            raise _Interrupt()
        seen.append(chunk.leaf)
        return payload

    eng = FileTransfer(_path(streams=1), record=False,
                       fault_hook=interrupter)
    with pytest.raises(_Interrupt):
        eng.copy(src, dst)

    _make_file(src, seed=99)                     # new bytes, new mtime
    eng.fault_hook = None
    res = eng.copy(src, dst)
    assert res.skipped == 0                      # stale sidecar discarded
    assert file_sha256(dst) == file_sha256(src)


# -- checksums ----------------------------------------------------------------

def test_checksum_mismatch_requeues_chunk(tmp_path):
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)
    corrupted: list[int] = []

    def corrupt_once(chunk, hop, payload):
        if chunk.leaf == 2 and not corrupted:    # flip bytes on first pass
            corrupted.append(chunk.leaf)
            return b"\xff" + payload[1:]
        return payload

    res = FileTransfer(_path(), record=False,
                       fault_hook=corrupt_once).copy(src, dst)
    assert res.retries == 1                      # requeued exactly once
    assert file_sha256(dst) == file_sha256(src)  # and healed


def test_checksum_failure_exhausts_retries(tmp_path):
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)

    def always_corrupt(chunk, hop, payload):
        return b"\x00" * len(payload) if chunk.leaf == 0 else payload

    eng = FileTransfer(_path(), record=False, max_retries=2,
                       fault_hook=always_corrupt)
    with pytest.raises(ChecksumError):
        eng.copy(src, dst)


# -- multi-hop / facade -------------------------------------------------------

def test_filecopy_two_hop_roundtrip_resume_and_per_hop_report(tmp_path):
    """The PR acceptance path: a multi-chunk FileCopy over a 2-hop route
    round-trips bit-exact, resumes without re-sending completed chunks, and
    MPW.Report shows per-hop wire bytes for the transfer."""
    src, dst = str(tmp_path / "src.bin"), str(tmp_path / "dst.bin")
    _make_file(src)
    topo = cosmogrid_topology()
    mpw = MPW.Init()
    pid = mpw.CreateForwarder(topo, "tokyo", "espoo")   # no direct link
    mpw.setChunkSize(pid, 1 << 16)                      # multi-chunk
    path = mpw.path(pid)
    assert path.n_hops == 2

    res = mpw.FileCopy(pid, src, dst)
    assert res.n_chunks == 5
    assert file_sha256(dst) == file_sha256(src)         # bit-exact

    # per-hop wire bytes are visible in the report
    report = mpw.Report()
    for i in range(path.n_hops):
        hop = report[path.hop_key(i)]
        assert hop["total_bytes"] == res.hop_wire_bytes[i] > 0
        assert hop["plan"]["wire_bytes"] == res.hop_wire_bytes[i]
    assert "hops" in mpw.PathStats(pid)

    # interrupt a second transfer mid-flight, then resume through the verb
    seen: list[int] = []

    def interrupter(chunk, hop, payload):
        if len(seen) >= 2 and chunk.leaf not in seen:
            raise _Interrupt()
        if hop == 1:                                   # counted at final hop
            seen.append(chunk.leaf)
        return payload

    eng = FileTransfer(path.with_(streams=1), fault_hook=interrupter,
                       record=False)
    dst2 = str(tmp_path / "dst2.bin")
    with pytest.raises(_Interrupt):
        eng.copy(src, dst2)
    eng.fault_hook = None
    resumed = eng.copy(src, dst2)
    assert resumed.skipped == 2 and resumed.sent == 3   # no chunk re-sent
    assert file_sha256(dst2) == file_sha256(src)
    mpw.Finalize()


def test_filesend_filerecv_directions(tmp_path):
    src = str(tmp_path / "src.bin")
    _make_file(src, 70_000)
    mpw = MPW.Init()
    pid = mpw.CreatePath(nstreams=2, comm=CommConfig(streams=2,
                                                     chunk_mb=0.0625))
    out = mpw.FileSend(pid, src, str(tmp_path / "sent.bin"))
    back = mpw.FileRecv(pid, str(tmp_path / "sent.bin"),
                        str(tmp_path / "back.bin"))
    assert out.sha256 == back.sha256 == file_sha256(src)
    mpw.Finalize()


def test_file_transfers_feed_the_online_tuner(tmp_path):
    """File transfers tune with the same knobs as collectives: Observe
    (called by the File* verbs) advances the path's OnlineTuner."""
    src = str(tmp_path / "src.bin")
    _make_file(src, 150_000)
    mpw = MPW.Init()
    pid = mpw.CreatePath(comm=CommConfig(streams=1, chunk_mb=0.0625))
    mpw.setAutoTuning(pid, True, online=True, window=1)
    tuner = mpw.paths[pid].tuner
    assert tuner is not None
    for i in range(4):
        mpw.FileCopy(pid, src, str(tmp_path / f"d{i}.bin"))
    assert tuner.history                       # controller digested samples
    assert get_telemetry().path(mpw.path(pid).key).transfers >= 4
    # file timings carry no algo signal: the first file verb pins the algo
    # knob so noise cannot silently switch the path's collective algorithm
    assert tuner.tune_algo is False
    assert all("algo" not in cfg for cfg, _ in tuner.history)
    mpw.Finalize()


def test_file_verbs_revert_applied_algo_probe(tmp_path):
    """If an algo probe was already APPLIED to the path when the first file
    verb arrives, pinning must also revert the path — post-pin configs
    exclude 'algo', so nothing else would ever undo the probe."""
    src = str(tmp_path / "src.bin")
    _make_file(src, 70_000)
    mpw = MPW.Init()
    pid = mpw.CreatePath(comm=CommConfig(streams=1, chunk_mb=0.0625))
    mpw.setAutoTuning(pid, True, online=True, window=1)  # incumbent: psum
    # simulate Observe having applied an algo probe to the path
    mpw.paths[pid].path = mpw.path(pid).with_(algo="ring2")
    mpw.FileCopy(pid, src, str(tmp_path / "d.bin"))
    assert mpw.path(pid).comm.algo == "psum"    # reverted to the incumbent
    mpw.Finalize()


def test_datagather_verb_mirrors_and_prunes(tmp_path):
    src, dst = str(tmp_path / "data"), str(tmp_path / "mirror")
    _make_file(os.path.join(src, "keep.bin"), 70_000)
    _make_file(os.path.join(src, "old/drop.bin"), 70_000)
    mpw = MPW.Init()
    pid = mpw.CreatePath(comm=CommConfig(streams=2, chunk_mb=0.0625))
    g = mpw.DataGather(pid, src, dst, start=False)
    assert g.transfer.digest is False   # mirror discards results: no
    assert g.sync() == 2                # second full read per file
    import shutil
    shutil.rmtree(os.path.join(src, "old"))
    g.sync()
    assert not os.path.exists(os.path.join(dst, "old"))
    assert os.path.isfile(os.path.join(dst, "keep.bin"))
    mpw.Finalize()


# -- checkpoint restart-from-replica ------------------------------------------

def test_manager_restores_from_replica_after_primary_loss(tmp_path):
    """Whole-pod loss: the primary checkpoint dir is gone, the DataGather
    replica (shipped over a WidePath) is what the restart restores from."""
    import numpy as np

    from repro.checkpoint import CheckpointManager, store

    primary, replica = str(tmp_path / "ckpt"), str(tmp_path / "replica")
    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
             "b": np.ones(3, dtype=np.float32)}
    mgr = CheckpointManager(primary, replica_dir=replica)
    mgr.save(10, state)
    # replicate_now serializes against the background gatherer (which may
    # already have mirrored everything on start): after it returns, the
    # mirror is current either way
    mgr.replicate_now()
    assert os.path.isdir(os.path.join(replica, "step_00000010"))
    mgr.close()

    import shutil
    shutil.rmtree(primary)                      # the pod's storage is gone
    mgr2 = CheckpointManager(primary, replica_dir=replica)
    assert mgr2.latest_step() is None           # primary really is empty
    assert mgr2.has_checkpoint()                # but the replica is not
    like = {"w": np.zeros((3, 4), np.float32), "b": np.zeros(3, np.float32)}
    restored, manifest = mgr2.restore(like)
    assert manifest["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
    mgr2.close()
    # replica checkpoints carry no transfer droppings
    step_dir = os.path.join(replica, "step_00000010")
    assert os.path.exists(os.path.join(step_dir, store.MANIFEST))
    assert not [f for f in os.listdir(step_dir)
                if f.endswith((PART_SUFFIX, SIDECAR_SUFFIX))]
