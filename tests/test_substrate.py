"""Optimizer, schedule, data pipeline, checkpoint store/manager/DataGather."""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("sub", max_examples=15, deadline=None)
    settings.load_profile("sub")
except ImportError:  # property tests skip; deterministic tests still run
    from conftest import given, st  # noqa: F401

from repro.checkpoint import CheckpointManager, DataGather, restore, save, sync_once
from repro.configs.base import TrainConfig
from repro.data import DataConfig, Prefetcher, SyntheticLM, make_pipeline
from repro.optim import adamw_update, init_opt_state, lr_at


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(120):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(g, opt, params, tc, jnp.float32(0.05))
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_bounds_update():
    tc = TrainConfig(lr=1.0, weight_decay=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    opt = init_opt_state(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, stats = adamw_update(huge, opt, params, tc, jnp.float32(1.0))
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


@given(step=st.integers(0, 2000))
def test_lr_schedule_bounds(step):
    tc = TrainConfig(lr=3e-4, warmup_steps=100, total_steps=1000, min_lr_ratio=0.1)
    lr = float(lr_at(step, tc))
    assert 0.0 <= lr <= tc.lr + 1e-9
    if step >= tc.total_steps:
        assert lr == pytest.approx(tc.lr * tc.min_lr_ratio, rel=1e-3)


def test_lr_warmup_monotone():
    tc = TrainConfig(lr=1e-3, warmup_steps=50, total_steps=500)
    lrs = [float(lr_at(s, tc)) for s in range(0, 50, 5)]
    assert all(b >= a for a, b in zip(lrs, lrs[1:]))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_learnable_and_deterministic():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=4, seed=7, noise=0.0)
    it1, it2 = iter(SyntheticLM(cfg)), iter(SyntheticLM(cfg))
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 33) and b1.dtype == np.int32
    # affine recurrence: t_{i+1} = (a t_i + b) % V for SOME (a,b) per row
    row = b1[0].astype(np.int64)
    found = any(((a * row[:-1] + b) % 97 == row[1:]).all()
                for a in [3, 5, 7, 11, 13] for b in range(17))
    assert found, "documents must follow a learnable recurrence"


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8)
    a = next(iter(SyntheticLM(cfg, host_id=0, host_count=2)))
    b = next(iter(SyntheticLM(cfg, host_id=1, host_count=2)))
    assert a.shape[0] == 4 and b.shape[0] == 4
    assert not np.array_equal(a, b)


def test_prefetcher_delivers():
    cfg = DataConfig(vocab_size=11, seq_len=4, global_batch=2)
    pf = Prefetcher(iter(SyntheticLM(cfg)), depth=2)
    xs = [next(pf) for _ in range(3)]
    pf.close()
    assert all(x.shape == (2, 5) for x in xs)


def test_binary_pipeline(tmp_path):
    toks = np.arange(900, dtype=np.uint16) % 100
    p = tmp_path / "tokens.bin"
    toks.tofile(p)
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2, kind="binary",
                     path=str(p))
    batch = next(iter(make_pipeline(cfg, prefetch=0)))
    assert batch.shape == (2, 9)
    np.testing.assert_array_equal(batch[0], toks[:9].astype(np.int32))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(2048, dtype=jnp.float32).reshape(32, 64),
            "nested": {"b": jnp.ones((7,), jnp.bfloat16),
                       "c": jnp.int32(5)}}


def test_store_roundtrip_chunked(tmp_path):
    t = _tree()
    save(t, str(tmp_path / "ck"), step=3, chunk_mb=0.001, streams=4)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, manifest = restore(str(tmp_path / "ck"), like)
    assert manifest["step"] == 3
    for k in ("a",):
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(t[k]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"], np.float32),
                                  np.asarray(t["nested"]["b"], np.float32))
    # multi-chunk: leaf a is 8KB with 1KB chunks
    files = os.listdir(tmp_path / "ck")
    assert sum(f.startswith("leaf00000") for f in files) >= 8


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, chunk_mb=1)
    for s in (1, 2, 3):
        m.save(s, {"x": jnp.float32(s)})
    assert m.latest_step() == 3
    assert m.steps() == [2, 3]
    out, man = m.restore({"x": jax.ShapeDtypeStruct((), jnp.float32)})
    assert float(out["x"]) == 3.0
    m.close()


def test_datagather_mirrors(tmp_path):
    src, dst = tmp_path / "src", tmp_path / "dst"
    os.makedirs(src / "sub")
    (src / "a.bin").write_bytes(b"hello")
    (src / "sub" / "b.bin").write_bytes(b"world")
    n = sync_once(str(src), str(dst))
    assert n == 2
    assert (dst / "a.bin").read_bytes() == b"hello"
    (src / "a.bin").write_bytes(b"hello2")
    os.remove(src / "sub" / "b.bin")
    sync_once(str(src), str(dst))
    assert (dst / "a.bin").read_bytes() == b"hello2"
    assert not (dst / "sub" / "b.bin").exists()


def test_datagather_thread(tmp_path):
    src, dst = str(tmp_path / "s"), str(tmp_path / "d")
    os.makedirs(src)
    g = DataGather(src, dst, interval_s=0.05).start()
    with open(os.path.join(src, "x"), "w") as f:
        f.write("1")
    time.sleep(0.3)
    g.stop()
    assert os.path.exists(os.path.join(dst, "x"))
