"""Elastic-membership acceptance: evict -> resize -> retune -> recover.

The deterministic scenario ISSUE 8 pins: a 4-site local-SGD run loses a
site mid-run (its only link drops), completes with a bumped epoch and a
re-formed 3-site gateway subgroup, and the evicted site rejoins later via
replica catch-up without perturbing the survivors.  Run twice in one
process to assert bit-identical timelines and losses (CI's `elastic` job
re-runs the whole test back-to-back for cross-process determinism).
"""
from __future__ import annotations

import pytest

pytestmark = pytest.mark.slow


# The amsterdam-tokyo link (tokyo's only link on the star topology) dies
# at step 6 and heals at step 14.  lease_steps=2 -> suspect at 6, evict
# at 8; rejoin_after=2 -> join at 15.
_ELASTIC_SCENARIO = """
import json
import jax
from repro.configs import (get_config, smoke_config, RunConfig, ShapeConfig,
                           CommConfig, TrainConfig)
from repro.runtime import Trainer
from repro.core import cosmogrid_topology, get_incident_log
from repro.core.membership import SiteMembership
from repro.data import DataConfig, make_pipeline

STEPS, FAULT, HEAL = 20, 6, 14

def build():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=4,
                                   chunk_mb=0.01, autotune=False,
                                   local_steps=4),
                   train=TrainConfig(zero1=True, warmup_steps=2,
                                     total_steps=50))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8), prefetch=0)
    return rc, data

mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)

def run_chaos():
    log = get_incident_log(); log.clear()
    t = cosmogrid_topology()   # star: tokyo only reachable via amsterdam
    for a, b in (("amsterdam", "tokyo"), ("tokyo", "amsterdam")):
        t.connect(a, b, t.link(a, b).drop(FAULT, until=HEAL))
    mem = SiteMembership(t, "amsterdam", lease_steps=2, rejoin_after=2)
    rc, data = build()
    with jax.set_mesh(mesh):
        tr = Trainer(rc, mesh, route=t.route("amsterdam", "espoo"),
                     site_groups=t.pod_groups(), membership=mem)
        tr.init_or_restore()
        hist = tr.run(data, STEPS, log_every=0)
    tl = [[e.kind, e.subject, e.step] for e in log.events()]
    details = {}
    for e in log.events():
        details.setdefault(e.kind, e.detail)   # first event of each kind
    return mem, tl, details, [h["loss"] for h in hist]

mem1, tl1, det1, loss1 = run_chaos()
mem2, tl2, det2, loss2 = run_chaos()

# 3-site fault-free baseline: tokyo pre-evicted, its link down for good
log = get_incident_log(); log.clear()
t3 = cosmogrid_topology()
for a, b in (("amsterdam", "tokyo"), ("tokyo", "amsterdam")):
    t3.connect(a, b, t3.link(a, b).drop(0))
mem3 = SiteMembership(t3, "amsterdam", lease_steps=2)
mem3.evict("tokyo", 0, reason="baseline")
rcb, datab = build()
with jax.set_mesh(mesh):
    trb = Trainer(rcb, mesh, route=t3.route("amsterdam", "espoo"),
                  site_groups=t3.pod_groups(), membership=mem3)
    trb.init_or_restore()
    histb = trb.run(datab, STEPS, log_every=0)

print("RESULT:" + json.dumps({
    "epoch": mem1.epoch,
    "timeline": tl1,
    "identical_runs": tl1 == tl2 and loss1 == loss2,
    "members": mem1.members(),
    "resize_members": det1.get("resize", {}).get("members"),
    "catchup": det1.get("catchup", {}),
    "losses": loss1,
    "baseline_final": histb[-1]["loss"],
    "baseline_epoch": mem3.epoch,
}))
"""


@pytest.fixture(scope="module")
def scenario(multidev):
    return multidev(_ELASTIC_SCENARIO, ndev=8)


def test_evict_rejoin_timeline_is_golden(scenario):
    assert scenario["timeline"] == [
        ["detect", "tokyo", 6],                               # lease clock
        ["evict", "tokyo", 8],                                # lease expired
        ["resize", "amsterdam,espoo,edinburgh", 8],           # 3-site world
        ["retune", "train:ams-espoo", 8],
        ["recover", "amsterdam,espoo,edinburgh", 8],
        ["join", "tokyo", 15],                                # link healed
        ["resize", "amsterdam,tokyo,espoo,edinburgh", 15],
        ["catchup", "tokyo", 15],                             # replica clone
        ["retune", "train:ams-espoo", 15],
        ["recover", "amsterdam,tokyo,espoo,edinburgh", 15],
    ]


def test_epoch_bumps_once_per_resize(scenario):
    assert scenario["epoch"] == 2          # one evict + one rejoin
    assert scenario["members"] == ["amsterdam", "tokyo", "espoo", "edinburgh"]


def test_world_reforms_as_three_site_subgroup(scenario):
    # the delta-sync subgroup after the evict is the 3 surviving gateways
    assert scenario["resize_members"] == ["amsterdam", "espoo", "edinburgh"]


def test_rejoin_catches_up_from_a_survivor(scenario):
    # catch-up clones a surviving gateway's params onto tokyo's pods; the
    # survivors' params pass through the broadcast bit-untouched
    assert scenario["catchup"].get("source") == "amsterdam"
    assert scenario["catchup"].get("pods")


def test_run_is_deterministic_and_losses_stay_sane(scenario):
    assert scenario["identical_runs"]      # timelines AND losses, twice
    losses = scenario["losses"]
    assert all(l == l for l in losses), losses          # no NaNs anywhere
    # the resized run's final loss lands within tolerance of the 3-site
    # fault-free baseline (same seed, tokyo never a member)
    assert abs(losses[-1] - scenario["baseline_final"]) < 0.25
    # the baseline really was 3-site throughout: no rejoin happened
    assert scenario["baseline_epoch"] == 1
