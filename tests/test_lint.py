"""mpwlint's own test coverage.

One bad/good fixture twin per rule (R1..R5): the bad snippet must fire and
the good twin must stay silent — deleting any rule's implementation breaks
its bad-fixture test.  Layer 2 (S1..S4) is pinned by running the real
verifier against the live planners, plus seeded-violation twins.  The
end-to-end test asserts `src/` is clean with an empty baseline, gating the
pass in tier-1 forever.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.mpwlint.engine import lint_paths  # noqa: E402
from tools.mpwlint.findings import (Finding, is_suppressed,  # noqa: E402
                                    load_baseline, suppressed_rules,
                                    write_baseline)
from tools.mpwlint.rules import RULES, audit_mpw_verbs, build_context  # noqa: E402
from tools.mpwlint import semantic  # noqa: E402


def run_rule(rule_id: str, source: str, relpath: str = "src/repro/core/x.py"):
    ctx = build_context(relpath, textwrap.dedent(source))
    findings = RULES[rule_id](ctx)
    return [f for f in findings if not is_suppressed(f, ctx.lines)]


# -- R1: traced purity --------------------------------------------------------

R1_BAD = """
    import time, jax

    @jax.jit
    def step(x):
        t0 = time.perf_counter()
        return x + t0
"""

R1_GOOD = """
    import time, jax

    @jax.jit
    def step(x):
        return x * 2

    def host_timer():
        return time.perf_counter()
"""


def test_r1_fires_on_host_call_under_jit():
    found = run_rule("R1", R1_BAD)
    assert any(f.rule == "R1" and "time.perf_counter" in f.message
               for f in found)


def test_r1_silent_on_pure_jit_and_host_code():
    assert run_rule("R1", R1_GOOD) == []


def test_r1_fires_on_self_mutation_in_custom_vjp():
    src = """
        import jax

        @jax.custom_vjp
        def hook(self, x):
            self.count += 1
            return x
    """
    found = run_rule("R1", src)
    assert any("self.count" in f.message for f in found)


def test_r1_fires_on_scanned_function():
    src = """
        import jax

        def body(carry, x):
            open("/tmp/log").write("hi")
            return carry, x

        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """
    found = run_rule("R1", src)
    assert any("open" in f.message for f in found)


def test_r1_fires_on_partial_jit_decorator():
    src = """
        import random, jax
        from functools import partial

        @partial(jax.jit, static_argnums=0)
        def step(n, x):
            return x + random.random()
    """
    assert any("random.random" in f.message for f in run_rule("R1", src))


# -- R2: lock discipline ------------------------------------------------------

R2_BAD = """
    import threading

    class Mirror:
        def __init__(self):
            self._lock = threading.Lock()
            self.total = 0
            self._thread = threading.Thread(target=self._run)

        def _run(self):
            self.total += 1

        def reset(self):
            self.total = 0
"""

R2_GOOD = """
    import threading

    class Mirror:
        def __init__(self):
            self._lock = threading.Lock()
            self.total = 0
            self._thread = threading.Thread(target=self._run)

        def _run(self):
            with self._lock:
                self.total += 1

        def reset(self):
            with self._lock:
                self.total = 0
"""


def test_r2_fires_on_unguarded_shared_write():
    found = run_rule("R2", R2_BAD)
    assert any(f.rule == "R2" and "Mirror.total" in f.message for f in found)


def test_r2_silent_when_writes_are_lock_guarded():
    assert run_rule("R2", R2_GOOD) == []


def test_r2_ignores_modules_without_threads_or_locks():
    src = R2_BAD.replace("import threading", "").replace(
        "self._lock = threading.Lock()", "pass").replace(
        "self._thread = threading.Thread(target=self._run)", "pass")
    assert run_rule("R2", src) == []


def test_r2_single_writer_attrs_are_fine():
    src = """
        import threading

        class Worker:
            def __init__(self):
                self.t = None

            def start(self):
                self.t = threading.Thread(target=print)
    """
    # `t` is written in __init__ + one method: that IS two methods, so the
    # post-construction write must be guarded
    assert any("Worker.t" in f.message for f in run_rule("R2", src))
    solo = """
        import threading

        class Worker:
            def start(self):
                self.t = threading.Thread(target=print)
    """
    assert run_rule("R2", solo) == []


# -- R3: typed errors ---------------------------------------------------------

def test_r3_fires_on_bare_assert():
    found = run_rule("R3", "def f(n):\n    assert n > 0\n")
    assert any("bare `assert`" in f.message for f in found)


def test_r3_silent_on_typed_raise():
    src = """
        def f(n):
            if n <= 0:
                raise ValueError(f"n must be > 0, got {n}")
    """
    assert run_rule("R3", src) == []


def test_r3_fires_on_constant_valueerror_in_core():
    src = 'def f(n):\n    raise ValueError("bad value")\n'
    found = run_rule("R3", src, relpath="src/repro/core/x.py")
    assert any("constant message" in f.message for f in found)
    # outside core/ the constant-message check does not apply
    assert run_rule("R3", src, relpath="src/repro/runtime/x.py") == []


# -- R4: telemetry keys -------------------------------------------------------

def test_r4_fires_on_off_grammar_key():
    src = 'def f(tel, key, i):\n    tel.record(f"{key}/leg{i}", 1.0)\n'
    found = run_rule("R4", src)
    assert any("{}/leg{}" in f.message for f in found)


def test_r4_silent_on_documented_grammar():
    src = """
        def f(tel, key, i, leg):
            tel.record(f"{key}/hop{i}:{leg}", 1.0)
            tel.note_plan(f"{key}/bkt{i}", payload_bytes=0)
            tel.record(f"{key}/intra", 1.0)
            tel.record(f"{key}/wan", 1.0)
            tel.record(key, 1.0)
            tel.record("ckpt:interpod", 1.0)
            g(tel_key=f"{key}/bkt{i}")
    """
    assert run_rule("R4", src) == []


def test_r4_checks_tel_key_kwarg():
    src = 'def f(g, key):\n    g(tel_key=f"{key}-oops")\n'
    assert any(f.rule == "R4" for f in run_rule("R4", src))


def test_r4_fires_on_misspelled_incident_kind():
    src = 'def f(log, step, rid):\n    log.add(step, "resheep", f"req{rid}")\n'
    found = run_rule("R4", src)
    assert any("resheep" in f.message for f in found)


def test_r4_silent_on_vocabulary_incident_kinds():
    src = """
        def f(log, step, rid):
            log.add(step, "reship", f"req{rid}")
            log.add(step, "reroute", f"req{rid}")
            log.add(step, "serve_failover", "decode:a->b")
            log.add(step, "degrade", "serve")
            log.add(step, "timeout", f"req{rid}")
            log.add(step, "shed", f"req{rid}")
    """
    assert run_rule("R4", src) == []


def test_r4_ignores_set_add_and_dynamic_kinds():
    src = """
        def f(log, seen, step, kind, rid):
            seen.add(rid)
            log.add(step, kind, f"req{rid}")
    """
    assert run_rule("R4", src) == []


def test_r4_mpw_verb_audit_fires_on_undocumented_verb(tmp_path):
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "docs").mkdir()
    (tmp_path / "src/repro/core/api.py").write_text(textwrap.dedent("""
        class MPW:
            def Send(self, x):
                return x

            def Mystery(self, x):
                return x
    """))
    (tmp_path / "docs/api.md").write_text("| `Send(x)` | ships x |\n")
    found = audit_mpw_verbs(tmp_path)
    assert [f for f in found if "Mystery" in f.message]
    assert not [f for f in found if "`Send`" in f.message]


def test_r4_mpw_verb_audit_clean_on_this_repo():
    assert audit_mpw_verbs(REPO) == []


# -- R5: core determinism -----------------------------------------------------

def test_r5_fires_on_wall_clock_in_core():
    src = "import time\n\ndef f():\n    return time.monotonic()\n"
    found = run_rule("R5", src, relpath="src/repro/core/x.py")
    assert any("wall-clock" in f.message for f in found)


def test_r5_fires_on_unseeded_rng_in_core():
    src = ("import numpy as np\n\ndef f():\n"
           "    return np.random.default_rng().random()\n")
    found = run_rule("R5", src, relpath="src/repro/core/x.py")
    assert any("RNG" in f.message for f in found)


def test_r5_silent_on_seeded_rng_and_outside_core():
    seeded = ("import numpy as np\n\ndef f(seed):\n"
              "    return np.random.default_rng(seed).random()\n")
    assert run_rule("R5", seeded, relpath="src/repro/core/x.py") == []
    clock = "import time\n\ndef f():\n    return time.monotonic()\n"
    assert run_rule("R5", clock, relpath="src/repro/runtime/x.py") == []


# -- R6: retry loops route through RetryPolicy --------------------------------

R6_BAD = """
    import time

    def fetch(sock):
        while True:
            try:
                return sock.recv()
            except OSError:
                time.sleep(0.5)
                continue
"""

R6_GOOD = """
    from repro.core.retry import RetryPolicy, RetryState

    def fetch(sock):
        retry = RetryState(RetryPolicy(max_attempts=3))
        while True:
            try:
                return sock.recv()
            except OSError:
                if retry.next_delay_s() is None:
                    raise
                continue
"""


def test_r6_fires_on_continue_from_except_in_while():
    found = run_rule("R6", R6_BAD)
    assert any(f.rule == "R6" and "except" in f.message for f in found)


def test_r6_fires_on_sleep_backoff_in_retry_loop():
    found = run_rule("R6", R6_BAD)
    assert any("time.sleep" in f.message for f in found)


def test_r6_silent_when_routed_through_retrypolicy():
    assert run_rule("R6", R6_GOOD) == []


def test_r6_silent_outside_src_and_on_plain_loops():
    # same hand-rolled loop outside src/ (tests, tools) is not our business
    assert run_rule("R6", R6_BAD, relpath="tools/x.py") == []
    # a while loop whose continue is plain control flow, not error-swallowing
    plain = """
        def drain(q):
            while q:
                item = q.pop()
                if item is None:
                    continue
                handle(item)
    """
    assert run_rule("R6", plain) == []
    # a sleep in a poll loop with no try/except is pacing, not retry
    poll = """
        import time

        def wait_for(flag):
            while not flag():
                time.sleep(0.1)
    """
    assert run_rule("R6", poll) == []


def test_r6_continue_in_nested_for_does_not_blame_the_while():
    # the `continue` targets the inner for-loop, which has no try around it
    src = """
        def pump(jobs):
            while jobs:
                try:
                    jobs = refresh(jobs)
                except KeyError:
                    jobs = []
                for j in jobs:
                    if j.done:
                        continue
                    run(j)
    """
    assert run_rule("R6", src) == []


# -- suppressions and baseline ------------------------------------------------

def test_inline_suppression_silences_one_rule():
    src = ("import time\n\ndef f():\n"
           "    return time.monotonic()    # mpwlint: disable=R5\n")
    assert run_rule("R5", src, relpath="src/repro/core/x.py") == []
    assert suppressed_rules("x = 1  # mpwlint: disable=R1,R5") == {"R1", "R5"}
    # a suppression for a different rule does not silence this one
    other = ("import time\n\ndef f():\n"
             "    return time.monotonic()    # mpwlint: disable=R1\n")
    assert run_rule("R5", other, relpath="src/repro/core/x.py") != []


def test_baseline_roundtrip_waives_known_findings(tmp_path):
    f = Finding("R5", "src/repro/core/x.py", 3, "wall-clock read", "fix it")
    baseline = tmp_path / "baseline.json"
    write_baseline(baseline, [f])
    keys = load_baseline(baseline)
    assert f.key in keys
    moved = Finding("R5", "src/repro/core/x.py", 99, "wall-clock read", "")
    assert moved.key in keys        # line moves don't invalidate the waiver
    assert Finding("R5", "src/repro/core/x.py", 3, "other", "").key not in keys


# -- Layer 2: semantic verifier ----------------------------------------------

def test_semantic_chunk_coverage_clean():
    assert semantic.check_chunk_coverage() == []
    assert semantic.check_file_chunk_coverage() == []


def test_semantic_wire_bound_clean():
    assert semantic.check_wire_bound() == []


def test_semantic_routes_clean():
    assert semantic.check_route_soundness() == []


def test_semantic_buckets_clean():
    assert semantic.check_bucket_contracts() == []


def test_semantic_wire_bound_catches_violation(monkeypatch):
    from repro.core import ring as real_ring
    monkeypatch.setattr(real_ring, "wire_bytes_per_pod",
                        lambda payload, world, algo="psum", compress="none":
                        float(payload) * 2.0 * max(1, world))
    assert semantic.check_wire_bound() != []


def test_semantic_routes_catch_dead_hop(monkeypatch):
    # a topology that ignores fail_link() must be caught as a dead hop
    from repro.core import topology as topo_mod
    monkeypatch.setattr(topo_mod.Topology, "fail_link",
                        lambda self, a, b, bidirectional=True: None)
    findings = semantic.check_route_soundness()
    assert any("dead hop" in f.message for f in findings)


# -- end to end ---------------------------------------------------------------

def test_src_is_clean_ast_rules():
    """Layer 1 over the real src/ tree: zero findings, empty baseline."""
    findings = lint_paths(["src"], REPO)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert load_baseline(REPO / "tools/mpwlint/baseline.json") == set()


def test_cli_end_to_end_json_exit_codes(tmp_path):
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "tools.mpwlint", "src", "--format=json",
         "--no-semantic"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    report = json.loads(out.stdout)
    assert report["count"] == 0

    bad = tmp_path / "bad.py"
    bad.write_text("def f(n):\n    assert n > 0\n")
    out = subprocess.run(
        [sys.executable, "-m", "tools.mpwlint", str(bad), "--format=json",
         "--no-semantic"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 1
    report = json.loads(out.stdout)
    assert report["count"] == 1
    assert report["findings"][0]["rule"] == "R3"


@pytest.mark.slow
def test_cli_full_run_including_semantic():
    out = subprocess.run(
        [sys.executable, "-m", "tools.mpwlint", "src"],
        cwd=REPO, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stdout
