"""Serving-tier acceptance suite (ISSUE 9).

1. Disaggregated prefill-site/decode-site serving produces **bit-identical**
   tokens to monolithic single-site serving for the same seed (decode is
   row-local and the ``none`` codec ships bytes unchanged).
2. Golden schedule: a seeded 8-request trace through the continuous batcher
   yields a pinned admit/prefill/ship/decode/complete timeline, identical
   across two runs (same style as test_chaos/test_elastic).
3. KV-ship byte accounting: telemetry wire bytes under ``serve/req{id}/kv``
   exactly equal the planned KV leaf bytes, per hop, for every codec.
4. The `Server.generate` bugfix: per-sequence positions/budgets with EOS
   early-exit, and `_warm_shapes` keyed on cache geometry too.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest

from repro.configs import (CommConfig, RunConfig, ShapeConfig, TrainConfig,
                           get_config, smoke_config)
from repro.core.api import MPW
from repro.core.kvship import kv_cache_bytes, plan_kv_ship, ship_kv
from repro.core.path import (WAN_LONDON_POZNAN, WAN_POZNAN_GDANSK, Hop,
                             WidePath)
from repro.core.serving import ContinuousBatcher
from repro.core.telemetry import get_telemetry

GOLDEN_TRACE = [(0, 8, 3), (0, 16, 2), (1, 8, 4), (2, 8, 1), (2, 24, 2),
                (3, 8, 2), (3, 8, 3), (4, 8, 2)]

GOLDEN_TIMELINE = [
    ["admit", "req0", 0], ["admit", "req1", 0], ["prefill", "req0", 0],
    ["admit", "req2", 1], ["ship", "req0", 1], ["prefill", "req1", 1],
    ["admit", "req3", 2], ["admit", "req4", 2], ["decode", "req0", 2],
    ["admit", "req5", 3], ["reject", "req6", 3], ["ship", "req1", 3],
    ["reject", "req7", 4], ["decode", "req1", 4], ["complete", "req0", 4],
    ["prefill", "req2", 4], ["ship", "req2", 5], ["complete", "req1", 5],
    ["prefill", "req3", 5], ["ship", "req3", 6], ["decode", "req2", 6],
    ["decode", "req3", 7], ["complete", "req3", 7], ["prefill", "req4", 7],
    ["complete", "req2", 9], ["ship", "req4", 10], ["prefill", "req5", 10],
    ["ship", "req5", 11], ["decode", "req4", 11], ["decode", "req5", 12],
    ["complete", "req4", 12], ["complete", "req5", 13],
]


def _golden_batcher() -> ContinuousBatcher:
    return ContinuousBatcher(
        2, 4, prefill_steps=lambda r: max(1, r.prompt_len // 8),
        ship_steps=1, step_s=1e-2)


# ---------------------------------------------------------------------------
# golden schedule (pure core, no devices)
# ---------------------------------------------------------------------------

def test_golden_schedule_timeline_and_stats():
    b = _golden_batcher()
    stats = b.run(GOLDEN_TRACE)
    assert b.timeline() == GOLDEN_TIMELINE
    assert stats["completed"] == 6
    assert stats["rejected"] == 2
    assert stats["total_tokens"] == 14
    assert stats["latency_p50_s"] == pytest.approx(0.065)
    assert stats["latency_p99_s"] == pytest.approx(0.1)
    assert stats["ttft_p50_s"] == pytest.approx(0.05)
    assert stats["ttft_p99_s"] == pytest.approx(0.09)
    assert stats["goodput_tok_s"] == pytest.approx(100.0)


def test_golden_schedule_run_twice_identical():
    runs = []
    for _ in range(2):
        b = _golden_batcher()
        stats = b.run(GOLDEN_TRACE)
        runs.append((b.timeline(), stats))
    assert runs[0] == runs[1]


def test_mpw_serve_verbs_drive_the_same_schedule():
    mpw = MPW.Init()
    pid = mpw.CreatePath(link=WAN_LONDON_POZNAN)
    b = mpw.Serve(pid, max_slots=2, queue_limit=4,
                  prefill_steps=lambda r: max(1, r.prompt_len // 8),
                  ship_steps=1, step_s=1e-2)
    for step, plen, mnew in GOLDEN_TRACE:
        while b.now() < step:
            b.step_once()
        mpw.Admit(pid, plen, mnew)
    out = mpw.ServeStats(pid, drain=True)
    assert out["timeline"] == GOLDEN_TIMELINE
    assert out["completed"] == 6 and out["rejected"] == 2
    mpw.Finalize()


def test_mpw_serve_kv_bytes_model():
    mpw = MPW.Init()
    pid = mpw.CreatePath(link=WAN_LONDON_POZNAN)
    cfg = get_config("llama3.2-3b")
    per_req = lambda r: kv_cache_bytes(cfg.num_layers, cfg.num_kv_heads,
                                       cfg.resolved_head_dim, r.prompt_len)
    b = mpw.Serve(pid, max_slots=2, kv_bytes=per_req, step_s=25e-3)
    assert mpw.Admit(pid, 256, 2) == 0
    stats = mpw.ServeStats(pid)
    assert stats["completed"] == 1
    # a 256-token KV cache on a 125 MB/s WAN link takes real ship steps:
    # TTFT must exceed the 2 virtual steps of admit->prefill alone
    assert stats["ttft_p50_s"] > 2 * 25e-3
    pid2 = mpw.CreatePath(link=WAN_LONDON_POZNAN)
    with pytest.raises(ValueError, match="no serving scheduler"):
        mpw.Admit(pid2, 8, 1)


# ---------------------------------------------------------------------------
# KV-ship byte accounting (planner + codecs, no devices)
# ---------------------------------------------------------------------------

def _two_hop_path(compress: str = "none") -> WidePath:
    comm = CommConfig(streams=4, chunk_mb=0.001, compress=compress)
    hops = (Hop(name="hop0-lon-poz", link=WAN_LONDON_POZNAN, comm=comm),
            Hop(name="hop1-poz-gda", link=WAN_POZNAN_GDANSK, comm=comm))
    return WidePath(axis="pod", comm=comm, name="kvship").with_hops(hops)


@pytest.mark.parametrize("compress", ["none", "bf16", "int8"])
def test_kv_ship_exact_wire_bytes_per_hop(compress):
    rng = np.random.default_rng(3)
    kv = {"k": rng.standard_normal((4, 24, 2, 8)).astype(np.float32),
          "v": rng.standard_normal((4, 24, 2, 8)).astype(np.float32)}
    path = _two_hop_path(compress)
    plan = plan_kv_ship(kv, path)
    assert plan.payload_bytes == sum(a.nbytes for a in kv.values())
    if compress == "none":
        assert plan.wire_bytes_hop == plan.payload_bytes
    tel = get_telemetry()
    rid = {"none": 900, "bf16": 901, "int8": 902}[compress]
    key = f"serve/req{rid}/kv"
    tel.reset(key)
    out, res = ship_kv(kv, plan, rid)
    # telemetry wire bytes == planned wire bytes, end-to-end and per hop
    assert res.wire_bytes_hop == plan.wire_bytes_hop
    assert res.wire_bytes_total == plan.wire_bytes_hop * 2
    assert tel.path(key).total_bytes == plan.wire_bytes_hop * 2
    for i, hop in enumerate(path.route):
        hop_key = f"{key}/hop{i}:{hop.name}"
        assert tel.path(hop_key).total_bytes == plan.wire_bytes_hop, hop_key
    if compress == "none":
        # the none codec is bit-identical across the whole route
        for n in kv:
            np.testing.assert_array_equal(out[n], kv[n])
    else:
        for n in kv:
            assert out[n].shape == kv[n].shape
            np.testing.assert_allclose(out[n], kv[n], atol=0.2)


def test_kv_ship_plan_rejects_geometry_drift():
    kv = {"k": np.zeros((4, 8, 2, 8), np.float32),
          "v": np.zeros((4, 8, 2, 8), np.float32)}
    plan = plan_kv_ship(kv, _two_hop_path())
    grown = {"k": np.zeros((4, 9, 2, 8), np.float32), "v": kv["v"]}
    with pytest.raises(ValueError, match="re-plan on cache-geometry change"):
        ship_kv(grown, plan, 903)


def test_kv_cache_bytes_formula():
    # bf16 k+v leaves: 2 bytes * 2 leaves * nL * S * KH * Dh
    assert kv_cache_bytes(4, 2, 32, 8) == 2 * 2 * 4 * 8 * 2 * 32


# ---------------------------------------------------------------------------
# disaggregated vs monolithic engine parity (real model, single process)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_rc():
    from repro.launch.mesh import make_local_mesh
    cfg = smoke_config(get_config("llama3.2-3b"))
    rc = RunConfig(model=cfg, shape=ShapeConfig("d", 64, 3, "decode"),
                   comm=CommConfig(), train=TrainConfig())
    return rc, make_local_mesh()


def _requests(cfg, seed=7):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, size=int(pl)), int(mn))
            for pl, mn in [(8, 5), (12, 3), (8, 7), (16, 4), (12, 6)]]


def test_disagg_bit_identical_to_mono(serve_rc):
    from repro.runtime.serving import ServingEngine
    rc, mesh = serve_rc
    reqs = _requests(rc.model)
    wan = WidePath(axis="pod", comm=CommConfig(streams=4, chunk_mb=0.001),
                   link=WAN_LONDON_POZNAN, name="kvship")
    engines = {}
    for mode, path in (("mono", None), ("disagg", wan)):
        eng = ServingEngine(rc, mesh, mode=mode, path=path, seed=0)
        for prompt, mnew in reqs:
            assert eng.submit(prompt, mnew) is not None
        stats = eng.run_to_completion()
        assert stats["completed"] == len(reqs)
        engines[mode] = eng
    mono, disagg = engines["mono"], engines["disagg"]
    # same schedule, and every request's tokens bit-identical
    assert mono.batcher.timeline() == disagg.batcher.timeline()
    assert sorted(mono.results) == sorted(disagg.results)
    for rid in mono.results:
        np.testing.assert_array_equal(mono.results[rid], disagg.results[rid])
        assert len(mono.results[rid]) == reqs[rid][1]   # max_new honored


def test_disagg_engine_telemetry_byte_accounting(serve_rc):
    from repro.runtime.serving import ServingEngine
    rc, mesh = serve_rc
    cfg = rc.model
    wan = WidePath(axis="pod", comm=CommConfig(streams=4, chunk_mb=0.001),
                   link=WAN_LONDON_POZNAN, name="kvship")
    tel = get_telemetry()
    eng = ServingEngine(rc, mesh, mode="disagg", path=wan, seed=0)
    reqs = _requests(cfg)
    # rids restart at 0 per batcher but telemetry is process-global: clear
    # any serve/req{rid}/kv slots earlier tests recorded under the same rids
    for rid in range(len(reqs)):
        key = f"serve/req{rid}/kv"
        tel.reset(key)
        for h, hop in enumerate(wan.route):
            tel.reset(f"{key}/hop{h}:{hop.name}")
    for prompt, mnew in reqs:
        eng.submit(prompt, mnew)
    eng.run_to_completion()
    Dh = cfg.resolved_head_dim
    for rid, (prompt, _mnew) in enumerate(reqs):
        expect = kv_cache_bytes(cfg.num_layers, cfg.num_kv_heads, Dh,
                                len(prompt))
        key = f"serve/req{rid}/kv"
        assert tel.path(key).total_bytes == expect * wan.n_hops, key
        for h, hop in enumerate(wan.route):
            hop_key = f"{key}/hop{h}:{hop.name}"
            assert tel.path(hop_key).total_bytes == expect, hop_key


# ---------------------------------------------------------------------------
# Server bugfix: per-sequence positions, EOS early-exit, warm-shape keys
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server(serve_rc):
    from repro.runtime.serve_loop import Server
    rc, mesh = serve_rc
    rc2 = replace(rc, shape=ShapeConfig("d", 64, 2, "decode"))
    return Server(rc2, mesh, seed=0)


def test_server_vector_pos_matches_single_row_runs(server):
    prompts = np.array([[5], [9]], np.int32)
    res = server.generate(prompts, max_new=4,
                          prefill_pos=np.array([3, 7], np.int32))
    assert res.tokens.shape == (2, 4)
    assert res.lengths.tolist() == [4, 4]
    # each row must equal a batch run at that row's scalar depth (decode is
    # row-local; the vector-pos path may not leak across rows)
    for row, p in ((0, 3), (1, 7)):
        ref = server.generate(
            np.repeat(prompts[row:row + 1], 2, axis=0), max_new=4,
            prefill_pos=p)
        np.testing.assert_array_equal(res.tokens[row], ref.tokens[0])


def test_server_per_seq_budget_and_padding(server):
    prompts = np.array([[5], [9]], np.int32)
    res = server.generate(prompts, max_new=6,
                          max_new_per_seq=np.array([2, 5]))
    assert res.steps == 5                      # early exit before max_new=6
    assert res.lengths.tolist() == [2, 5]
    assert (res.tokens[0, 2:] == 0).all()      # freed row pads with pad_id


def test_server_eos_early_exit(server):
    prompts = np.array([[5], [9]], np.int32)
    ref = server.generate(prompts, max_new=6, prefill_pos=0)
    eos = int(ref.tokens[0, 1])
    # row 0's EOS lands wherever greedy decode first emits that token
    exp0 = int(np.argmax(ref.tokens[0] == eos)) + 1
    res = server.generate(prompts, max_new=6, prefill_pos=0, eos_id=eos,
                          pad_id=-1)
    assert res.lengths[0] == exp0              # EOS counted, then row frees
    assert (res.tokens[0, exp0:] == -1).all()
    assert res.lengths[1] <= 6
    # row 1's tokens before its own EOS/budget match the no-EOS run
    n1 = int(res.lengths[1])
    np.testing.assert_array_equal(res.tokens[1, :n1], ref.tokens[1, :n1])


def test_server_warm_shapes_include_cache_geometry(server):
    tel = get_telemetry()
    key = server.bundle.path.key
    prompts = np.array([[5], [9]], np.int32)

    def transfers():
        return tel.path(key).transfers

    server.generate(prompts, max_new=3)        # warm the (B, scalar) sig
    n0 = transfers()
    server.generate(prompts, max_new=3)
    assert transfers() - n0 == 3               # warm: every step recorded
    # a new cache geometry forces a recompile: its first step must be
    # excluded from timings even though B is unchanged
    from repro.models.param import tree_init
    cd = server.bundle.model.cache_defs(2, 32)   # shorter cache
    small = tree_init(cd, 0)
    n1 = transfers()
    server.generate(prompts, max_new=3, cache=small)
    assert transfers() - n1 == 2               # first step skipped again
