"""DataGather sync_once: mirror exactness (orphan files AND directories are
pruned), tolerance to files deleted from src concurrently with the walk —
the checkpoint GC races the mirror thread in production — same-size rewrite
detection by mtime, and the WAN transfer-engine data plane."""
from __future__ import annotations

import os
import time

from repro.checkpoint.replicate import sync_once


def _write(path: str, text: str = "x") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def test_prune_removes_empty_orphan_dirs(tmp_path):
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "step_10", "shard0.bin"))
    _write(os.path.join(src, "step_10", "sub", "meta.json"))
    _write(os.path.join(src, "step_20", "shard0.bin"))
    assert sync_once(src, dst) == 3
    assert os.path.isfile(os.path.join(dst, "step_10", "sub", "meta.json"))

    # checkpoint GC deletes step_10 from src: the mirror must drop the files
    # AND the now-empty directory tree, not leave orphan dirs behind
    import shutil
    shutil.rmtree(os.path.join(src, "step_10"))
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "step_10"))
    assert os.path.isfile(os.path.join(dst, "step_20", "shard0.bin"))


def test_nested_orphan_dirs_removed_bottom_up(tmp_path):
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "a", "b", "c", "deep.bin"))
    sync_once(src, dst)
    import shutil
    shutil.rmtree(os.path.join(src, "a"))
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "a"))
    assert os.path.isdir(dst)            # the mirror root itself survives


def test_dir_kept_when_it_still_exists_in_src(tmp_path):
    """An empty-but-live src directory is mirrored, not pruned."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    os.makedirs(os.path.join(src, "empty_live"))
    _write(os.path.join(src, "f.bin"))
    sync_once(src, dst)
    sync_once(src, dst)                  # prune pass must not remove it
    assert os.path.isdir(os.path.join(dst, "empty_live"))


def test_concurrent_deletion_mid_walk(tmp_path, monkeypatch):
    """A src file that vanishes between the walk and the stat/copy must not
    crash the pass; remaining files still sync."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "vanishing.bin"), "a")
    _write(os.path.join(src, "stable.bin"), "a")
    assert sync_once(src, dst) == 2

    # both files change; vanishing.bin is GC'd exactly when the copy pass
    # stats it (os.path.getmtime on a vanished path used to crash the pass)
    _write(os.path.join(src, "vanishing.bin"), "bb")
    _write(os.path.join(src, "stable.bin"), "bb")
    real_getmtime = os.path.getmtime

    def racing_getmtime(p):
        if p.endswith(os.path.join(src, "vanishing.bin")) and os.path.exists(p):
            os.remove(p)                 # the GC got there first
        return real_getmtime(p)          # raises FileNotFoundError for it

    monkeypatch.setattr(os.path, "getmtime", racing_getmtime)
    copied = sync_once(src, dst)         # must not raise
    assert os.path.isfile(os.path.join(dst, "stable.bin"))
    assert copied == 1                   # stable.bin updated, vanished skipped
    monkeypatch.undo()
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "vanishing.bin"))


def test_staging_tmp_directories_not_mirrored(tmp_path):
    """store.save stages whole checkpoints in `step_N.tmp/` dirs before its
    atomic rename: the mirror must not descend into them (that would ship
    partial shards, then ship the published copy again)."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "step_10", "shard0.bin"))
    _write(os.path.join(src, "step_20.tmp", "shard0.bin"))   # mid-write
    assert sync_once(src, dst) == 1
    assert os.path.isfile(os.path.join(dst, "step_10", "shard0.bin"))
    assert not os.path.exists(os.path.join(dst, "step_20.tmp"))


def test_same_size_newer_mtime_overwrites(tmp_path):
    """Checkpoint files are fixed-shape: a rewrite has the same size but new
    bytes.  The mirror diff must ship on mtime alone."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "shard.bin"), "aaaa")
    assert sync_once(src, dst) == 1

    time.sleep(0.01)                     # ensure a strictly newer mtime
    _write(os.path.join(src, "shard.bin"), "bbbb")   # same size, new bytes
    assert sync_once(src, dst) == 1
    with open(os.path.join(dst, "shard.bin")) as f:
        assert f.read() == "bbbb"
    # and an untouched pass copies nothing (mtime was preserved on copy)
    assert sync_once(src, dst) == 0


def test_prune_removes_orphaned_engine_droppings(tmp_path):
    """A mirror pass killed mid-copy can leave a full-size .part (and its
    sidecar) in the replica; the next pass's prune must remove them, or the
    replica grows without bound across interruptions."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "f.bin"), "fresh")
    sync_once(src, dst)
    _write(os.path.join(dst, "f.bin.part"), "x" * 1000)      # orphans
    _write(os.path.join(dst, "f.bin.mpwcp.json"), "{}")
    _write(os.path.join(dst, "gone.bin.part"), "x" * 1000)
    sync_once(src, dst)
    leftover = [f for _, _, fs in os.walk(dst) for f in fs]
    assert leftover == ["f.bin"]


def test_mirror_thread_survives_checksum_failure(tmp_path):
    """A chunk exhausting its CRC retries raises ChecksumError out of
    sync(); the background loop and the stop() drain must survive it (the
    old OSError-only guard let it kill the mirror thread silently)."""
    from repro.checkpoint.replicate import DataGather
    from repro.core import FileTransfer
    from repro.core.path import local_path

    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "f.bin"), "payload")
    bad = FileTransfer(local_path(), record=False, max_retries=0,
                       fault_hook=lambda c, h, p: b"\x00" * len(p))
    g = DataGather(src, dst, interval_s=0.01, transfer=bad).start()
    time.sleep(0.1)
    assert g._thread.is_alive()          # failures did not kill the loop
    g.stop()                             # drain must not raise either
    assert not os.path.exists(os.path.join(dst, "f.bin"))

    g2 = DataGather(src, dst)            # healthy plane still mirrors
    assert g2.sync() == 1
    """The mirror's data plane is the mpw-cp engine: a WAN-configured
    FileTransfer (multi-stream, compressed) produces the same mirror and
    leaves no .part/.mpwcp.json droppings for later passes to mis-copy."""
    from repro.configs.base import CommConfig
    from repro.core import FileTransfer, WidePath
    from repro.core.path import WAN_LONDON_POZNAN

    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "step_10", "shard0.bin"), "x" * 200_000)
    _write(os.path.join(src, "step_10", "meta.json"), "{}")
    eng = FileTransfer(WidePath(axis="pod", link=WAN_LONDON_POZNAN,
                                name="mirror-test",
                                comm=CommConfig(streams=4, chunk_mb=0.0625,
                                                compress="int8")))
    assert sync_once(src, dst, transfer=eng) == 2
    with open(os.path.join(dst, "step_10", "shard0.bin")) as f:
        assert f.read() == "x" * 200_000
    assert sync_once(src, dst, transfer=eng) == 0    # already mirrored
    names = [f for _, _, fs in os.walk(dst) for f in fs]
    assert all(not n.endswith((".part", ".mpwcp.json", ".tmp"))
               for n in names)
