"""DataGather sync_once: mirror exactness (orphan files AND directories are
pruned) and tolerance to files deleted from src concurrently with the walk —
the checkpoint GC races the mirror thread in production."""
from __future__ import annotations

import os

from repro.checkpoint.replicate import sync_once


def _write(path: str, text: str = "x") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def test_prune_removes_empty_orphan_dirs(tmp_path):
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "step_10", "shard0.bin"))
    _write(os.path.join(src, "step_10", "sub", "meta.json"))
    _write(os.path.join(src, "step_20", "shard0.bin"))
    assert sync_once(src, dst) == 3
    assert os.path.isfile(os.path.join(dst, "step_10", "sub", "meta.json"))

    # checkpoint GC deletes step_10 from src: the mirror must drop the files
    # AND the now-empty directory tree, not leave orphan dirs behind
    import shutil
    shutil.rmtree(os.path.join(src, "step_10"))
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "step_10"))
    assert os.path.isfile(os.path.join(dst, "step_20", "shard0.bin"))


def test_nested_orphan_dirs_removed_bottom_up(tmp_path):
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "a", "b", "c", "deep.bin"))
    sync_once(src, dst)
    import shutil
    shutil.rmtree(os.path.join(src, "a"))
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "a"))
    assert os.path.isdir(dst)            # the mirror root itself survives


def test_dir_kept_when_it_still_exists_in_src(tmp_path):
    """An empty-but-live src directory is mirrored, not pruned."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    os.makedirs(os.path.join(src, "empty_live"))
    _write(os.path.join(src, "f.bin"))
    sync_once(src, dst)
    sync_once(src, dst)                  # prune pass must not remove it
    assert os.path.isdir(os.path.join(dst, "empty_live"))


def test_concurrent_deletion_mid_walk(tmp_path, monkeypatch):
    """A src file that vanishes between the walk and the stat/copy must not
    crash the pass; remaining files still sync."""
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    _write(os.path.join(src, "vanishing.bin"), "a")
    _write(os.path.join(src, "stable.bin"), "a")
    assert sync_once(src, dst) == 2

    # both files change; vanishing.bin is GC'd exactly when the copy pass
    # stats it (os.path.getmtime on a vanished path used to crash the pass)
    _write(os.path.join(src, "vanishing.bin"), "bb")
    _write(os.path.join(src, "stable.bin"), "bb")
    real_getmtime = os.path.getmtime

    def racing_getmtime(p):
        if p.endswith(os.path.join(src, "vanishing.bin")) and os.path.exists(p):
            os.remove(p)                 # the GC got there first
        return real_getmtime(p)          # raises FileNotFoundError for it

    monkeypatch.setattr(os.path, "getmtime", racing_getmtime)
    copied = sync_once(src, dst)         # must not raise
    assert os.path.isfile(os.path.join(dst, "stable.bin"))
    assert copied == 1                   # stable.bin updated, vanished skipped
    monkeypatch.undo()
    sync_once(src, dst)
    assert not os.path.exists(os.path.join(dst, "vanishing.bin"))
