"""Train/serve step integration on 8 fake devices (subprocess):
- comm-mode loss parity (flat == hierarchical+ZeRO == gateway)
- compression trains (int8 tolerates quantization noise)
- microbatch overlap preserves gradients
- decode bundle runs with sharded caches
"""
from __future__ import annotations

import pytest

_PARITY = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step
from repro.models.registry import batch_concrete

cfg = smoke_config(get_config("llama3.2-3b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
out = {}
for mode, compress, micro in [("flat","none",1), ("hierarchical","none",1),
                              ("gateway","none",1), ("hierarchical","bf16",1),
                              ("hierarchical","none",2)]:
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode=mode, streams=4, chunk_mb=0.001,
                                   compress=compress),
                   train=TrainConfig(zero1=True, microbatches=micro,
                                     warmup_steps=1, total_steps=10, lr=1e-3))
    with jax.set_mesh(mesh):
        b = build_train_step(rc, mesh)
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(b.init_state(0), sh(b.state_specs))
        losses = []
        for i in range(4):
            batch = jax.device_put(batch_concrete(cfg, "train", 8, 32, seed=i),
                                   sh(b.batch_specs))
            state, m = b.fn(state, batch)
            losses.append(float(m["loss"]))
        out[f"{mode}/{compress}/m{micro}"] = losses
print("RESULT:" + json.dumps(out))
"""


def test_mode_parity_and_training(multidev):
    res = multidev(_PARITY, timeout=1500)
    base = res["flat/none/m1"]
    for key, losses in res.items():
        assert all(np.isfinite(l) for l in losses), (key, losses)
        # same data, same init: all modes should track the flat baseline
        if key.endswith("m1"):
            tol = 0.05 if "bf16" in key else 0.01
            for a, b in zip(base, losses):
                assert abs(a - b) < tol, (key, base, losses)
    # microbatched run sees the same data split differently; loss must still
    # be in-family and decreasing-ish
    m2 = res["hierarchical/none/m2"]
    assert abs(m2[0] - base[0]) < 0.2


import numpy as np  # noqa: E402

_DECODE = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_serve_step
from repro.models.param import tree_init, tree_abstract

out = {}
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
for arch in ["qwen1.5-0.5b", "mamba2-780m", "zamba2-1.2b"]:
    cfg = smoke_config(get_config(arch))
    rc = RunConfig(model=cfg, shape=ShapeConfig("d", 64, 8, "decode"),
                   comm=CommConfig(), train=TrainConfig(zero1=True))
    with jax.set_mesh(mesh):
        b = build_serve_step(rc, mesh, kind="decode")
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(tree_init(b.param_defs, 0),
                                sh(b.state_specs["params"]))
        cache = jax.device_put(tree_init(b.cache_defs, 0),
                               sh(b.state_specs["cache"]))
        toks = jax.device_put(jnp.ones((8,1), jnp.int32),
                              sh(b.batch_specs["tokens"]))
        logits, cache = b.fn(params, cache, jnp.int32(0), toks)
        logits2, _ = b.fn(params, cache, jnp.int32(1), toks)
        out[arch] = {"shape": list(logits.shape),
                     "finite": bool(jnp.isfinite(logits2).all())}
print("RESULT:" + json.dumps(out))
"""


def test_decode_bundles(multidev):
    res = multidev(_DECODE, timeout=1500)
    for arch, r in res.items():
        assert r["finite"], arch
        assert r["shape"][0] == 8 and r["shape"][1] == 1, (arch, r)
