"""Fault-tolerant serving acceptance suite (ISSUE 10).

1. Golden serve-chaos timeline: the amsterdam->tokyo light path drops
   mid-ship on the CosmoGrid testbed; the KV ship reships, reroutes over the
   tokyo-edinburgh backup, and the route recovers when the fault clears.
   Both the batcher event timeline and the incident timeline are pinned and
   must replay **bit-identically** across two runs (CI runs this file
   twice).
2. Deadlines: in-flight requests past ``deadline_steps`` reach the terminal
   TIMEOUT state at exactly ``arrival + deadline`` and free their slot.
3. SLO-aware admission: hopeless requests shed at submit; queue-full
   rejections land a ``shed`` incident too.
4. Decode-site failover: a `SiteMembership` eviction drains in-flight
   requests back to QUEUED and re-plans onto a surviving site; with no
   surviving pair the batcher degrades to collocated mono-site serving.
5. The `modeled_ship_steps` fault-clock regression (satellite): a degraded
   or dead hop lengthens the modeled ship only when the step lands in the
   fault window.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.chaos import IncidentLog
from repro.core.membership import SiteMembership
from repro.core.serving import (DONE, SHED, TIMEOUT, ContinuousBatcher,
                                FaultAwareShipper, modeled_ship_steps)
from repro.core.topology import Fault, cosmogrid_topology

STEP_S = 0.5          # coarse decode step so the slow backup link fits
KV_BYTES = 16 << 20

# the pinned scenario: primary light path drops for steps [4, 60) while
# req0's KV is on the wire; req2 carries a hopeless 10-step deadline; req3
# arrives after the fault clears and ships over the healed primary
GOLDEN_TRACE = [(0, 8, 3), (1, 8, 2), (20, 8, 2, 10), (65, 8, 2)]

GOLDEN_TIMELINE = [
    ["admit", "req0", 0], ["prefill", "req0", 0], ["admit", "req1", 1],
    ["ship", "req0", 2], ["prefill", "req1", 2], ["ship", "req1", 4],
    ["shed", "req2", 20], ["decode", "req1", 53], ["complete", "req1", 54],
    ["decode", "req0", 55], ["complete", "req0", 57],
    ["admit", "req3", 65], ["prefill", "req3", 65], ["ship", "req3", 67],
    ["decode", "req3", 70], ["complete", "req3", 71],
]

# incident rows in arrival order (reship/reroute are logged when req0's
# ship runs at step 2, so they precede the inject row the step-4
# housekeeping pass writes)
GOLDEN_INCIDENTS = [
    {"step": 5, "event": "reship", "subject": "amsterdam->tokyo",
     "detail": {"rid": 0, "attempt": 1, "backoff_s": 0.0}},
    {"step": 6, "event": "reroute", "subject": "amsterdam->tokyo",
     "detail": {"rid": 0, "route": ["amsterdam", "edinburgh", "tokyo"]}},
    {"step": 4, "event": "inject", "subject": "ams-tokyo-lightpath",
     "detail": {"kind": "drop", "start": 4, "stop": 60, "factor": 1.0,
                "error_rate": 0.0}},
    {"step": 20, "event": "shed", "subject": "req2",
     "detail": {"reason": "slo", "modeled_steps": 52, "deadline_steps": 10}},
    {"step": 60, "event": "recover", "subject": "amsterdam->tokyo",
     "detail": {"mode": "reroute", "latency_steps": 56}},
]


def _chaos_setup(max_reships: int = 1):
    topo = cosmogrid_topology(backup_links=True)
    prof = topo.link("amsterdam", "tokyo").with_fault(
        Fault("drop", start=4, stop=60))
    topo.connect("amsterdam", "tokyo", prof)
    log = IncidentLog()
    shipper = FaultAwareShipper(
        topo, "amsterdam", "tokyo", kv_bytes=KV_BYTES, step_s=STEP_S,
        max_reships=max_reships, timeout_s=STEP_S, log=log, seed=0)
    batcher = ContinuousBatcher(
        2, 8, prefill_steps=2, step_s=STEP_S, deadline_steps=200,
        shipper=shipper, log=log, prefill_site="amsterdam",
        decode_site="tokyo")
    return topo, log, shipper, batcher


def _run_chaos():
    topo, log, shipper, batcher = _chaos_setup()
    stats = batcher.run(GOLDEN_TRACE)
    return batcher.timeline(), log.timeline(), stats, shipper


# ---------------------------------------------------------------------------
# golden serve-chaos timeline
# ---------------------------------------------------------------------------

def test_golden_chaos_timeline():
    timeline, incidents, stats, shipper = _run_chaos()
    assert timeline == GOLDEN_TIMELINE
    assert incidents == GOLDEN_INCIDENTS
    assert stats["completed"] == 3
    assert stats["shed"] == 1
    assert stats["timed_out"] == 0
    assert stats["reships"] == 1
    assert stats["reroutes"] == 1
    assert stats["degraded"] is False
    assert stats["slo_attainment"] == pytest.approx(0.75)
    # the fault cleared: the shipper is back on the primary light path
    assert shipper.route_names == ("amsterdam", "tokyo")
    assert not shipper.detoured


def test_golden_chaos_timeline_replays_bit_identically():
    a = _run_chaos()
    b = _run_chaos()
    assert a[0] == b[0]           # batcher event timeline
    assert a[1] == b[1]           # incident timeline
    assert a[2] == b[2]           # stats dict


def test_ship_telemetry_counts_reships():
    from repro.core.telemetry import get_telemetry
    tel = get_telemetry()
    tel.reset("serve/req0/kv")
    _run_chaos()
    row = tel.path("serve/req0/kv").summary()
    assert row["reships"] == 1
    assert row["reroutes"] == 1


# ---------------------------------------------------------------------------
# deadlines -> TIMEOUT
# ---------------------------------------------------------------------------

def test_deadline_times_out_inflight_request():
    # shed=False forces the hopeless request into the pipeline so the sweep
    # (not admission) has to kill it
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=50,
                          shed=False, log=IncidentLog())
    rid = b.submit(8, 4, step=0, deadline_steps=10)
    assert rid == 0
    b.drain()
    tr = b._reqs[rid]
    assert tr.state == TIMEOUT
    assert tr.t_done == 10                      # exactly arrival + deadline
    assert b.active_slots() == [None]           # the slot was freed
    assert b.stats()["timed_out"] == 1
    assert ["timeout", "req0", 10] in b.timeline()


def test_timeout_frees_slot_for_later_requests():
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=30, shed=False)
    b.submit(8, 2, step=0, deadline_steps=5)    # will time out mid-ship
    for _ in range(6):
        b.step_once()
    rid2 = b.submit(8, 2, step=b.now())         # no deadline: must complete
    assert rid2 is not None
    b.drain()
    assert b._reqs[rid2].state == DONE
    stats = b.stats()
    assert stats["timed_out"] == 1 and stats["completed"] == 1


def test_timeout_incident_records_stage():
    log = IncidentLog()
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=50,
                          shed=False, log=log)
    b.submit(8, 4, step=0, deadline_steps=10)
    b.drain()
    rows = [r for r in log.timeline() if r["event"] == "timeout"]
    assert rows == [{"step": 10, "event": "timeout", "subject": "req0",
                     "detail": {"stage": "ship", "tokens": 0}}]


# ---------------------------------------------------------------------------
# SLO-aware admission -> SHED
# ---------------------------------------------------------------------------

def test_slo_admission_sheds_hopeless_request():
    log = IncidentLog()
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=50, log=log)
    rid = b.submit(8, 4, step=0, deadline_steps=10)
    assert rid is None
    assert b._reqs[0].state == SHED
    assert b.stats()["shed"] == 1
    assert b.stats()["slo_attainment"] == 0.0
    row = [r for r in log.timeline() if r["event"] == "shed"][0]
    assert row["detail"]["reason"] == "slo"
    assert row["detail"]["modeled_steps"] >= row["detail"]["deadline_steps"]


def test_feasible_deadline_is_admitted_and_met():
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=2)
    rid = b.submit(8, 4, step=0, deadline_steps=50)
    assert rid is not None
    b.drain()
    tr = b._reqs[rid]
    assert tr.state == DONE
    assert tr.t_done - tr.req.arrival < 50


def test_queue_full_rejection_lands_shed_incident():
    log = IncidentLog()
    b = ContinuousBatcher(1, 1, prefill_steps=1, ship_steps=0, log=log)
    b.submit(8, 2)
    assert b.submit(8, 2) is None               # queue_limit=1: second rejects
    assert b.stats()["rejected"] == 1
    rows = [r for r in log.timeline() if r["event"] == "shed"]
    assert rows and rows[0]["detail"]["reason"] == "queue-full"


def test_shed_disabled_admits_hopeless_request():
    b = ContinuousBatcher(1, 8, prefill_steps=1, ship_steps=50, shed=False)
    assert b.submit(8, 4, step=0, deadline_steps=10) is not None


# ---------------------------------------------------------------------------
# decode-site failover and the degraded fallback
# ---------------------------------------------------------------------------

def _evict_tokyo_setup():
    """Both links into tokyo drop at step 5: membership evicts it and the
    decode role must move to a surviving site."""
    topo = cosmogrid_topology(backup_links=True)
    for a, b in [("amsterdam", "tokyo"), ("tokyo", "edinburgh")]:
        topo.connect(a, b, topo.link(a, b).with_fault(
            Fault("drop", start=5, stop=200)))
    log = IncidentLog()
    shipper = FaultAwareShipper(topo, "amsterdam", "tokyo",
                                kv_bytes=4 << 20, step_s=STEP_S,
                                max_reships=1, timeout_s=STEP_S, log=log)
    ms = SiteMembership(topo, "amsterdam", lease_steps=3, log=log)
    batcher = ContinuousBatcher(2, 8, prefill_steps=2, step_s=STEP_S,
                                shipper=shipper, log=log, membership=ms,
                                prefill_site="amsterdam", decode_site="tokyo")
    return topo, log, shipper, batcher


def test_decode_site_failover_on_eviction():
    _, log, shipper, b = _evict_tokyo_setup()
    stats = b.run([(0, 8, 3), (1, 8, 2), (30, 8, 2)])
    assert stats["completed"] == 3
    assert stats["failovers"] == 1
    assert stats["degraded"] is False           # the new pair routes again
    assert b._decode_site == "espoo"
    assert shipper.route_names == ("amsterdam", "espoo")
    events = [r["event"] for r in log.timeline()]
    assert "evict" in events and "serve_failover" in events
    fo = [r for r in log.timeline() if r["event"] == "serve_failover"][0]
    assert fo["subject"] == "decode:tokyo->espoo"


def test_failover_drains_inflight_to_queued():
    _, log, _, b = _evict_tokyo_setup()
    # max_new=40 keeps req0 decoding on tokyo when the eviction lands
    stats = b.run([(0, 8, 40)])
    assert stats["completed"] == 1
    assert stats["failovers"] == 1
    fo = [r for r in log.timeline() if r["event"] == "serve_failover"][0]
    assert fo["detail"]["requeued"] == 1
    tl = b.timeline()
    assert ["requeue", "req0", 8] in tl
    # decode restarted from scratch on the new site after the requeue
    decode_steps = [e[2] for e in tl if e[0] == "decode"]
    assert len(decode_steps) == 2 and decode_steps[1] > 8
    assert b._reqs[0].tokens == 40


def test_unroutable_ship_degrades_to_collocated():
    topo = cosmogrid_topology()                 # no backup: tokyo is a leaf
    topo.connect("amsterdam", "tokyo", topo.link("amsterdam", "tokyo")
                 .with_fault(Fault("drop", start=3, stop=1 << 20)))
    log = IncidentLog()
    shipper = FaultAwareShipper(topo, "amsterdam", "tokyo",
                                kv_bytes=4 << 20, step_s=STEP_S,
                                max_reships=1, timeout_s=STEP_S, log=log)
    b = ContinuousBatcher(2, 8, prefill_steps=2, step_s=STEP_S,
                          shipper=shipper, log=log,
                          prefill_site="amsterdam", decode_site="tokyo")
    stats = b.run([(0, 8, 3), (4, 8, 2)])
    assert stats["completed"] == 2              # collocated serving finishes
    assert stats["degraded"] is True
    rows = [r for r in log.timeline() if r["event"] == "degrade"]
    assert rows == [{"step": 2, "event": "degrade", "subject": "serve",
                     "detail": {"reason": "req0: no surviving route"}}]


def test_degrade_hook_for_runtime_engines():
    log = IncidentLog()
    b = ContinuousBatcher(1, 8, ship_steps=5, log=log)
    b.degrade(reason="real ship failed")
    assert b.stats()["degraded"] is True
    rid = b.submit(8, 2)
    b.drain()
    assert b._reqs[rid].state == DONE
    # degraded ships are free: ship and decode land on the same step
    tl = b.timeline()
    ship_at = [e[2] for e in tl if e[0] == "ship"][0]
    decode_at = [e[2] for e in tl if e[0] == "decode"][0]
    assert ship_at == decode_at


# ---------------------------------------------------------------------------
# modeled_ship_steps fault clock (satellite regression)
# ---------------------------------------------------------------------------

def test_modeled_ship_steps_degraded_hop_lengthens_ship():
    topo = cosmogrid_topology()
    topo.connect("amsterdam", "tokyo", topo.link("amsterdam", "tokyo")
                 .with_fault(Fault("degrade", start=10, stop=20, factor=0.005)))
    route = topo.route("amsterdam", "tokyo")
    healthy = modeled_ship_steps(KV_BYTES, step_s=STEP_S, step=0, route=route)
    degraded = modeled_ship_steps(KV_BYTES, step_s=STEP_S, step=10,
                                  route=route)
    after = modeled_ship_steps(KV_BYTES, step_s=STEP_S, step=20, route=route)
    assert degraded > healthy           # capacity below the window cap
    assert after == healthy                     # the fault clock moved on


def test_modeled_ship_steps_dead_hop_costs_the_watchdog():
    topo = cosmogrid_topology()
    topo.connect("amsterdam", "tokyo", topo.link("amsterdam", "tokyo")
                 .with_fault(Fault("drop", start=5, stop=8)))
    route = topo.route("amsterdam", "tokyo")
    healthy = modeled_ship_steps(KV_BYTES, step_s=STEP_S, step=0, route=route)
    dead = modeled_ship_steps(KV_BYTES, step_s=STEP_S, step=5, route=route,
                              timeout_s=30.0)
    assert dead == int(np.ceil(30.0 / STEP_S))  # the naive wait-out model
    assert dead > healthy


def test_modeled_ship_steps_requires_path_or_route():
    with pytest.raises(ValueError, match="route"):
        modeled_ship_steps(KV_BYTES, path=None, route=None)


# ---------------------------------------------------------------------------
# FaultAwareShipper determinism & estimates
# ---------------------------------------------------------------------------

def test_shipper_estimate_matches_ship_steps():
    topo, log, shipper, _ = _chaos_setup()
    from repro.core.serving import Request
    req = Request(0, 2, 8, 3)
    est = shipper.estimate_steps(req, 2)
    out = shipper.ship(req, 2)
    assert out.ok and out.steps == est


def test_shipper_unroutable_estimate_blows_any_deadline():
    topo = cosmogrid_topology()
    topo.connect("amsterdam", "tokyo", topo.link("amsterdam", "tokyo")
                 .with_fault(Fault("drop", start=0, stop=1 << 20)))
    shipper = FaultAwareShipper(topo, "amsterdam", "tokyo",
                                kv_bytes=4 << 20, step_s=STEP_S,
                                max_reships=0, timeout_s=STEP_S)
    from repro.core.serving import Request
    assert shipper.estimate_steps(Request(0, 0, 8, 2), 0) >= 1 << 30


def test_note_ship_accounts_real_ship_retries():
    # runtime engines ship through kvship.ship_kv (the batcher runs with
    # ship_steps=0) and feed the real KVShipResult back via note_ship —
    # without it stats() would report 0 reships while the incident log
    # fills up
    b = ContinuousBatcher(2, 8, prefill_steps=1, ship_steps=0)
    rid = b.submit(8, 2)
    b.note_ship(rid, reships=2, reroutes=1)
    b.note_ship(rid + 999, reships=1)        # unknown rid: counter-only
    b.drain()
    s = b.stats()
    assert s["reships"] == 3 and s["reroutes"] == 1
