"""Mini dry-run: the dryrun machinery (lower + compile + analysis) on a
reduced arch and an 8-device mesh — fast proxy for the production matrix,
keeps the pipeline itself under test."""
from __future__ import annotations

import pytest

_SNIPPET = r"""
import json
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step, build_serve_step
from repro.models.registry import batch_abstract
from repro.models.param import tree_abstract
from repro.launch import hlo_analysis as H

cfg = smoke_config(get_config("llama3.2-3b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
shape = ShapeConfig("t", 64, 8, "train")
rc = RunConfig(model=cfg, shape=shape,
               comm=CommConfig(mode="hierarchical", streams=4, chunk_mb=0.001),
               train=TrainConfig(zero1=True))
out = {}
with jax.set_mesh(mesh):
    b = build_train_step(rc, mesh)
    lowered = b.fn.lower(b.abstract_state(),
                         {"tokens": jax.ShapeDtypeStruct((8, 65), jnp.int32)})
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = H.analyze(compiled.as_text(), pod_size=4)
    out["train"] = {
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "flops": cost.flops, "bytes": cost.bytes,
        "ici": cost.coll_ici, "xpod": cost.coll_cross,
        "n_coll": cost.n_coll_ops,
    }
    # decode bundle lowers too
    rc2 = RunConfig(model=cfg, shape=ShapeConfig("d", 64, 8, "decode"),
                    comm=CommConfig(), train=TrainConfig(zero1=True))
    b2 = build_serve_step(rc2, mesh, kind="decode")
    l2 = b2.fn.lower(tree_abstract(b2.param_defs), tree_abstract(b2.cache_defs),
                     jax.ShapeDtypeStruct((), jnp.int32),
                     jax.ShapeDtypeStruct((8,1), jnp.int32))
    c2 = l2.compile()
    out["decode_ok"] = True
print("RESULT:" + json.dumps(out))
"""


def test_mini_dryrun(multidev):
    res = multidev(_SNIPPET, timeout=1500)
    t = res["train"]
    assert t["flops"] > 0 and t["bytes"] > 0
    assert t["n_coll"] > 0, "train step must contain collectives"
    assert t["xpod"] > 0, "hierarchical mode must cross the pod axis"
    # cross-pod traffic must be far below intra-pod (the MPWide hierarchy)
    assert t["xpod"] < t["ici"], res
    assert res["decode_ok"]
