"""Online autotuner + telemetry subsystem.

Convergence is tested against the synthetic link simulator (deterministic
LCG noise) — the same landscape benchmarks/autotune_convergence.py reports
on — plus the MPW facade loop (setAutoTuning/Observe/PathStats/Report) and
the telemetry registry itself.
"""
from __future__ import annotations

import pytest

from repro.core.api import MPW
from repro.core.autotune import (ALGO_GRID, BUCKET_GRID_MB, CHUNK_GRID_MB,
                                 STREAM_GRID, OnlineTuner,
                                 simulate_transfer_s)
from repro.core.path import ICI, WAN_LONDON_POZNAN, WidePath
from repro.core.telemetry import Telemetry, get_telemetry

PAYLOAD = 64 << 20


def _sweep_best(link, payload=PAYLOAD):
    return min(
        simulate_transfer_s(payload, link, streams=s, chunk_bytes=c * (1 << 20))
        for s in STREAM_GRID for c in CHUNK_GRID_MB)


def _drive(tuner, link, payload=PAYLOAD, jitter=0.02, max_steps=600, seed0=0):
    cfg = tuner.config()
    for i in range(max_steps):
        t = simulate_transfer_s(payload, link, streams=cfg["streams"],
                                chunk_bytes=cfg["chunk_mb"] * (1 << 20),
                                pacing=cfg["pacing"], jitter=jitter,
                                seed=seed0 + i)
        new = tuner.observe(t)
        if new is not None:
            cfg = new
        if tuner.converged:
            break
    return cfg


# ---------------------------------------------------------------------------
# tuner convergence
# ---------------------------------------------------------------------------

def test_tuner_converges_within_10pct_from_worst_start():
    """From the 1-stream / payload-sized-chunk scp baseline, the hill climb
    must land within 10% of the exhaustive-sweep optimum (acceptance)."""
    link = WAN_LONDON_POZNAN
    tuner = OnlineTuner(streams=1, chunk_mb=64.0, window=5, warmup=1)
    cfg = _drive(tuner, link)
    assert tuner.converged
    final = simulate_transfer_s(PAYLOAD, link, streams=cfg["streams"],
                                chunk_bytes=cfg["chunk_mb"] * (1 << 20),
                                pacing=cfg["pacing"])
    assert final <= 1.10 * _sweep_best(link), (cfg, final)


def test_tuner_converges_from_oversubscribed_start():
    """256 streams of tiny chunks pays setup overhead; the tuner must back
    off toward the optimum, not just climb up."""
    link = WAN_LONDON_POZNAN
    tuner = OnlineTuner(streams=256, chunk_mb=0.0625, window=5, warmup=1)
    cfg = _drive(tuner, link, seed0=5000)
    final = simulate_transfer_s(PAYLOAD, link, streams=cfg["streams"],
                                chunk_bytes=cfg["chunk_mb"] * (1 << 20),
                                pacing=cfg["pacing"])
    assert final <= 1.10 * _sweep_best(link), (cfg, final)


def test_tuner_keeps_single_stream_on_local_link():
    """On a window-free fabric more streams only add overhead: starting at
    1 stream must stay at 1 stream (paper: 1 stream local)."""
    tuner = OnlineTuner(streams=1, chunk_mb=8.0, window=3, warmup=0)
    cfg = _drive(tuner, ICI, jitter=0.0)
    assert cfg["streams"] == 1


def test_tuner_mechanics():
    tuner = OnlineTuner(streams=32, chunk_mb=8.0, pacing=1.0, window=2,
                        warmup=0)
    incumbent = {"streams": 32, "chunk_mb": 8.0, "pacing": 1.0,
                 "algo": "psum", "bucket_mb": 0.0}
    assert tuner.config() == incumbent
    # off-grid warm starts are kept exact (inserted as grid points), so the
    # incumbent is the config actually running
    t2 = OnlineTuner(streams=33, chunk_mb=7.0, pacing=0.9)
    assert t2.config()["streams"] == 33 and t2.config()["chunk_mb"] == 7.0
    assert t2.config()["pacing"] == 0.9
    # no decision before a full window
    assert tuner.observe(1.0) is None
    first = tuner.observe(1.0)         # window complete -> first probe move
    assert first is not None and first != incumbent
    # every proposed config stays on the grids
    for _ in range(200):
        cfg = tuner.observe(1.0)
        if tuner.converged:
            break
        if cfg is not None:
            assert cfg["streams"] in STREAM_GRID
            assert cfg["chunk_mb"] in CHUNK_GRID_MB
            assert cfg["algo"] in ALGO_GRID
            assert cfg["bucket_mb"] in BUCKET_GRID_MB
    # constant cost everywhere -> nothing beats the incumbent -> revert
    assert tuner.converged
    assert tuner.config() == tuner.best_config() == incumbent
    assert tuner.observe(1.0) is None  # converged tuner stays quiet


def test_abort_probe_reverts_mid_probe_config():
    """Regression: a path fault during a probe window must revert the
    probed config.  Before the fix, the fault left the (possibly losing)
    probed knobs pinned on the path while the tuner's incumbent pointed at
    the old config — and the fault-corrupted window could even be booked
    as the probe's cost."""
    tuner = OnlineTuner(streams=32, chunk_mb=8.0, window=3, warmup=0)
    incumbent = tuner.config()
    probe = None
    for _ in range(3):
        probe = tuner.observe(1.0) or probe
    assert probe is not None and probe != incumbent   # probe in flight
    tuner.observe(50.0)                    # fault corrupts the window...
    reverted = tuner.abort_probe()         # ...and the path dies mid-probe
    assert reverted == incumbent, "losing config must not stay pinned"
    assert tuner.config() == tuner.best_config() == incumbent
    # the corrupted partial window is discarded, not booked as a cost
    assert all(cost == 1.0 for _, cost in tuner.history)
    # the aborted move is re-queued for a clean re-probe after recovery
    assert tuner._moves and tuner._moves[0] is not None
    again = None
    for _ in range(3):
        again = tuner.observe(1.0) or again
    assert again == probe, "aborted probe must be re-tried, not lost"
    # aborting with no probe in flight is a no-op returning None
    fresh = OnlineTuner(streams=32, chunk_mb=8.0, window=3, warmup=0)
    assert fresh.abort_probe() is None


def test_route_tuner_abort_probe_reverts_every_hop():
    from repro.core.autotune import RouteTuner
    from repro.core.path import Hop, LinkSpec

    wan = LinkSpec("wan", 50e-3, 1e8, 64 << 10)
    path = WidePath(axis="pod", name="r").with_hops((
        Hop("a->b", ICI, WidePath().comm, 1),
        Hop("b->c", wan, WidePath().comm, 1)))
    rt = RouteTuner(path, window=2, warmup=0)
    for _ in range(2):
        rt.observe_total(1.0)              # both hops propose probes
    incumbents = [t.best_config() for t in rt.tuners]
    reverted = rt.abort_probe()
    assert set(reverted) == {0, 1}
    for i, t in enumerate(rt.tuners):
        assert reverted[i] == incumbents[i]
        assert t.config() == t.best_config()


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_records_and_reports():
    tele = Telemetry()
    tele.note_plan("p:wan", payload_bytes=1 << 20, n_chunks=16,
                   streams_used=8, streams_configured=16,
                   chunk_bytes=1 << 16, pacing=1.0, load_balance=1.2)
    for i in range(4):
        tele.record("p:wan", 0.5, step=i)    # nbytes defaults from the plan
    s = tele.path("p:wan").summary()
    assert s["transfers"] == 4
    assert s["total_bytes"] == 4 << 20
    assert s["stream_utilization"] == 0.5
    assert abs(s["achieved_GBps"] - (4 << 20) / 2.0 / 1e9) < 1e-9
    assert "p:wan" in tele.report()
    assert "p:wan" in tele.format_report()
    with tele.timed("p:other", nbytes=100):
        pass
    assert tele.path("p:other").transfers == 1
    tele.reset("p:other")
    assert "p:other" not in tele.report()


def test_telemetry_window_is_bounded():
    tele = Telemetry()
    pt = tele.path("k")
    pt.window = 8
    for i in range(100):
        pt.record(0.001, nbytes=1, step=i)
    assert len(pt.samples) == 8
    assert pt.transfers == 100 and pt.total_bytes == 100


def test_plan_recorded_at_trace_time_by_streamed_psum():
    """Plans flow into the global registry even from abstract tracing."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import CommConfig
    from repro.core.collectives import streamed_psum

    get_telemetry().reset("traced:interpod")
    path = WidePath(axis="pod", comm=CommConfig(streams=4, chunk_mb=0.0001),
                    name="traced")
    mesh = jax.make_mesh((1, 1), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    f = jax.shard_map(lambda t: streamed_psum(t, path, dims={"g": 0}),
                      mesh=mesh, in_specs=(P(),), out_specs=P(),
                      axis_names={"pod", "data"}, check_vma=False)
    with jax.set_mesh(mesh):
        jax.eval_shape(f, {"g": jnp.zeros((4096, 32), jnp.float32)})
    plan = get_telemetry().path("traced:interpod").plan
    assert plan is not None
    assert plan.payload_bytes == 4096 * 32 * 4
    assert plan.n_chunks > 1        # 512 KiB over the 64 KiB chunk floor
    assert plan.streams_used <= plan.streams_configured == 4


# ---------------------------------------------------------------------------
# MPW facade: setAutoTuning / Observe / PathStats / Report
# ---------------------------------------------------------------------------

def test_mpw_online_autotuning_loop():
    link = WAN_LONDON_POZNAN
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=1, link=link)
    mpw.setChunkSize(pid, 64 << 20)
    mpw.setAutoTuning(pid, True, online=True, window=5)
    retuned = 0
    for i in range(600):
        p = mpw.path(pid)
        t = simulate_transfer_s(PAYLOAD, link, streams=p.streams,
                                chunk_bytes=p.chunk_bytes,
                                pacing=p.comm.pacing, jitter=0.02,
                                seed=9000 + i)
        retuned += mpw.Observe(pid, t, nbytes=PAYLOAD)
        if mpw.paths[pid].tuner.converged:
            break
    assert retuned > 0, "online tuner never re-tuned the path"
    p = mpw.path(pid)
    final = simulate_transfer_s(PAYLOAD, link, streams=p.streams,
                                chunk_bytes=p.chunk_bytes,
                                pacing=p.comm.pacing)
    assert final <= 1.10 * _sweep_best(link), (p.streams, p.comm.chunk_mb)

    stats = mpw.PathStats(pid)
    assert stats["transfers"] > 0 and stats["total_bytes"] > 0
    assert stats["retunes"], "retune history must be recorded"
    rep = mpw.Report()
    assert mpw.path(pid).key in rep
    assert isinstance(mpw.Report(formatted=True), str)

    # disabling drops the controller but keeps the tuned knobs
    streams_before = p.streams
    mpw.setAutoTuning(pid, False)
    assert mpw.paths[pid].tuner is None
    assert mpw.path(pid).streams == streams_before
    assert mpw.Observe(pid, 0.1) is False
    mpw.Finalize()


def test_mpw_warm_start_still_works():
    """payload_bytes path: model-based warm start seeds the online tuner."""
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=1, link=WAN_LONDON_POZNAN)
    mpw.setAutoTuning(pid, True, payload_bytes=256 << 20)
    assert mpw.path(pid).streams >= 32     # paper: >=32 streams on WANs
    assert mpw.paths[pid].tuner is not None
    # the controller's incumbent is exactly the warm-started, running config
    assert mpw.paths[pid].tuner.config()["streams"] == mpw.path(pid).streams
    assert mpw.paths[pid].tuner.config()["chunk_mb"] == mpw.path(pid).comm.chunk_mb


_TRAIN_AUTOTUNE = r"""
import json
import jax
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime import Trainer
from repro.data import DataConfig, make_pipeline
from repro.core import MPW
from repro.core.telemetry import get_telemetry

cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
               comm=CommConfig(mode="hierarchical", streams=2, chunk_mb=0.25),
               train=TrainConfig(zero1=True, warmup_steps=2, total_steps=50, lr=3e-3))
data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8), prefetch=0)
tr = Trainer(rc, mesh, autotune_every=2)
tr.init_or_restore()
hist = tr.run(iter(data), 8, log_every=0, log=lambda s: None)
stats = get_telemetry().path("train:interpod").summary()
rep = MPW.Init().Report()
print("RESULT:" + json.dumps({
    "steps": len(hist),
    "transfers": stats["transfers"],
    "plan_bytes": stats["plan"]["payload_bytes"],
    "n_retunes": len(stats["retunes"]),
    "report_keys": sorted(rep),
    "losses_finite": all(h["loss"] == h["loss"] for h in hist),
}))
"""


def test_trainer_online_autotune_end_to_end(multidev):
    """The full loop: measured step times drive the controller, the trainer
    swaps executables, telemetry + MPW.Report stay populated (acceptance)."""
    res = multidev(_TRAIN_AUTOTUNE)
    assert res["steps"] == 8 and res["losses_finite"]
    # compile-spike steps (one per newly built executable) are excluded
    # from telemetry, so transfers <= steps
    assert 1 <= res["transfers"] <= 8
    assert res["plan_bytes"] > 0
    assert res["n_retunes"] >= 1, "controller never proposed a re-tune"
    assert "train:interpod" in res["report_keys"]


def test_report_populated_by_train_step_build():
    """Acceptance: per-path stats are non-empty after a training run.

    Building the train step records the cross-pod gradient plan; executing
    steps records timings.  Exercised here via the cheapest real entry point
    (build on a single-device mesh) so the test runs without multi-pod
    devices; the full loop is covered by benchmarks/fig1 and test_runtime.
    """
    import jax

    from repro.configs.base import RunConfig, get_config, smoke_config
    from repro.configs.base import SHAPES
    from repro.runtime.step import build_train_step

    get_telemetry().reset("train:interpod")
    rc = RunConfig(model=smoke_config(get_config("qwen1.5-0.5b")),
                   shape=SHAPES["train_4k"])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    build_train_step(rc, mesh)
    stats = get_telemetry().path("train:interpod").summary()
    assert stats["plan"]["payload_bytes"] > 0
    assert stats["plan"]["streams_configured"] >= 1
    rep = MPW.Init().Report()
    assert "train:interpod" in rep and rep["train:interpod"]["plan"]
