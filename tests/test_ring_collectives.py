"""Ring collectives (repro/core/ring.py): numeric equivalence of the
ring/ring2 algorithms against the psum baseline across pod counts and
compress modes, odd-P rings, the gateway-subgroup site exchange, the wire
byte model, and regressions for the satellites that rode along (vectorized
dequant-sum, negative scatter dims, honest WAN telemetry)."""
from __future__ import annotations

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# numeric equivalence on real (fake-CPU) devices
# ---------------------------------------------------------------------------

# 4-pod ring: every (algo, compress) cell must reproduce the psum sum.  The
# (6,4) leaf extent is NOT divisible by 4, so the padding path is exercised;
# (3,) and the scalar hit the tiny-leaf and psum-fallback paths.
_EQUIV = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, streamed_psum
from repro.configs.base import CommConfig

mesh = jax.make_mesh((4,2), ("pod","data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
tree = {"a": jnp.arange(24., dtype=jnp.float32).reshape(6,4) + 1.0,
        "b": jnp.ones((3,), jnp.float32), "c": jnp.float32(2.0)}
out = {}
for algo in ("psum", "ring", "ring2"):
    for compress in ("none", "bf16", "int8"):
        comm = CommConfig(streams=4, chunk_mb=0.00005, compress=compress,
                          algo=algo)
        path = WidePath(axis="pod", comm=comm, name=f"{algo}-{compress}")
        def body(t):
            r = jax.lax.axis_index("pod").astype(jnp.float32)
            t = jax.tree.map(lambda x: x * (1.0 + r), t)
            return streamed_psum(t, path, dims={"a": 0, "b": 0, "c": None})
        f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          axis_names={"pod"}, check_vma=False)
        with jax.set_mesh(mesh):
            got = jax.jit(f)(tree)
        err = float(jnp.max(jnp.abs(got["a"] - tree["a"]*10)
                            / (jnp.abs(tree["a"]*10))))
        out[f"{algo}/{compress}"] = {"err": err, "c": float(got["c"]),
                                     "b0": float(got["b"][0])}
print("RESULT:" + json.dumps(out))
"""


def test_ring_matches_psum_all_modes(multidev):
    res = multidev(_EQUIV)
    for key, r in res.items():
        # int8 ring requantizes the partial sum each hop, so error grows
        # with hop count (still bounded by ~P * absmax/127 per element)
        tol = 0.08 if "int8" in key else 0.01
        assert r["err"] < tol, (key, r)
        assert abs(r["c"] - 20.0) < 20.0 * tol, (key, r)
        assert abs(r["b0"] - 10.0) < 10.0 * tol, (key, r)


# odd-P ring: 3 pods — the (6,4) leaf divides evenly, the (5,) leaf pads
_ODD = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, streamed_psum
from repro.configs.base import CommConfig

mesh = jax.make_mesh((3, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
tree = {"a": jnp.arange(24., dtype=jnp.float32).reshape(6, 4) + 1.0,
        "b": jnp.linspace(1., 2., 5).astype(jnp.float32)}
out = {}
for algo in ("ring", "ring2"):
    for compress in ("none", "int8"):
        comm = CommConfig(streams=2, chunk_mb=0.00005, compress=compress,
                          algo=algo)
        path = WidePath(axis="pod", comm=comm, name=f"{algo}-{compress}")
        def body(t):
            r = jax.lax.axis_index("pod").astype(jnp.float32)
            t = jax.tree.map(lambda x: x * (1.0 + r), t)
            return streamed_psum(t, path, dims={"a": 0, "b": 0})
        f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          axis_names={"pod"}, check_vma=False)
        with jax.set_mesh(mesh):
            got = jax.jit(f)(tree)
        out[f"{algo}/{compress}"] = {
            "err_a": float(jnp.max(jnp.abs(got["a"] - tree["a"]*6)
                                   / (jnp.abs(tree["a"]*6)))),
            "err_b": float(jnp.max(jnp.abs(got["b"] - tree["b"]*6)
                                   / (jnp.abs(tree["b"]*6)))),
        }
print("RESULT:" + json.dumps(out))
"""


def test_odd_pod_count_ring(multidev):
    res = multidev(_ODD, ndev=6)
    for key, r in res.items():
        tol = 0.08 if "int8" in key else 1e-6
        assert r["err_a"] < tol, (key, r)
        assert r["err_b"] < tol, (key, r)


# reduce-scatter / all-gather building blocks vs the lax primitives
_RSAG = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import ring_all_gather, ring_reduce_scatter

mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
x = jnp.arange(32., dtype=jnp.float32).reshape(8, 4)

def body(t):
    r = jax.lax.axis_index("pod").astype(jnp.float32)
    mine = t * (1.0 + r)
    rs = ring_reduce_scatter(mine, 0, "pod")
    rs_ref = jax.lax.psum_scatter(mine, "pod", scatter_dimension=0, tiled=True)
    ag = ring_all_gather(rs, 0, "pod")
    ag_ref = jax.lax.all_gather(rs_ref, "pod", axis=0, tiled=True)
    return jnp.max(jnp.abs(rs - rs_ref)), jnp.max(jnp.abs(ag - ag_ref))
f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
                  axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    rs_err, ag_err = jax.jit(f)(x)
print("RESULT:" + json.dumps({"rs_err": float(rs_err),
                              "ag_err": float(ag_err)}))
"""


def test_ring_rs_ag_match_lax_primitives(multidev):
    res = multidev(_RSAG)
    assert res["rs_err"] == 0.0
    assert res["ag_err"] == 0.0


# site-hierarchical exchange: ring over the gateway subgroup must deliver
# the same global sum as the masked-psum fallback, and the /wan plan must
# account gateway-subgroup bytes (satellite: WAN telemetry overcounting)
_SITE = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, streamed_psum, get_telemetry
from repro.configs.base import CommConfig

mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
tree = {"a": jnp.arange(24., dtype=jnp.float32).reshape(6, 4) + 1.0,
        "c": jnp.float32(2.0)}
groups = [[0, 1], [2, 3]]
out = {}
for algo in ("psum", "ring", "ring2"):
    comm = CommConfig(streams=2, chunk_mb=0.00005, algo=algo)
    path = WidePath(axis="pod", comm=comm, name=f"site-{algo}")
    def body(t):
        r = jax.lax.axis_index("pod").astype(jnp.float32)
        t = jax.tree.map(lambda x: x * (1.0 + r), t)
        return streamed_psum(t, path, dims={"a": 0, "c": None},
                             site_groups=groups)
    f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                      axis_names={"pod"}, check_vma=False)
    with jax.set_mesh(mesh):
        got = jax.jit(f)(tree)
    wan = get_telemetry().path(f"site-{algo}:interpod/wan").plan
    out[algo] = {"err": float(jnp.max(jnp.abs(got["a"] - tree["a"]*10))),
                 "c": float(got["c"]),
                 "payload": wan.payload_bytes, "wire": wan.wire_bytes,
                 "algo": wan.algo}
print("RESULT:" + json.dumps(out))
"""


def test_site_gateway_exchange_and_wan_accounting(multidev):
    res = multidev(_SITE)
    payload = res["psum"]["payload"]
    for algo, r in res.items():
        assert r["err"] < 1e-4, (algo, r)
        assert r["c"] == pytest.approx(20.0), (algo, r)
        assert r["algo"] == algo
        # gateway-subgroup accounting: S=2 of P=4 pods carry the WAN bytes,
        # so the per-pod average is 2*(S-1)/S * payload * S/P = payload/2 —
        # NOT the full payload the pre-fix plan implied every pod shipped
        assert r["wire"] == payload // 2, (algo, r)


# ---------------------------------------------------------------------------
# wire-byte model (host-side; the acceptance bound)
# ---------------------------------------------------------------------------

def test_wire_byte_model_acceptance_bound():
    from repro.core.ring import wire_bytes_per_pod
    n = 64 << 20  # f32 payload bytes
    for P in (2, 4, 8):
        ring_int8 = wire_bytes_per_pod(n, P, algo="ring", compress="int8")
        # acceptance: int8 ring moves <= 2*(P-1)/P * n/4 per pod
        assert ring_int8 <= 2 * (P - 1) / P * n / 4 + 1e-9
        gather_int8 = wire_bytes_per_pod(n, P, algo="psum", compress="int8")
        assert gather_int8 == (P - 1) * n / 4      # linear in P
        assert gather_int8 / ring_int8 == pytest.approx(P / 2)
        # uncompressed psum is XLA's own ring: no gather penalty to beat
        assert (wire_bytes_per_pod(n, P, algo="psum")
                == wire_bytes_per_pod(n, P, algo="ring"))
    assert wire_bytes_per_pod(n, 1, algo="ring") == 0.0
    assert wire_bytes_per_pod(n, 4, algo="shift") == n


def test_tuner_picks_ring_on_compressed_multipod_link():
    """With the wire-byte model in the loop, the algo knob must climb from
    the gather-based psum to a ring on a bandwidth-bound 8-pod int8 path."""
    from repro.core.autotune import OnlineTuner, simulate_transfer_s
    from repro.core.path import WAN_LONDON_POZNAN as link
    tuner = OnlineTuner(streams=32, chunk_mb=8.0, algo="psum", window=3,
                        warmup=0)
    cfg = tuner.config()
    for i in range(600):
        t = simulate_transfer_s(64 << 20, link, streams=cfg["streams"],
                                chunk_bytes=cfg["chunk_mb"] * (1 << 20),
                                pacing=cfg["pacing"], algo=cfg["algo"],
                                world=8, compress="int8",
                                jitter=0.02, seed=i)
        new = tuner.observe(t)
        if new is not None:
            cfg = new
        if tuner.converged:
            break
    assert tuner.converged
    assert tuner.best_config()["algo"] in ("ring", "ring2"), tuner.best_config()


# ---------------------------------------------------------------------------
# satellite regressions (host-side)
# ---------------------------------------------------------------------------

def test_normalize_dims_negative_means_last_dim():
    """Regression: d=-1 used to silently remap to dim 0, which can slice
    across a TP-sharded dimension; it must mean the last dim."""
    import jax.numpy as jnp

    from repro.core.streams import normalize_dims, plan_chunks
    leaves = [jnp.zeros((4, 6)), jnp.zeros((3, 5, 7)), jnp.zeros(()),
              jnp.zeros((8,))]
    dims = [-1, -2, None, 0]
    norm = normalize_dims(leaves, dims)
    assert norm == [1, 1, None, 0]
    # planning over the normalized dims slices the stated dim, not dim 0
    chunks = plan_chunks(leaves, norm, chunk_bytes=64)
    spans = sorted((c.start, c.start + c.size)
                   for c in chunks if c.leaf == 0)
    assert spans[0][0] == 0 and spans[-1][1] == 6   # tiles dim 1 (extent 6)


def test_normalize_dims_fallbacks_unchanged():
    import jax.numpy as jnp

    from repro.core.streams import normalize_dims
    leaves = [jnp.zeros((4, 6)), jnp.zeros(())]
    assert normalize_dims(leaves, None) == [0, None]
    assert normalize_dims(leaves, [None, None]) == [0, None]
    assert normalize_dims(leaves, [1, 0]) == [1, None]  # scalar: no dim
    # out-of-range positive dims pass through (the chunk planner fails
    # loudly at trace time) rather than silently wrapping onto dim 0
    assert normalize_dims([jnp.zeros((4, 6))], [5]) == [5]


def test_dequant_sum_matches_per_shard_loop():
    """Regression for the vectorized compressed_psum: the one-shot batch
    dequant-and-sum must equal the old per-shard dequant loop."""
    import jax.numpy as jnp

    from repro.core.compress import dequant_chunk, dequant_sum, quant_chunk
    rng = np.random.RandomState(0)
    x = rng.randn(3, 7, 5).astype(np.float32)
    for dim in (0, 1, 2):
        q, s, meta = quant_chunk(jnp.asarray(x), dim)
        # fake a gathered (P, ...) batch: same int8 payload, distinct scales
        qg = jnp.stack([q] * 4)
        sg = jnp.stack([s * (1.0 + p) for p in range(4)])
        got = dequant_sum(qg, sg, meta)
        want = sum(dequant_chunk(qg[p], sg[p], meta) for p in range(4))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert got.shape == x.shape


def test_plan_summary_carries_algo_and_wire_bytes():
    import jax.numpy as jnp

    from repro.core.streams import assign_streams, plan_chunks, plan_summary
    leaves = [jnp.zeros((64, 8), jnp.float32)]
    chunks = plan_chunks(leaves, [0], chunk_bytes=512)
    buckets = assign_streams(chunks, 4)
    s = plan_summary(chunks, buckets, 4, 512, algo="ring", world=4,
                     compress="int8")
    assert s["algo"] == "ring"
    n = 64 * 8 * 4
    assert s["payload_bytes"] == n
    assert s["wire_bytes"] == round(2 * 3 / 4 * n / 4)
    # default: unknown world -> no wire claim (falls back to payload)
    s1 = plan_summary(chunks, buckets, 4, 512)
    assert s1["wire_bytes"] == 0
