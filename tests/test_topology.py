"""Topology subsystem: sites/links/route planning, multi-hop WidePaths, the
Forwarder relay, per-hop tuning, and the site-hierarchical collective —
host-side planning plus numerics on 8 fake CPU devices (subprocess)."""
from __future__ import annotations

import pytest

from repro.configs.base import CommConfig
from repro.core.autotune import RouteTuner
from repro.core.path import Hop, LinkSpec, WidePath
from repro.core.topology import (Forwarder, LinkProfile, Topology,
                                 cosmogrid_topology)


def _triangle() -> Topology:
    """a--b--c chain plus a direct a--c link that is low-latency but thin:
    metrics must disagree (latency -> direct, width -> via b)."""
    t = Topology()
    for n in ("a", "b", "c"):
        t.add_site(n)
    t.connect("a", "b", LinkProfile("ab", 10e-3, 100e6))
    t.connect("b", "c", LinkProfile("bc", 10e-3, 100e6))
    t.connect("a", "c", LinkProfile("ac-thin", 5e-3, 10e6))
    return t


def test_route_metrics_disagree():
    t = _triangle()
    assert t.route("a", "c", metric="latency").sites == ("a", "c")
    assert t.route("a", "c", metric="hops").sites == ("a", "c")
    wide = t.route("a", "c", metric="width")
    assert wide.sites == ("a", "b", "c")
    assert wide.profiles[wide.bottleneck].bandwidth_Bps == 100e6


def test_route_disconnected_raises():
    t = Topology()
    t.add_site("x")
    t.add_site("y")
    with pytest.raises(KeyError):
        t.route("x", "y")
    with pytest.raises(KeyError):
        t.route("x", "nosuch")
    with pytest.raises(ValueError):
        t.route("x", "x")     # 0-hop route would degrade to a real shift


def test_observe_hop_validation():
    from repro.core import MPW
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=2)
    mpw.setAutoTuning(pid, True, online=True)
    with pytest.raises(ValueError):
        mpw.Observe(pid, 0.1, hop=3)   # out of range for a 1-hop path
    # hop=0 on a single-link path is the path itself: the controller advances
    for _ in range(20):
        mpw.Observe(pid, 0.1, hop=0)
    assert mpw.paths[pid].tuner.history


def test_site_allreduce_rejects_unequal_groups():
    from repro.core.collectives import site_allreduce
    t = Topology()
    t.add_site("big", n_pods=2)
    t.add_site("small", n_pods=1)
    with pytest.raises(ValueError, match="equal pods per site"):
        # raises host-side even outside a mesh (before any collective):
        # TPU psum lowering cannot take unequal axis_index_groups
        site_allreduce({"g": None}, WidePath(), t.pod_groups())


def test_cosmogrid_forwarder_route():
    """Tokyo<->Espoo has no direct link: the planner must relay through
    Amsterdam (the paper's Forwarder scenario), >=2 hops."""
    t = cosmogrid_topology()
    r = t.route("tokyo", "espoo")
    assert r.sites == ("tokyo", "amsterdam", "espoo")
    assert r.n_hops == 2
    # shifts compose to the net gateway delta
    assert sum(r.shifts) == t.site("espoo").gateway - t.site("tokyo").gateway
    # store-and-forward time strictly exceeds either leg alone
    s = r.modeled_s(16 << 20)
    assert s > max(p.transfer_s(16 << 20) for p in r.profiles)


def test_pod_groups_must_tile_axis():
    t = Topology()
    t.add_site("a", pods=(0, 1))
    t.add_site("b", pods=(3,))   # hole at 2
    with pytest.raises(ValueError):
        t.pod_groups()
    t2 = Topology()
    t2.add_site("a", n_pods=2)
    t2.add_site("b", n_pods=2)
    assert t2.pod_groups() == [[0, 1], [2, 3]]
    assert t2.gateways() == [0, 2]
    assert t2.site_of_pod(3).name == "b"


def test_multihop_path_knobs_target_bottleneck():
    slow = Hop("slow", LinkSpec("slow", 50e-3, 10e6),
               CommConfig(streams=64, chunk_mb=1.0), shift=1)
    fast = Hop("fast", LinkSpec("fast", 1e-3, 1e9),
               CommConfig(streams=4, chunk_mb=32.0), shift=1)
    p = WidePath(name="t").with_hops((fast, slow))
    assert p.bottleneck == 1
    assert p.link.name == "slow"          # with_hops rebinds to bottleneck
    assert p.streams == 64
    p2 = p.with_(streams=128, chunk_mb=2.0)
    assert p2.route[1].streams == 128     # knob write lands on the slow hop
    assert p2.route[0].streams == 4       # fast hop untouched
    assert p2.streams == 128
    p3 = p.with_hop(0, streams=2)
    assert p3.route[0].streams == 2 and p3.route[1].streams == 64
    assert p.hop_keys() == [p.hop_key(0), p.hop_key(1)]
    assert p.hop_key(1).startswith(p.key + "/hop1:")


def test_route_as_hops_bottleneck_comm_override():
    t = cosmogrid_topology()
    r = t.route("tokyo", "espoo")
    tuned = CommConfig(streams=7, chunk_mb=3.0)
    hops = r.as_hops(bottleneck_comm=tuned)
    assert hops[r.bottleneck].comm.streams == 7
    other = 1 - r.bottleneck
    assert hops[other].comm.streams == r.profiles[other].streams


def test_route_tuner_per_hop():
    t = cosmogrid_topology()
    fwd = Forwarder(t, "tokyo", "espoo")
    rt = RouteTuner(fwd.path, window=2, warmup=0)
    # per-hop observation drives only that hop's controller
    cfg = None
    for _ in range(4):
        cfg = rt.observe(0, 1.0) or cfg
    assert cfg is not None and set(cfg) == {"streams", "chunk_mb", "pacing"}
    assert not rt.tuners[1].history
    # end-to-end observation advances every hop, split by modeled share
    retunes = {}
    for _ in range(6):
        retunes.update(rt.observe_total(2.0, nbytes=64 << 20))
    assert rt.tuners[1].history      # the other hop's controller moved too


_MULTIDEV = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.base import CommConfig
from repro.core import (MPW, Topology, LinkProfile, WidePath, streamed_psum,
                        get_telemetry, relay, sendrecv)

mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
out = {}
mpw = MPW.Init()
pid = mpw.CreatePath(axis="pod", nstreams=2)
mpw.setChunkSize(pid, 1 << 12)

# (1) negative-shift Send/Recv symmetry: Recv undoes Send
def sym_body(x):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    v = {"v": x + me}
    sent = mpw.Send(pid, v, shift=1)       # receive from pod-1
    back = mpw.Recv(pid, sent, shift=1)    # receive from pod+1: undoes it
    direct = mpw.Recv(pid, v, shift=1)     # from pod+1 directly
    return v["v"], back["v"], sent["v"], direct["v"]
f = jax.jit(jax.shard_map(sym_body, mesh=mesh, in_specs=(P(),),
                          out_specs=(P("pod"),) * 4, axis_names={"pod"},
                          check_vma=False))
with jax.set_mesh(mesh):
    own, back, sent, direct = f(jnp.zeros((4, 2)))
out["own"] = [float(own[4 * i, 0]) for i in range(4)]
out["back"] = [float(back[4 * i, 0]) for i in range(4)]
out["sent"] = [float(sent[4 * i, 0]) for i in range(4)]
out["direct"] = [float(direct[4 * i, 0]) for i in range(4)]

# (2) Relay(hops=2) == two composed SendRecvs; multi-hop facade path
path = mpw.path(pid)
def relay_body(x):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    v = {"v": x + me}
    two = relay(v, path, 2)
    composed = sendrecv(sendrecv(v, path, 1), path, 1)
    fac = mpw.Relay(pid, v, hops=2)
    return two["v"], composed["v"], fac["v"]
f2 = jax.jit(jax.shard_map(relay_body, mesh=mesh, in_specs=(P(),),
                           out_specs=(P("pod"),) * 3, axis_names={"pod"},
                           check_vma=False))
with jax.set_mesh(mesh):
    two, composed, fac = f2(jnp.zeros((4, 2)))
out["relay2"] = [float(two[4 * i, 0]) for i in range(4)]
out["composed"] = [float(composed[4 * i, 0]) for i in range(4)]
out["facade"] = [float(fac[4 * i, 0]) for i in range(4)]

# (3) forwarder: 2-hop route (a->b->c gateways 0,1,2) == direct 2-shift,
# and Relay on a multi-hop path follows the route
chain = Topology()
for n in ("a", "b", "c"):
    chain.add_site(n)
chain.connect("a", "b", LinkProfile("ab", 1e-3, 1e9, streams=2, chunk_mb=0.001))
chain.connect("b", "c", LinkProfile("bc", 20e-3, 1e8, streams=4, chunk_mb=0.001))
fpid = mpw.CreateForwarder(chain, "a", "c")
out["fwd_hops"] = len(mpw.path(fpid).route)
def fwd_body(x):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    v = {"v": x + me}
    relayed = mpw.Relay(fpid, v)
    return relayed["v"]
f3 = jax.jit(jax.shard_map(fwd_body, mesh=mesh, in_specs=(P(),),
                           out_specs=P("pod"), axis_names={"pod"},
                           check_vma=False))
with jax.set_mesh(mesh):
    r3 = f3(jnp.zeros((4, 2)))
out["fwd"] = [float(r3[4 * i, 0]) for i in range(4)]
hops_stats = mpw.PathStats(fpid)["hops"]
out["hop_plans"] = [h["plan"]["n_chunks"] if h.get("plan") else 0
                    for h in hops_stats]

# (4) site-hierarchical psum == flat psum numerically; scatter dims are
# threaded (the pod_shift/streamed_psum dims contract), and per-stage
# telemetry records intra/wan plans
topo = Topology()
topo.add_site("s0", n_pods=2)
topo.add_site("s1", n_pods=2)
topo.connect("s0", "s1", LinkProfile("wan", 50e-3, 1e8))
groups = topo.pod_groups()
hier = WidePath(axis="pod", name="hier",
                comm=CommConfig(streams=2, chunk_mb=0.0625))
# leaf "a" is 512 KiB so the 64 KiB chunk floor still yields 8 chunks cut
# along dim 1 (the stated scatter dim)
tree = {"a": (jnp.arange(4 * 32768, dtype=jnp.float32) % 97).reshape(4, 32768),
        "c": jnp.float32(1.5)}
def site_body(t):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    t = jax.tree.map(lambda x: x * (1 + me), t)
    return streamed_psum(t, hier, dims={"a": 1, "c": None},
                         site_groups=groups)
f4 = jax.jit(jax.shard_map(site_body, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), axis_names={"pod"},
                           check_vma=False))
with jax.set_mesh(mesh):
    got = f4(tree)
out["site_a_err"] = float(jnp.max(jnp.abs(got["a"] - tree["a"] * 10)))
out["site_c"] = float(got["c"])
rep = get_telemetry().report(prefix="hier:interpod")
out["hier_keys"] = sorted(rep)
# dim=1 slicing of the (4,6) leaf at 100-byte chunks: 6 cols of 16B ->
# ceil(100/16)=6 rows... chunks along dim1; must be >1 chunk for "a"
out["wan_chunks"] = rep["hier:interpod/wan"]["plan"]["n_chunks"]
print("RESULT:" + json.dumps(out))
"""


_TRAIN_ROUTE = r"""
import json
import jax
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime import Trainer
from repro.core.topology import Topology, LinkProfile
from repro.core import get_telemetry
from repro.data import DataConfig, make_pipeline

# WAN chain a -> b -> c: the train path notionally relays via b; the slow
# b->c hop is the bottleneck rc.comm drives
t = Topology()
for n in ("a", "b", "c"):
    t.add_site(n)
t.connect("a", "b", LinkProfile("lan-ab", 1e-4, 5e9, streams=1, chunk_mb=32.0))
t.connect("b", "c", LinkProfile("wan-bc", 50e-3, 1e8, streams=32, chunk_mb=1.0))
route = t.route("a", "c")

cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
               comm=CommConfig(mode="hierarchical", streams=4, chunk_mb=0.01,
                               autotune=False),
               train=TrainConfig(zero1=True, warmup_steps=2, total_steps=50))
data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8), prefetch=0)
out = {}
with jax.set_mesh(mesh):
    tr = Trainer(rc, mesh, route=route, site_groups=[[0], [1]])
    tr.init_or_restore()
    hist = tr.run(data, 4, log_every=0)
p = tr.bundle.path
out["n_hops"] = p.n_hops
out["bottleneck_streams"] = p.streams          # rc.comm drives the slow hop
out["losses_finite"] = all(h["loss"] == h["loss"] for h in hist)
rep = get_telemetry().report(prefix=p.key)
out["keys"] = sorted(rep)
out["hop_transfers"] = [rep[k]["transfers"] for k in sorted(rep)
                        if "/hop" in k]
print("RESULT:" + json.dumps(out))
"""


def test_trainer_route_wiring(multidev):
    """A route-wired Trainer trains, the bottleneck hop carries rc.comm's
    knobs, and per-hop telemetry (plans + time splits) is populated."""
    res = multidev(_TRAIN_ROUTE)
    assert res["n_hops"] == 2
    assert res["bottleneck_streams"] == 4
    assert res["losses_finite"]
    assert any("/hop0:" in k for k in res["keys"])
    assert any("/hop1:" in k for k in res["keys"])
    # steps after the compile step record per-hop samples
    assert all(t >= 1 for t in res["hop_transfers"]), res


def test_multihop_and_site_collectives(multidev):
    res = multidev(_MULTIDEV)
    own = res["own"]
    assert own == [0.0, 1.0, 2.0, 3.0]
    # Send: pod p holds pod p-1's value; Recv: pod p+1's; Recv(Send(x)) == x
    assert res["sent"] == [3.0, 0.0, 1.0, 2.0]
    assert res["direct"] == [1.0, 2.0, 3.0, 0.0]
    assert res["back"] == own, "Recv must undo Send (negative-shift symmetry)"
    # Relay(hops=2) == composed shifts, on the raw paths and the facade
    assert res["relay2"] == [2.0, 3.0, 0.0, 1.0]
    assert res["composed"] == res["relay2"] == res["facade"]
    # forwarder: 2 hops a->b->c, net shift +2
    assert res["fwd_hops"] == 2
    assert res["fwd"] == [2.0, 3.0, 0.0, 1.0]
    assert all(n >= 1 for n in res["hop_plans"]), "per-hop plans recorded"
    # site-hierarchical psum: exact global sum, both stages in telemetry
    assert res["site_a_err"] < 1e-3
    assert res["site_c"] == pytest.approx(15.0)   # 1.5 * (1+2+3+4)
    assert res["hier_keys"] == ["hier:interpod/intra", "hier:interpod/wan"]
    assert res["wan_chunks"] > 1, "dims must thread into the chunk plan"
