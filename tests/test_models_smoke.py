"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward/train step on CPU, assert output shapes + no NaNs.
Decode-capable archs also run one serve step against a cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, smoke_config
from repro.models import batch_concrete, build_model
from repro.models.param import tree_abstract, tree_init

ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(get_config(arch))
            model = build_model(cfg)
            params = tree_init(model.param_defs(), seed=0)
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(arch, built):
    cfg, model, params = built(arch)
    batch = batch_concrete(cfg, "train", 2, 32)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    assert 2.0 < float(loss) < 12.0, f"{arch} loss {float(loss)} implausible"


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(arch, built):
    cfg, model, params = built(arch)
    batch = batch_concrete(cfg, "train", 2, 16)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat), arch
    total = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32)))) for x in flat)
    assert total > 0, f"{arch}: all-zero gradients"


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch, built):
    cfg, model, params = built(arch)
    batch = batch_concrete(cfg, "prefill", 2, 24)
    logits = model.logits(params, batch)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


DECODE_ARCHS = [a for a in ARCHS if get_config(a).family != "vlm"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_step(arch, built):
    cfg, model, params = built(arch)
    B, W = 2, 32
    cache = tree_init(model.cache_defs(B, W), seed=0)  # zeros
    tokens = jnp.array([[1], [2]], jnp.int32)
    logits, cache2 = model.decode_step(params, cache, jnp.int32(0), tokens)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # one more step re-using the updated cache
    logits2, _ = model.decode_step(params, cache2, jnp.int32(1), tokens)
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-780m", "zamba2-1.2b",
                                  "whisper-medium"])
def test_prefill_matches_decode(arch, built):
    """Prefill then decode must equal running the decode loop token by token.

    Run in f32 so the check isolates *structural* parity (cache indexing,
    rope positions, state recurrences) from bf16 re-quantization drift,
    which SSD recurrences amplify."""
    cfg, model, _ = built(arch)
    to_f32 = lambda t: jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, t)
    params = to_f32(tree_init(model.param_defs(), seed=0))
    B, S = 1, 8
    batch = to_f32(batch_concrete(cfg, "prefill", B, S))
    logits_pref, cache = model.prefill(params, batch)
    # decode the same tokens one by one from an empty cache — except the
    # cross-attention K/V, which only prefill (the encoder pass) can supply
    cache2 = to_f32(tree_init(model.cache_defs(B, max(S, 8)), seed=0))
    if "xk" in cache2:
        cache2 = dict(cache2, xk=cache["xk"].astype(jnp.float32),
                      xv=cache["xv"].astype(jnp.float32))
    toks = batch["tokens"]
    logits_step = None
    for i in range(S):
        dbatch = toks[:, i:i + 1]
        logits_step, cache2 = model.decode_step(params, cache2, jnp.int32(i), dbatch)
    a = np.asarray(logits_pref[:, -1], np.float32).ravel()
    b = np.asarray(logits_step[:, -1], np.float32).ravel()
    np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-3)
    assert np.argmax(a) == np.argmax(b)


def test_vlm_prefix_alignment(built):
    """pixtral: loss sees only text positions; patch count changes hidden len."""
    cfg, model, params = built("pixtral-12b")
    batch = batch_concrete(cfg, "train", 2, 16)
    assert batch["patch_embeds"].shape[1] == cfg.vision_tokens
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_sliding_window_arch_ignores_distant_context(built):
    """danube (SWA): tokens beyond the stacked receptive field (num_layers x
    window) must not change the last-position logits."""
    cfg, model, params = built("h2o-danube-3-4b")
    W = cfg.sliding_window
    S = cfg.num_layers * W + 40   # receptive field of last pos starts > 40
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab_size, size=(1, S)).astype(np.int32)
    t2 = t1.copy()
    t2[0, :8] = (t1[0, :8] + 7) % cfg.vocab_size
    l1 = model.logits(params, {"tokens": jnp.asarray(t1)})
    l2 = model.logits(params, {"tokens": jnp.asarray(t2)})
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-3)


def test_long_context_skip_rules():
    long = SHAPES["long_500k"]
    from repro.configs import cell_applicable
    runs = [a for a in ARCHS if cell_applicable(get_config(a), long)[0]]
    assert sorted(runs) == ["h2o-danube-3-4b", "mamba2-780m", "zamba2-1.2b"]
