"""Doc-consistency gate: docs cannot silently rot.

Every fenced ```python block in `docs/*.md` and `README.md` must (a) be
valid syntax and (b) actually execute against the library — each block runs
in a subprocess with 8 fake CPU devices and `src` on the path.  A block
that is illustrative rather than self-contained opts out with a marker
line immediately above its fence:

    <!-- docs-test: skip -->

(skipped blocks are still compiled).  A second audit asserts every public
`MPW` facade verb is documented in docs/api.md, so new verbs cannot land
undocumented.
"""
from __future__ import annotations

import inspect
import os
import re
import subprocess
import sys
from dataclasses import dataclass

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_MARK = "<!-- docs-test: skip -->"


@dataclass(frozen=True)
class DocBlock:
    path: str          # repo-relative markdown file
    lineno: int        # 1-based line of the opening fence
    lang: str          # fence info string ("python", "bash", "", ...)
    skip: bool         # opted out of execution
    code: str

    @property
    def id(self) -> str:
        return f"{self.path}:{self.lineno}"


def _doc_files() -> list[str]:
    out = ["README.md"]
    docs = os.path.join(REPO, "docs")
    out += sorted(os.path.join("docs", f) for f in os.listdir(docs)
                  if f.endswith(".md"))
    return out


def _extract_blocks(relpath: str) -> list[DocBlock]:
    with open(os.path.join(REPO, relpath)) as f:
        lines = f.read().splitlines()
    blocks: list[DocBlock] = []
    i = 0
    while i < len(lines):
        m = re.match(r"^```(\S*)\s*$", lines[i])
        if m and m.group(1):              # opening fence with an info string
            lang, start = m.group(1), i
            body = []
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            skip = start > 0 and lines[start - 1].strip() == SKIP_MARK
            blocks.append(DocBlock(relpath, start + 1, lang, skip,
                                   "\n".join(body) + "\n"))
        i += 1
    return blocks


ALL_BLOCKS = [b for f in _doc_files() for b in _extract_blocks(f)]
PY_BLOCKS = [b for b in ALL_BLOCKS if b.lang == "python"]
RUN_BLOCKS = [b for b in PY_BLOCKS if not b.skip]


def test_docs_contain_python_blocks():
    # the gate is vacuous if extraction breaks: pin a floor
    assert len(PY_BLOCKS) >= 5, [b.id for b in PY_BLOCKS]
    assert len(RUN_BLOCKS) >= 4, [b.id for b in RUN_BLOCKS]


@pytest.mark.parametrize("block", PY_BLOCKS, ids=lambda b: b.id)
def test_python_block_compiles(block):
    compile(block.code, block.id, "exec")     # skipped blocks too


@pytest.mark.parametrize("block", RUN_BLOCKS, ids=lambda b: b.id)
def test_python_block_executes(block):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", block.code], env=env,
                         text=True, capture_output=True, timeout=600,
                         cwd=REPO)
    assert out.returncode == 0, (
        f"{block.id} failed (rc={out.returncode}):\n"
        f"STDOUT:\n{out.stdout[-2000:]}\nSTDERR:\n{out.stderr[-3000:]}")


def test_every_mpw_verb_is_documented():
    """docs/api.md must mention every public facade verb (the audit that
    caught the File* verbs landing undocumented)."""
    from repro.core import MPW

    with open(os.path.join(REPO, "docs", "api.md")) as f:
        api_md = f.read()
    verbs = [n for n, _ in inspect.getmembers(MPW, inspect.isfunction)
             if not n.startswith("_") and n != "path"]  # path(): accessor
    assert len(verbs) >= 25, verbs            # the facade really was scanned
    missing = [v for v in verbs if f"{v}(" not in api_md]
    assert not missing, f"undocumented MPW verbs: {missing}"
