"""Chunk-planning edge cases: zero-dim leaves, degenerate shapes, leaves
smaller than a chunk, uneven round-robin balance, and plan summaries."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.streams import (assign_streams, leaf_bytes, plan_chunks,
                                plan_summary, slice_chunk, stitch_leaf)


def test_scalar_leaf_single_chunk():
    """A zero-dim leaf (loss scale, step counter) is one chunk, unsliced."""
    x = jnp.float32(3.0)
    chunks = plan_chunks([x], [None], chunk_bytes=1 << 20)
    assert len(chunks) == 1 and chunks[0].nbytes == 4
    assert slice_chunk(x, chunks[0]) is x
    assert stitch_leaf(x, [(chunks[0], x)]) is x


def test_zero_size_leaf():
    """A (0,)-shaped leaf plans to one empty chunk and round-trips."""
    x = jnp.zeros((0,), jnp.float32)
    chunks = plan_chunks([x], [0], chunk_bytes=64)
    assert len(chunks) == 1 and chunks[0].nbytes == 0
    out = stitch_leaf(x, [(chunks[0], slice_chunk(x, chunks[0]))])
    assert out.shape == (0,)


def test_leaf_smaller_than_chunk_is_not_split():
    x = jnp.zeros((8, 4), jnp.float32)      # 128 B
    chunks = plan_chunks([x], [0], chunk_bytes=1 << 20)
    assert len(chunks) == 1
    assert slice_chunk(x, chunks[0]).shape == x.shape


def test_dim_of_size_one_is_not_split():
    """shape[dim] == 1 cannot be cut even when the leaf exceeds chunk_bytes."""
    x = jnp.zeros((1, 4096), jnp.float32)   # 16 KiB > chunk_bytes
    chunks = plan_chunks([x], [0], chunk_bytes=1024)
    assert len(chunks) == 1 and chunks[0].nbytes == leaf_bytes(x)


def test_row_larger_than_chunk_still_progresses():
    """bytes_per_row > chunk_bytes: chunks degrade to one row each, and the
    plan still tiles the dim exactly."""
    x = jnp.zeros((5, 1024), jnp.float32)   # 4 KiB rows, 1 KiB chunks
    chunks = plan_chunks([x], [0], chunk_bytes=1024)
    assert len(chunks) == 5
    spans = sorted((c.start, c.start + c.size) for c in chunks)
    assert spans[0][0] == 0 and spans[-1][1] == 5
    assert all(b == c for (_, b), (c, _) in zip(spans, spans[1:]))


def test_uneven_round_robin_balance():
    """7 equal chunks on 4 streams: no stream gets more than 2; all chunks
    appear exactly once."""
    x = jnp.zeros((7, 256), jnp.float32)
    chunks = plan_chunks([x], [0], chunk_bytes=1024)
    assert len(chunks) == 7
    buckets = assign_streams(chunks, 4)
    assert len(buckets) == 4
    sizes = sorted(len(b) for b in buckets)
    assert sizes == [1, 2, 2, 2]
    seen = sorted((c.start for b in buckets for c in b))
    assert seen == [c.start for c in sorted(chunks, key=lambda c: c.start)]


def test_more_streams_than_chunks_collapses():
    """Streams are capped at the chunk count (paper: a payload cut into K
    pieces cannot feed more than K channels)."""
    x = jnp.zeros((2, 256), jnp.float32)
    chunks = plan_chunks([x], [0], chunk_bytes=1024)
    buckets = assign_streams(chunks, 256)
    assert len(buckets) == len(chunks) == 2


def test_mixed_tree_balance_by_bytes():
    """Descending-size round robin keeps max load within 2x of the mean even
    with wildly uneven leaves."""
    leaves = [jnp.zeros((64, 64), jnp.float32),   # 16 KiB
              jnp.zeros((3,), jnp.float32),       # 12 B
              jnp.zeros((), jnp.float32)]         # 4 B
    chunks = plan_chunks(leaves, [0, 0, None], chunk_bytes=2048)
    buckets = assign_streams(chunks, 4)
    loads = [sum(c.nbytes for c in b) for b in buckets]
    assert max(loads) <= 2 * (sum(loads) / len(loads)) + 2048


def test_chunk_bytes_sum_exactly_across_shapes():
    """Regression for the bytes_per_row = nb // n truncation: summed chunk
    nbytes must equal leaf bytes exactly for every leaf (plan_chunks now
    asserts this; payload_bytes / telemetry GB/s depend on it), including
    prime extents, both cut dims, and tail chunks."""
    leaves, dims = [], []
    for shape in [(7, 3), (3, 5), (13, 4), (5, 7, 2), (31,), (2, 9)]:
        for dtype in (jnp.bfloat16, jnp.float32, jnp.int8):
            for d in range(len(shape)):
                leaves.append(jnp.zeros(shape, dtype))
                dims.append(d)
    for chunk_bytes in (1, 16, 48, 1 << 20):
        chunks = plan_chunks(leaves, dims, chunk_bytes=chunk_bytes)
        per_leaf: dict[int, int] = {}
        for c in chunks:
            per_leaf[c.leaf] = per_leaf.get(c.leaf, 0) + c.nbytes
        for i, l in enumerate(leaves):
            assert per_leaf[i] == leaf_bytes(l), (i, chunk_bytes)


def test_chunk_bytes_remainder_absorbed_by_last_chunk():
    """A (7, 5) f32 leaf cut along dim 0 into 2-row chunks: 3 full chunks +
    one 1-row tail; byte totals must be exact whatever the cut."""
    x = jnp.zeros((7, 5), jnp.float32)   # 140 B; 20 B rows
    chunks = plan_chunks([x], [0], chunk_bytes=48)   # 2 rows per chunk
    assert [c.size for c in chunks] == [2, 2, 2, 1]
    assert sum(c.nbytes for c in chunks) == 140
    s = plan_summary(chunks, assign_streams(chunks, 2), 2, 48)
    assert s["payload_bytes"] == leaf_bytes(x) == 140


def test_plan_summary_fields():
    leaves = [jnp.zeros((64, 64), jnp.float32), jnp.zeros((), jnp.float32)]
    chunks = plan_chunks(leaves, [0, None], chunk_bytes=2048)
    buckets = assign_streams(chunks, 4)
    s = plan_summary(chunks, buckets, streams_configured=4, chunk_bytes=2048,
                     pacing=0.5)
    assert s["payload_bytes"] == 64 * 64 * 4 + 4
    assert s["n_chunks"] == len(chunks)
    assert s["streams_used"] == len(buckets) <= 4
    assert s["chunk_bytes"] == 2048 and s["pacing"] == 0.5
    assert s["load_balance"] >= 1.0


def test_plan_summary_on_abstract_leaves():
    """The runtime records plans at build time from ShapeDtypeStructs —
    planning must not require concrete arrays."""
    import jax

    leaves = [jax.ShapeDtypeStruct((128, 32), jnp.float32),
              jax.ShapeDtypeStruct((), jnp.float32)]
    chunks = plan_chunks(leaves, [0, None], chunk_bytes=4096)
    buckets = assign_streams(chunks, 8)
    s = plan_summary(chunks, buckets, 8, 4096)
    assert s["payload_bytes"] == 128 * 32 * 4 + 4
    assert s["n_chunks"] == int(np.ceil(128 * 32 * 4 / 4096)) + 1
