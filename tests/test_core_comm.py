"""MPWide core: wide_allreduce modes, compression, relay, MPW API —
numerically validated on 8 fake CPU devices (subprocess)."""
from __future__ import annotations

import numpy as np
import pytest

_ALLREDUCE = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, wide_allreduce
from repro.configs.base import CommConfig

mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
tree = {"a": jnp.arange(24., dtype=jnp.float32).reshape(6,4),
        "b": jnp.ones((3,), jnp.float32), "c": jnp.float32(2.0)}
out = {}
for mode, compress, streams, pacing in [
        ("flat","none",1,1.0), ("hierarchical","none",4,1.0),
        ("hierarchical","none",4,0.5), ("gateway","none",4,1.0),
        ("hierarchical","bf16",4,1.0), ("hierarchical","int8",4,1.0)]:
    comm = CommConfig(mode=mode, streams=streams, chunk_mb=0.00005,
                      compress=compress, pacing=pacing)
    path = WidePath(axis="pod", comm=comm)
    def body(t):
        r = jax.lax.axis_index("pod") * 2 + jax.lax.axis_index("data")
        t = jax.tree.map(lambda x: x * (1.0 + r.astype(jnp.float32)), t)
        return wide_allreduce(t, path, data_axes=("data",),
                              dims={"a":0,"b":0,"c":None})
    f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                      axis_names={"pod","data"}, check_vma=False)
    with jax.set_mesh(mesh):
        got = jax.jit(f)(tree)
    err = float(jnp.max(jnp.abs(got["a"] - tree["a"]*10) / (jnp.abs(tree["a"]*10)+1)))
    out[f"{mode}/{compress}/p{pacing}"] = {
        "err": err, "c": float(got["c"]), "b0": float(got["b"][0])}
print("RESULT:" + json.dumps(out))
"""


def test_wide_allreduce_all_modes(multidev):
    res = multidev(_ALLREDUCE)
    for key, r in res.items():
        tol = 0.05 if "int8" in key else 0.01
        assert r["err"] < tol, (key, r)
        assert abs(r["c"] - 20.0) < 20.0 * tol, (key, r)
        assert abs(r["b0"] - 10.0) < 10.0 * tol, (key, r)


_RING = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath, sendrecv, relay, cycle, barrier, MPW
from repro.configs.base import CommConfig
mesh = jax.make_mesh((4,2), ("pod","data"), axis_types=(jax.sharding.AxisType.Auto,)*2)
path = WidePath(axis="pod", comm=CommConfig(streams=2, chunk_mb=0.0001))
out = {}

def body(x):
    me = jax.lax.axis_index("pod").astype(jnp.float32)
    recv = sendrecv({"v": x + me}, path, 1)       # from pod-1
    hop2 = relay({"v": x + me}, path, 2)          # two hops
    barrier()
    return recv["v"], hop2["v"]
f = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=(P("pod"), P("pod")),
                  axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    r1, r2 = jax.jit(f)(jnp.zeros((4,2)))
# out_specs P("pod") stacks the (4,2) per-pod locals -> global (16,2);
# pod p's value sits at row 4*p
out["recv"] = [float(r1[4*i,0]) for i in range(4)]
out["hop2"] = [float(r2[4*i,0]) for i in range(4)]

mpw = MPW.Init()
pid = mpw.CreatePath(axis="pod", nstreams=4)
mpw.setChunkSize(pid, 1<<14); mpw.setPacingRate(pid, 0.5); mpw.setWin(pid, 1<<16)
mpw.setAutoTuning(pid, True, payload_bytes=1<<20)
def body2(x):
    got, tok = mpw.ISendRecv(pid, {"x": x + jax.lax.axis_index("pod").astype(jnp.float32)})
    assert mpw.Has_NBE_Finished(tok)
    got = mpw.Wait(got, tok)
    return got["x"]
f2 = jax.shard_map(body2, mesh=mesh, in_specs=(P(),), out_specs=P("pod"),
                   axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    r3 = jax.jit(f2)(jnp.zeros((4,2)))
out["mpw"] = [float(r3[4*i,0]) for i in range(4)]
out["tuned_streams"] = mpw.path(pid).streams
print("RESULT:" + json.dumps(out))
"""


def test_ring_and_api(multidev):
    res = multidev(_RING)
    # pod i receives from pod i-1 (mod 4)
    assert res["recv"] == [3.0, 0.0, 1.0, 2.0]
    assert res["hop2"] == [2.0, 3.0, 0.0, 1.0]
    assert res["mpw"] == [3.0, 0.0, 1.0, 2.0]
    assert res["tuned_streams"] >= 1


def test_stream_plan_covers_payload():
    """Chunk planning: every element is in exactly one chunk; streams are
    load-balanced."""
    import jax.numpy as jnp

    from repro.core.streams import assign_streams, plan_chunks
    leaves = [jnp.zeros((64, 8)), jnp.zeros((5,)), jnp.zeros(())]
    chunks = plan_chunks(leaves, [0, 0, None], chunk_bytes=256)
    # leaf 0: 64 rows of 32B -> 8 rows/chunk -> 8 chunks
    per_leaf = {}
    for c in chunks:
        per_leaf.setdefault(c.leaf, []).append(c)
    spans = sorted((c.start, c.start + c.size) for c in per_leaf[0])
    assert spans[0][0] == 0 and spans[-1][1] == 64
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, "chunks must tile the dim exactly"
    buckets = assign_streams(chunks, 4)
    assert 1 <= len(buckets) <= 4
    loads = [sum(c.nbytes for c in b) for b in buckets]
    assert max(loads) <= 2 * (sum(loads) / len(loads)) + 256


def test_autotuner_matches_paper_guidance():
    from repro.core.autotune import tune
    from repro.core.path import ICI, WAN_LONDON_POZNAN
    wan = tune(256 << 20, WAN_LONDON_POZNAN, world=2)
    local = tune(256 << 20, ICI, world=16)
    assert wan.streams >= 32, "WAN links want many streams (paper: >=32)"
    assert wan.streams <= 256, "up to 256 streams remain efficient (paper)"
    assert local.streams <= 32, "local links want few streams (paper: 1)"
    # exposure model sanity: more chunks can't make total transfer faster
    # than the bandwidth floor
    assert wan.modeled_link_s >= (2 * 0.5 * 256 * 2**20) / WAN_LONDON_POZNAN.bandwidth_Bps
