"""Trainer fault tolerance, straggler detection, elastic restart (subprocess
multi-device), plus the detector's unit behaviour."""
from __future__ import annotations

from repro.runtime import StragglerDetector


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(alpha=0.3, z_thresh=3.0)
    for i in range(20):
        det.observe(i, 0.1)
    assert det.observe(20, 10.0) is True
    assert det.flagged and det.flagged[-1][0] == 20


def test_straggler_detector_tolerates_drift():
    det = StragglerDetector(alpha=0.3, z_thresh=3.0)
    t = 0.1
    flagged = 0
    for i in range(50):
        t *= 1.02  # slow drift should adapt, not flag
        flagged += det.observe(i, t)
    assert flagged == 0


_FAULT = r"""
import json, tempfile, os
import jax
from repro.configs import get_config, smoke_config, RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime import Trainer, InjectedFault, elastic_restart
from repro.data import DataConfig, make_pipeline

cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
               comm=CommConfig(mode="hierarchical", streams=4, chunk_mb=0.001),
               train=TrainConfig(zero1=True, warmup_steps=2, total_steps=50, lr=3e-3))
data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8), prefetch=0)
faults = {6}
def hook(step):
    if step in faults:
        faults.discard(step)
        raise InjectedFault("boom")

out = {}
with tempfile.TemporaryDirectory() as d, jax.set_mesh(mesh):
    tr = Trainer(rc, mesh, ckpt_dir=d+"/c", replica_dir=d+"/r", ckpt_every=4,
                 fault_hook=hook)
    tr.init_or_restore()
    hist = tr.run(data, 10, log_every=0)
    out["final_step"] = tr.step
    out["ran"] = len(hist)
    out["recovered"] = 6 not in faults
    tr.manager.gatherer.stop()
    out["replica"] = sorted(os.listdir(d+"/r"))
    mesh2 = jax.make_mesh((4,2), ("data","model"),
                          axis_types=(jax.sharding.AxisType.Auto,)*2)
    with jax.set_mesh(mesh2):
        tr2 = elastic_restart(rc, tr, mesh2)
        out["elastic_step"] = tr2.step
        h2 = tr2.run(data, 2, log_every=0)
        out["elastic_losses_finite"] = all(r["loss"] == r["loss"] for r in h2)
        tr2.close()
print("RESULT:" + json.dumps(out))
"""


def test_fault_recovery_and_elastic(multidev):
    res = multidev(_FAULT, timeout=1800)
    assert res["final_step"] == 10
    assert res["recovered"]
    assert res["ran"] >= 10           # includes replayed steps after restore
    assert any(s.startswith("step_") for s in res["replica"])
    assert res["elastic_step"] == 10  # restored on a smaller mesh
    assert res["elastic_losses_finite"]
