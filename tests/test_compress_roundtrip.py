"""quant_chunk/dequant_chunk round-trips: the per-chunk int8 compression the
cross-pod path applies must survive scalar leaves, extents that are not a
multiple of QBLOCK, and non-trailing scatter dims."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import QBLOCK, dequant_chunk, quant_chunk


def _roundtrip(x, dim):
    q, s, meta = quant_chunk(jnp.asarray(x), dim)
    return np.asarray(dequant_chunk(q, s, meta))


def test_scalar_leaf_roundtrip():
    """0-dim leaves (loss scale, step counter) quantize via a (1,1) view and
    come back as the same scalar shape/dtype."""
    x = jnp.float32(3.25)
    y = _roundtrip(x, 0)
    assert y.shape == ()
    assert y == pytest.approx(3.25, rel=1e-2)


def test_non_multiple_of_qblock_extent():
    """Extents that don't divide QBLOCK are padded for the kernel and the
    pad must be sliced back off — shape and values round-trip."""
    for n in (1, 5, QBLOCK - 1, QBLOCK + 3, 2 * QBLOCK + 17):
        x = np.linspace(-4.0, 4.0, n, dtype=np.float32)
        y = _roundtrip(x, 0)
        assert y.shape == (n,)
        # blockwise absmax int8: error bounded by scale = absmax/127
        assert np.max(np.abs(y - x)) <= 4.0 / 127 + 1e-6


def test_non_trailing_dim_roundtrip():
    """Quantizing along a non-last scatter dim moves it to the back and must
    move it home on dequant."""
    rng = np.random.RandomState(0)
    x = rng.randn(3, 7, 5).astype(np.float32)
    for dim in (0, 1, 2):
        y = _roundtrip(x, dim)
        assert y.shape == x.shape
        assert np.max(np.abs(y - x)) <= np.max(np.abs(x)) / 127 + 1e-6


def test_bf16_leaf_roundtrip_keeps_dtype():
    x = jnp.asarray(np.arange(10.0, dtype=np.float32)).astype(jnp.bfloat16)
    q, s, meta = quant_chunk(x, 0)
    y = dequant_chunk(q, s, meta)
    assert y.dtype == jnp.bfloat16
    assert np.max(np.abs(np.asarray(y, np.float32)
                         - np.arange(10.0, dtype=np.float32))) <= 9.0 / 127 + 0.1
