"""Error-hygiene regression tests: every ValueError core/ raises must NAME
the offending shape/knob/key (mpwlint rule R3 enforces the shape of the
message; these tests pin each message's content), plus the typed errors the
bare-assert promotions introduced.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import CommConfig
from repro.core import ring
from repro.core.path import INTERPOD, WidePath


def _path(**comm_kw) -> WidePath:
    return WidePath(axis="pod", comm=CommConfig(**comm_kw))


# -- collectives --------------------------------------------------------------

def test_streamed_psum_unknown_algo_names_algo():
    from repro.core.collectives import streamed_psum
    with pytest.raises(ValueError, match=r"unknown comm algo 'bogus'"):
        streamed_psum({"g": np.zeros(4, np.float32)}, _path(algo="bogus"))


def test_site_allreduce_unequal_sites_names_sizes():
    from repro.core.collectives import site_allreduce
    with pytest.raises(ValueError, match=r"equal pods per site.*\[1, 2\]"):
        site_allreduce({"g": np.zeros(4, np.float32)}, _path(),
                       site_groups=[[0], [1, 2]])


def test_wide_allreduce_unknown_mode_names_mode():
    from repro.core.collectives import wide_allreduce
    with pytest.raises(ValueError, match=r"unknown comm mode 'bogus'"):
        wide_allreduce({"g": np.zeros(4, np.float32)}, _path(mode="bogus"))


# -- buckets / streams --------------------------------------------------------

def test_plan_buckets_layer_mismatch_names_dims():
    from repro.core.buckets import plan_buckets
    leaves = [np.zeros((2, 3), np.float32), np.zeros((3, 3), np.float32)]
    with pytest.raises(ValueError,
                       match=r"disagree on the layers dim: \[2, 3\]"):
        plan_buckets(leaves, [True, True], 64)


# -- ring ---------------------------------------------------------------------

def test_ring_reduce_scatter_divisibility_names_extent(monkeypatch):
    # _ring_setup needs a live mesh axis; stub it so the shape check —
    # which precedes any collective — is reachable host-side.
    monkeypatch.setattr(ring, "_ring_setup",
                        lambda axis, sub: (3, 0, (0, 1, 2)))
    with pytest.raises(ValueError,
                       match=r"dim 0 extent 4 not divisible by world 3"):
        ring.ring_reduce_scatter(np.zeros((4, 2), np.float32), 0, "pod")


# -- MPW facade ---------------------------------------------------------------

def test_mpw_variadic_alignment_names_both_lengths():
    from repro.core.api import MPW
    m = MPW.Init()
    try:
        with pytest.raises(ValueError,
                           match=r"2 entries but links has 1"):
            m.CreatePathVariadic(streams_per_hop=(4, 4), links=[INTERPOD])
    finally:
        m.Finalize()


def test_mpw_set_algorithm_unknown_names_algo():
    from repro.core.api import MPW
    m = MPW.Init()
    try:
        pid = m.CreatePath()
        with pytest.raises(ValueError, match=r"unknown algo 'bogus'"):
            m.setAlgorithm(pid, "bogus")
    finally:
        m.Finalize()


def test_mpw_set_bucket_size_names_value():
    from repro.core.api import MPW
    m = MPW.Init()
    try:
        pid = m.CreatePath()
        with pytest.raises(ValueError, match=r"bucket size.*got -1"):
            m.setBucketSize(pid, -1)
    finally:
        m.Finalize()


def test_mpw_observe_hop_out_of_range_names_hop():
    from repro.core.api import MPW
    m = MPW.Init()
    try:
        pid = m.CreatePath()
        with pytest.raises(ValueError, match=r"hop 5 out of range"):
            m.Observe(pid, 0.1, hop=5)
    finally:
        m.Finalize()


# -- file transfer ------------------------------------------------------------

def test_file_transfer_unknown_codec_names_codec():
    from repro.core.filetransfer import FileTransfer
    with pytest.raises(ValueError, match=r"unknown file codec 'bogus'"):
        FileTransfer(_path(), compress="bogus")


# -- topology -----------------------------------------------------------------

def test_degrade_factor_names_factor():
    from repro.core.topology import LinkProfile
    prof = LinkProfile("l", 1e-3, 1e9)
    with pytest.raises(ValueError, match=r"\(0, 1\], got 1.5"):
        prof.degrade(1.5, (0, 5))


def test_unknown_fault_kind_names_kind():
    from repro.core.topology import Fault, LinkProfile
    prof = LinkProfile("l", 1e-3, 1e9).with_fault(Fault("bogus"))
    with pytest.raises(ValueError, match=r"unknown fault kind 'bogus'"):
        prof.health(0)


def test_duplicate_site_names_site():
    from repro.core.topology import Topology
    t = Topology()
    t.add_site("ams")
    with pytest.raises(ValueError, match=r"duplicate site 'ams'"):
        t.add_site("ams")


def test_pods_already_assigned_names_pods():
    from repro.core.topology import Topology
    t = Topology()
    t.add_site("a", pods=(0,))
    with pytest.raises(ValueError, match=r"pods \{0\} already assigned"):
        t.add_site("b", pods=(0,))


def test_pod_groups_gap_names_covered():
    from repro.core.topology import Topology
    t = Topology()
    t.add_site("a", pods=(1,))
    with pytest.raises(ValueError, match=r"must tile the pod axis.*\[1\]"):
        t.pod_groups()


def test_route_unknown_metric_names_metric():
    from repro.core.topology import cosmogrid_topology
    with pytest.raises(ValueError, match=r"unknown metric 'bogus'"):
        cosmogrid_topology().route("amsterdam", "tokyo", "bogus")


def test_route_coincident_endpoints_names_site():
    from repro.core.topology import cosmogrid_topology
    with pytest.raises(ValueError, match=r"tokyo -> tokyo.*coincide"):
        cosmogrid_topology().route("tokyo", "tokyo")


# -- chaos --------------------------------------------------------------------

def test_incident_log_unknown_kind_names_kind():
    from repro.core.chaos import IncidentLog
    with pytest.raises(ValueError, match=r"unknown incident kind 'bogus'"):
        IncidentLog().add(0, "bogus", "x")


# -- promoted bare asserts (R3 satellite) ------------------------------------

def test_quant_int8_ref_block_mismatch_names_shapes():
    from repro.kernels.ref import quant_int8_ref
    import jax.numpy as jnp
    with pytest.raises(ValueError, match=r"last dim 10.*block 256"):
        quant_int8_ref(jnp.zeros((4, 10)))


def test_quant_int8_2d_block_mismatch_names_shapes():
    from repro.kernels.quant import quant_int8_2d
    import jax.numpy as jnp
    with pytest.raises(ValueError, match=r"last dim 10.*block 256"):
        quant_int8_2d(jnp.zeros((4, 10)))


def test_flash_attention_gqa_mismatch_names_heads():
    from repro.kernels.ops import flash_attention
    import jax.numpy as jnp
    q = jnp.zeros((1, 4, 3, 8))
    kv = jnp.zeros((1, 4, 2, 8))
    with pytest.raises(ValueError, match=r"q heads 3.*kv heads 2"):
        flash_attention(q, kv, kv)


def test_flash_kernel_group_mismatch_names_heads():
    from repro.kernels.flash_attention import flash_attention_bhsd
    import jax.numpy as jnp
    q = jnp.zeros((3, 4, 8))
    kv = jnp.zeros((2, 4, 8))
    with pytest.raises(ValueError, match=r"q heads 3 != kv heads 2"):
        flash_attention_bhsd(q, kv, kv, group=2)


def test_pd_rank_mismatch_names_shape_and_axes():
    from repro.models.param import PD
    with pytest.raises(ValueError, match=r"shape \(2, 3\) and axes"):
        PD(shape=(2, 3), axes=("d",))


def test_trainer_run_without_state_raises_runtime_error():
    from repro.runtime.train_loop import Trainer
    t = Trainer.__new__(Trainer)
    t.state = None
    with pytest.raises(RuntimeError, match=r"init_or_restore"):
        t.run(iter([]), 1)
