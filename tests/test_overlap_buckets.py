"""Layer-bucketed backward overlap (repro/core/buckets.py).

Covers: bucket plans tile the layers dim exactly (remainder bucket absorbs
the tail), bucketed sync is numerically equivalent — bit-for-bit — to
accumulate-then-sync's whole-tree psum for psum/ring/ring2 × {none, bf16,
int8} × ZeRO on/off, per-bucket telemetry keys sum to the whole-tree bytes,
the backward-flush train step matches the unbucketed step, the bucketed
optimizer is exact, the tuner's fifth knob, the facade verb, and the
quant_int8 ragged-dim guard.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core import streams as st


def _leaves(L=7, d=8, f=384):
    import jax
    import jax.numpy as jnp
    return [jax.ShapeDtypeStruct((L, d, f), jnp.float32),
            jax.ShapeDtypeStruct((L, d), jnp.float32),
            jax.ShapeDtypeStruct((64, d), jnp.float32)]


# ---------------------------------------------------------------------------
# plan tiling
# ---------------------------------------------------------------------------

def test_bucket_plan_tiles_layers_exactly():
    leaves = _leaves(L=7, d=8, f=384)
    flags = bk.bucketable_flags(leaves, [True, True, False], [2, None, 0])
    # the replicated (L, d) leaf has no stated scatter dim -> rest bucket
    assert flags == [True, False, False]
    per_layer = 8 * 384 * 4
    plan = bk.plan_buckets(leaves, flags, bucket_bytes=2 * per_layer)
    assert plan.n_layers == 7 and plan.layers_per_bucket == 2
    bounds = plan.layer_bounds
    # tiles [0, 7) exactly: contiguous, no overlap, full coverage
    assert bounds[0][0] == 0 and bounds[-1][1] == 7
    for (_, hi), (lo, _) in zip(bounds, bounds[1:]):
        assert hi == lo
    # remainder bucket (the tail after 3 full 2-layer cuts) absorbs 1 layer
    sizes = sorted(hi - lo for lo, hi in bounds)
    assert sizes == [1, 2, 2, 2]
    # byte accounting is exact: layer buckets sum to the stacked bytes
    assert sum(b.nbytes for b in plan.layer_buckets) == plan.stacked_bytes
    assert plan.rest_bucket is not None
    assert plan.rest_bucket.nbytes == plan.rest_bytes
    # bucket 0 is the top of the stack (first grads backprop produces)
    assert plan.buckets[0].hi == 7


def test_bucket_plan_degenerate_cases():
    leaves = _leaves()
    # no stacked leaves -> everything in one rest bucket
    plan = bk.plan_buckets(leaves, [False, False, False], 1 << 20)
    assert plan.layer_buckets == () and plan.rest_bucket is not None
    # huge bucket -> one layer bucket covering the whole stack
    plan = bk.plan_buckets(leaves, [True, False, False], 1 << 30)
    assert len(plan.layer_buckets) == 1
    assert plan.layer_bounds == [(0, 7)]
    # mismatched layer dims raise
    import jax
    import jax.numpy as jnp
    bad = leaves + [jax.ShapeDtypeStruct((5, 8, 384), jnp.float32)]
    with pytest.raises(ValueError, match="disagree"):
        bk.plan_buckets(bad, [True, False, False, True], 1 << 20)


def test_aligned_chunks_match_full_leaf_geometry():
    """A bucket slice must be chunked with the full leaf's rows-per-chunk so
    int8 quantization blocks stay identical to the unbucketed transfer."""
    import jax
    import jax.numpy as jnp
    full = [jax.ShapeDtypeStruct((8, 8, 384), jnp.float32)]
    dims = [2]
    chunk_bytes = 1 << 16
    rows = st.chunk_rows(full[0], 2, chunk_bytes)
    assert rows is not None                # big enough to be chunked
    sliced = [jax.ShapeDtypeStruct((2, 8, 384), jnp.float32)]
    chunks = bk.aligned_chunks(full, sliced, [0], dims, chunk_bytes)
    # slice is below chunk_bytes, yet it must still be cut at the full
    # leaf's row boundaries (not shipped as one chunk)
    starts = sorted(c.start for c in chunks)
    full_chunks = st.plan_chunks(full, dims, chunk_bytes)
    assert starts == sorted(c.start for c in full_chunks)
    assert sum(c.nbytes for c in chunks) == 2 * 8 * 384 * 4


# ---------------------------------------------------------------------------
# numerical equivalence: bucketed == whole-tree, every algo x compression
# ---------------------------------------------------------------------------

_EQUIV = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import WidePath
from repro.core.buckets import bucketed_sync
from repro.core.collectives import streamed_psum
from repro.configs.base import CommConfig

mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
L, d, f, V = 7, 8, 384, 64
stacked = {"blocks": {"w": True, "b": True, "ln": True}, "embed": False}
dims = {"blocks": {"w": 2, "b": None, "ln": None}, "embed": 1}

def tree_for(zero):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    ff = f // 2 if zero else f          # ZeRO: scattered 1/D slices
    return {"blocks": {"w": jax.random.normal(ks[0], (L, d, ff), jnp.float32),
                       "b": jax.random.normal(ks[1], (L, d), jnp.float32),
                       "ln": jax.random.normal(ks[2], (L,), jnp.float32)},
            "embed": jax.random.normal(ks[3], (V, d), jnp.float32)}

out = {}
for zero in (True, False):
    tree = tree_for(zero)
    for algo in ("psum", "ring", "ring2"):
        for compress in ("none", "bf16", "int8"):
            comm = CommConfig(mode="hierarchical", streams=3, chunk_mb=0.0001,
                              compress=compress, algo=algo, bucket_mb=0.01)
            path = WidePath(axis="pod", comm=comm, name=f"eq-{algo}-{compress}-{zero}")

            def body(t):
                r = jax.lax.axis_index("pod").astype(jnp.float32)
                t = jax.tree.map(lambda x: x * (1.0 + r), t)
                whole = streamed_psum(t, path, dims=dims)
                bkt = bucketed_sync(t, path, stacked=stacked, dims=dims)
                return whole, bkt

            fn = jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                               out_specs=(P(), P()),
                               axis_names={"pod"}, check_vma=False)
            with jax.set_mesh(mesh):
                whole, bkt = jax.jit(fn)(tree)
            diff = max(jax.tree.leaves(jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))),
                whole, bkt)))
            out[f"{algo}/{compress}/zero={zero}"] = diff

# site-hierarchical stage (intra-site reduce, gateway ring) must survive
# bucketing bit-for-bit too — the chunk plan threads through site_allreduce
for algo, compress in [("ring", "int8"), ("psum", "int8")]:
    comm = CommConfig(mode="hierarchical", streams=3, chunk_mb=0.0001,
                      compress=compress, algo=algo, bucket_mb=0.01)
    path = WidePath(axis="pod", comm=comm, name=f"eqsite-{algo}")
    groups = [[0, 1], [2, 3]]

    def body(t):
        r = jax.lax.axis_index("pod").astype(jnp.float32)
        t = jax.tree.map(lambda x: x * (1.0 + r), t)
        whole = streamed_psum(t, path, dims=dims, site_groups=groups)
        bkt = bucketed_sync(t, path, stacked=stacked, dims=dims,
                            site_groups=groups)
        return whole, bkt

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
                       axis_names={"pod"}, check_vma=False)
    with jax.set_mesh(mesh):
        whole, bkt = jax.jit(fn)(tree_for(True))
    out[f"site/{algo}/{compress}"] = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), whole, bkt)))

# per-bucket telemetry accounting for one config
from repro.core.telemetry import get_telemetry
rep = get_telemetry().report(prefix="eq-psum-int8-True:interpod")
whole_plan = rep["eq-psum-int8-True:interpod"]["plan"]
bkts = {k: v["plan"] for k, v in rep.items() if "/bkt" in k}
out["n_bkt_keys"] = len(bkts)
out["payload_sum_matches"] = (
    sum(p["payload_bytes"] for p in bkts.values()) == whole_plan["payload_bytes"])
out["wire_sum_err"] = abs(sum(p["wire_bytes"] for p in bkts.values())
                          - whole_plan["wire_bytes"])
print("RESULT:" + json.dumps(out))
"""


def test_bucketed_sync_bit_identical_all_modes(multidev):
    res = multidev(_EQUIV)
    for key, diff in res.items():
        if "/" not in key:
            continue
        assert diff == 0.0, f"bucketed sync diverged for {key}: {diff}"
    assert res["n_bkt_keys"] >= 3
    assert res["payload_sum_matches"]
    # per-bucket wire bytes are rounded ints: allow one unit per bucket
    assert res["wire_sum_err"] <= res["n_bkt_keys"]


# ---------------------------------------------------------------------------
# backward flush + tail interleave inside the train step
# ---------------------------------------------------------------------------

_STEP = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config
from repro.configs.base import RunConfig, ShapeConfig, CommConfig, TrainConfig
from repro.runtime.step import build_train_step
from repro.models.registry import batch_concrete

cfg = smoke_config(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
out = {}
states = {}
for label, bucket_mb, compress, m in [
        ("off_m1", 0.0, "none", 1), ("flush_m1", 0.05, "none", 1),
        ("off_m2", 0.0, "none", 2), ("flush_m2", 0.05, "none", 2),
        ("off_int8", 0.0, "int8", 1), ("tail_int8", 0.05, "int8", 1)]:
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                   comm=CommConfig(mode="hierarchical", streams=4,
                                   chunk_mb=0.01, compress=compress,
                                   bucket_mb=bucket_mb, autotune=False),
                   train=TrainConfig(zero1=True, microbatches=m))
    with jax.set_mesh(mesh):
        b = build_train_step(rc, mesh)
        state = jax.device_put(b.init_state(0), jax.tree.map(
            lambda s: NamedSharding(mesh, s), b.state_specs,
            is_leaf=lambda x: isinstance(x, P)))
        batch = jax.device_put(batch_concrete(cfg, "train", 8, 32),
                               jax.tree.map(lambda s: NamedSharding(mesh, s),
                                            b.batch_specs,
                                            is_leaf=lambda x: isinstance(x, P)))
        state, metrics = b.fn(state, batch)
        states[label] = state
        out[label] = {"loss": float(metrics["loss"]),
                      "gnorm": float(metrics["grad_norm"]),
                      "n_buckets": len(b.bucket_plan.buckets) if b.bucket_plan else 0,
                      "window": b.compute_window}

def maxdiff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))

out["flush_m1_diff"] = maxdiff(states["off_m1"]["params"], states["flush_m1"]["params"])
out["flush_m2_diff"] = maxdiff(states["off_m2"]["params"], states["flush_m2"]["params"])
out["tail_int8_diff"] = maxdiff(states["off_int8"]["params"], states["tail_int8"]["params"])

from repro.core.telemetry import get_telemetry
rep = get_telemetry().report()
out["bkt_keys"] = sorted(k for k in rep if k.startswith("train:interpod/bkt"))
s = rep["train:interpod"]
out["exposed_s"] = s.get("exposed_s")
out["overlapped_s"] = s.get("overlapped_s")
from repro.core import MPW
out["report_has_overlap_cols"] = "exposed" in MPW.Init().Report(formatted=True)
print("RESULT:" + json.dumps(out))
"""


def test_bucketed_train_step_matches_unbucketed(multidev):
    res = multidev(_STEP, timeout=1200)
    for label in ("flush_m1", "flush_m2", "tail_int8"):
        assert res[label]["n_buckets"] >= 3, res[label]
    # the flush path re-rounds block grads through the bf16 param dtype
    # once; everything else is exact — one train step must agree tightly
    assert abs(res["off_m1"]["loss"] - res["flush_m1"]["loss"]) < 1e-5
    assert abs(res["off_m2"]["loss"] - res["flush_m2"]["loss"]) < 1e-5
    assert res["flush_m1_diff"] < 1e-3, res
    assert res["flush_m2_diff"] < 1e-3, res
    # tail mode (int8 wire forces it at tp>1) is bit-exact vs unbucketed
    assert res["tail_int8_diff"] == 0.0, res
    assert res["bkt_keys"], "per-bucket telemetry keys missing"
    assert res["exposed_s"] is not None and res["exposed_s"] > 0
    assert res["overlapped_s"] is not None
    assert res["report_has_overlap_cols"]


# ---------------------------------------------------------------------------
# bucketed optimizer is exact
# ---------------------------------------------------------------------------

def test_bucketed_adamw_bit_identical():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import TrainConfig
    from repro.optim import adamw_update, init_opt_state

    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    params = {"blocks": {"w": jax.random.normal(ks[0], (6, 4, 8), jnp.bfloat16),
                         "ln": jnp.ones((6, 4), jnp.bfloat16)},
              "embed": jax.random.normal(ks[1], (16, 4), jnp.bfloat16)}
    grads = {"blocks": {"w": jax.random.normal(ks[2], (6, 4, 8), jnp.float32),
                        "ln": jax.random.normal(ks[3], (6, 4), jnp.float32)},
             "embed": jnp.ones((16, 4), jnp.float32)}
    dims = {"blocks": {"w": 2, "ln": None}, "embed": 1}
    leaves = jax.tree.leaves(params)
    flags = bk.bucketable_flags(leaves, [True, True, False],
                                jax.tree.leaves(dims, is_leaf=lambda x: x is None))
    plan = bk.plan_buckets(leaves, flags, bucket_bytes=2 * 4 * 8 * 2)
    assert len(plan.layer_buckets) == 3
    tc = TrainConfig()
    lr = jnp.float32(1e-3)
    opt = init_opt_state(params)
    p1, o1, s1 = adamw_update(grads, opt, params, tc, lr, dims=dims)
    p2, o2, s2 = adamw_update(grads, opt, params, tc, lr, dims=dims,
                              buckets=plan, stacked=flags)
    for a, b in zip(jax.tree.leaves((p1, o1["m"], o1["v"])),
                    jax.tree.leaves((p2, o2["m"], o2["v"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(s1["grad_norm"]) == float(s2["grad_norm"])


# ---------------------------------------------------------------------------
# modeled exposure: the schedule the benchmark asserts on
# ---------------------------------------------------------------------------

def test_modeled_exposure_buckets_shrink_exposure():
    from repro.core.overlap import modeled_exposure
    from repro.core.path import WAN_LONDON_POZNAN as link
    payload = 64 << 20
    kw = dict(streams=32, chunk_bytes=1 << 18, world=2, compute_window=2.0)
    whole = modeled_exposure(payload, link, bucket_bytes=0, **kw)
    bkt = modeled_exposure(payload, link, bucket_bytes=8 << 20, **kw)
    # one whole sync after backward is fully exposed; buckets hide most
    assert whole["exposed_s"] >= 0.9 * whole["comm_s"]
    assert bkt["exposed_s"] < 0.5 * whole["exposed_s"]
    assert bkt["overlapped_s"] > 0
    # the exposed tail floors at one bucket's transfer time
    assert bkt["exposed_s"] >= max(bkt["per_bucket_s"]) * 0.99


# ---------------------------------------------------------------------------
# tuner fifth knob + facade verb + quant guard
# ---------------------------------------------------------------------------

def test_tuner_bucket_knob_and_pinning():
    from repro.core.autotune import BUCKET_GRID_MB, OnlineTuner
    t = OnlineTuner(streams=32, chunk_mb=8.0, bucket_mb=0.0, window=1,
                    warmup=0)
    assert t.config()["bucket_mb"] == 0.0
    seen = set()
    for i in range(200):
        cfg = t.observe(1.0 + 0.001 * (i % 3))
        if cfg is not None:
            assert cfg["bucket_mb"] in BUCKET_GRID_MB
            seen.add(cfg["bucket_mb"])
        if t.converged:
            break
    assert any(b > 0 for b in seen), "tuner never probed bucketing on"
    # pinning drops the knob from configs and reverts in-flight probes
    t2 = OnlineTuner(streams=32, chunk_mb=8.0, bucket_mb=16.0, window=1,
                     warmup=0)
    t2.pin_bucket()
    assert "bucket_mb" not in t2.config()
    assert t2.idx["bucket_mb"] == t2.best_idx["bucket_mb"]


def test_facade_set_bucket_size():
    from repro.core import MPW
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=4)
    mpw.setBucketSize(pid, 32 << 20)
    assert mpw.path(pid).comm.bucket_mb == 32.0
    assert mpw.path(pid).bucket_bytes == 32 << 20
    mpw.setBucketSize(pid, 0)
    assert mpw.path(pid).bucket_bytes == 0
    with pytest.raises(ValueError):
        mpw.setBucketSize(pid, -1)
    mpw.Finalize()


def test_quant_int8_ragged_dim_raises():
    import jax.numpy as jnp

    from repro.kernels import ops
    x = jnp.ones((4, 300), jnp.float32)
    with pytest.raises(ValueError, match=r"\(4, 300\).*block=256"):
        ops.quant_int8(x, block=256)
    with pytest.raises(ValueError):
        ops.quant_int8(jnp.float32(1.0))
