"""The bloodflow scenario (paper §1.2.2): two concurrently-running solvers on
different machines exchange boundary conditions every step through MPWide,
with latency hiding.

Here: a coarse "1D" solver lives on pod 0 and a fine "3D" solver on pod 1
(SPMD: both pods run both programs on their own data; the coupling exchange
is the pod-ring MPW_SendRecv).  Each outer step:
  1. both solvers advance their state (compute),
  2. boundary values are exchanged non-blocking (MPW_ISendRecv),
  3. MPW_Wait orders the receive before it is consumed next step —
     the exchange overlaps with the tail of compute, as in the paper.

Run:  PYTHONPATH=src python examples/couple_apps.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CommConfig
from repro.core import MPW

STEPS = 20
N = 512


def main():
    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=4)
    mpw.setChunkSize(pid, 1 << 12)

    def solver_step(u, boundary):
        # diffusion with the neighbour's boundary folded in at the edge
        u = u.at[0].set(0.5 * (u[0] + boundary))
        lap = jnp.roll(u, 1) - 2 * u + jnp.roll(u, -1)
        return u + 0.1 * lap

    def coupled(u0):
        def step(carry, _):
            u, boundary = carry
            u = solver_step(u, boundary)                       # compute
            got, tok = mpw.ISendRecv(pid, {"b": u[-1]})        # non-blocking
            new_boundary = mpw.Wait(got, tok)["b"]             # ordered
            return (u, new_boundary), jnp.mean(u)
        (u, _), means = jax.lax.scan(step, (u0, jnp.float32(0.0)),
                                     None, length=STEPS)
        mpw.Barrier()
        return u, means

    f = jax.jit(jax.shard_map(coupled, mesh=mesh, in_specs=(P(),),
                              out_specs=(P("pod"), P("pod")),
                              axis_names={"pod"}, check_vma=False))
    u0 = jnp.sin(jnp.linspace(0, 6.28, N))
    with jax.set_mesh(mesh):
        u, means = f(u0)
    means = means.reshape(2, STEPS)   # out_specs P("pod") stacks pods on dim0
    print(f"coupled solvers ran {STEPS} steps; per-pod mean trajectories:")
    print("  pod0:", [f"{float(x):.4f}" for x in means[0][::5]])
    print("  pod1:", [f"{float(x):.4f}" for x in means[1][::5]])
    assert jnp.isfinite(u).all()
    mpw.Finalize()
    print("couple_apps OK (MPW_ISendRecv/Wait/Barrier over the pod ring)")


if __name__ == "__main__":
    main()
