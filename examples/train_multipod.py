"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the learnable synthetic stream, with MPWide-hierarchical
gradient sync, ZeRO sharding, checkpoints + DataGather replication, and a
straggler report.

Run:  PYTHONPATH=src python examples/train_multipod.py [--steps 300]
(8 fake CPU devices arranged as 2 pods x 2 data x 2 model)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import jax

from repro.configs import CommConfig, ModelConfig, RunConfig, ShapeConfig, TrainConfig
from repro.data import DataConfig, make_pipeline
from repro.runtime import Trainer


def hundred_m_config() -> ModelConfig:
    """~100M llama-family model (the e2e deliverable target size)."""
    return ModelConfig(
        name="llama-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, rope_theta=10_000.0, remat=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"model: {cfg.name} params={cfg.param_count()/1e6:.1f}M")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    rc = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", args.seq_len, args.global_batch, "train"),
        comm=CommConfig(mode="hierarchical", streams=16, chunk_mb=2.0),
        train=TrainConfig(lr=args.lr, warmup_steps=args.steps // 10,
                          total_steps=args.steps, zero1=True, microbatches=2))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq_len,
                                    global_batch=args.global_batch,
                                    noise=0.02))
    with tempfile.TemporaryDirectory() as d, jax.set_mesh(mesh):
        tr = Trainer(rc, mesh, ckpt_dir=os.path.join(d, "ckpt"),
                     replica_dir=os.path.join(d, "replica"), ckpt_every=100)
        print("state:", tr.init_or_restore(),
              f"(ZeRO={tr.bundle.zero}, path: {tr.bundle.path.streams} streams "
              f"x {tr.bundle.path.chunk_bytes >> 20}MiB chunks)")
        hist = tr.run(data, args.steps, log_every=25)
        first = sum(h["loss"] for h in hist[:5]) / 5
        last = sum(h["loss"] for h in hist[-5:]) / 5
        print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps")
        print(f"stragglers flagged: {len(tr.detector.flagged)}")
        print(f"checkpoints: {tr.manager.steps()} (replicated via DataGather)")
        tr.close()


if __name__ == "__main__":
    main()
