"""Quickstart: the MPWide-style API in five minutes.

1. build a WidePath over the "pod" axis (a WAN-class link),
2. let the autotuner pick streams/chunks (paper: autotune on by default),
3. all-reduce a payload through it inside a training-style shard_map,
4. exchange point-to-point messages with the ring API (MPW_SendRecv),
5. read back per-path telemetry (MPW_PathStats / MPW_Report).

Run:  PYTHONPATH=src python examples/quickstart.py
(uses 8 fake CPU devices; real deployments use the production mesh)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CommConfig
from repro.core import MPW, WidePath, streamed_psum, wide_allreduce
from repro.core.autotune import autotune_path, tune
from repro.core.path import INTERPOD, WAN_LONDON_POZNAN


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)

    # --- 1+2: a tuned path ------------------------------------------------
    path = WidePath(axis="pod", comm=CommConfig(streams=32, chunk_mb=8.0))
    payload_bytes = 64 << 20
    path = autotune_path(path, payload_bytes, world=2)
    print(f"autotuned path: streams={path.streams} "
          f"chunk={path.chunk_bytes >> 20}MiB over {path.link.name}")
    t = tune(payload_bytes, WAN_LONDON_POZNAN, world=2)
    print(f"(the same payload on the paper's London-Poznan WAN would want "
          f"{t.streams} streams — the paper recommends >=32 on long links)")

    # --- 3: gradient-style all-reduce over the WAN stage --------------------
    grads = {"w": jnp.arange(1 << 16, dtype=jnp.float32)}

    def sync(g):
        return wide_allreduce(g, path, data_axes=("data",), dims={"w": 0})

    f = jax.jit(jax.shard_map(sync, mesh=mesh, in_specs=(P(),), out_specs=P(),
                              axis_names={"pod", "data"}, check_vma=False))
    with jax.set_mesh(mesh):
        out = f(grads)
    print(f"hierarchical wide_allreduce: sum over 4 DP ranks -> "
          f"w[1] = {float(out['w'][1])} (expected 4.0)")

    # --- 4: the MPW_* facade -------------------------------------------------
    mpw = MPW.Init()
    pid = mpw.CreatePath(axis="pod", nstreams=8)
    mpw.setChunkSize(pid, 1 << 20)

    def couple(x):
        me = jax.lax.axis_index("pod").astype(jnp.float32)
        got, token = mpw.ISendRecv(pid, {"boundary": x + me})
        got = mpw.Wait(got, token)        # latency hiding: work goes here
        mpw.Barrier()
        return got["boundary"]

    g = jax.jit(jax.shard_map(couple, mesh=mesh, in_specs=(P(),),
                              out_specs=P("pod"), axis_names={"pod"},
                              check_vma=False))
    with jax.set_mesh(mesh):
        recv = g(jnp.zeros((2, 4)))
    print(f"MPW_ISendRecv ring: pod0 received from pod1: {float(recv[0, 0])}")

    # --- 5: telemetry ------------------------------------------------------
    # host loops feed measured wall times back; here one timed eager call
    import time
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        jax.block_until_ready(g(jnp.zeros((2, 4))))
    mpw.Observe(pid, time.perf_counter() - t0)
    print("\nMPW_Report (per-path stats):")
    print(mpw.Report(formatted=True))
    mpw.Finalize()
    print("quickstart OK")


if __name__ == "__main__":
    main()
