"""Multi-site relay (paper: CosmoGrid across 4 supercomputers): sites
without direct connectivity exchange state through the Forwarder, and the
gradient-style all-reduce goes site-hierarchical so only gateway pods cross
the slow WAN hop.

Four single-pod sites form the CosmoGrid star (Tokyo and Espoo only reach
each other via Amsterdam).  Each outer step:
  1. every site advances a local state,
  2. Tokyo ships its boundary to Espoo through the 2-hop Forwarder route
     (store-and-forward via Amsterdam, per-hop chunking/streams),
  3. a site-aware AllReduce folds every site's scalar diagnostics.

Run:  PYTHONPATH=src python examples/multisite_relay.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import MPW, WidePath, cosmogrid_topology, get_telemetry, streamed_psum
from repro.configs.base import CommConfig

STEPS = 8
N = 256


def main():
    mesh = jax.make_mesh((4, 2), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    topo = cosmogrid_topology()          # 4 sites, no tokyo<->espoo link
    mpw = MPW.Init()
    fwd = mpw.CreateForwarder(topo, "tokyo", "espoo")
    print("forwarder route:", " -> ".join(h["name"] for h in mpw.Route(fwd)))
    groups = topo.pod_groups()
    ar_path = WidePath(axis="pod", name="diag",
                       comm=CommConfig(streams=2, chunk_mb=0.25))

    def coupled(u0):
        def step(carry, _):
            u, boundary = carry
            u = u.at[0].add(0.25 * boundary)             # fold the relay in
            u = u + 0.1 * (jnp.roll(u, 1) - 2 * u + jnp.roll(u, -1))
            got = mpw.Forward(fwd, {"b": u[-1]})          # 2-hop relay
            diag = streamed_psum({"m": jnp.mean(u)}, ar_path,
                                 site_groups=groups)      # site-aware reduce
            return (u, got["b"]), diag["m"]
        (u, _), means = jax.lax.scan(step, (u0, jnp.float32(0.0)),
                                     None, length=STEPS)
        mpw.Barrier()
        return u, means

    f = jax.jit(jax.shard_map(coupled, mesh=mesh, in_specs=(P(),),
                              out_specs=(P("pod"), P("pod")),
                              axis_names={"pod"}, check_vma=False))
    u0 = jnp.sin(jnp.linspace(0, 6.28, N))
    with jax.set_mesh(mesh):
        u, means = f(u0)
    assert jnp.isfinite(u).all()
    print(f"{STEPS} coupled steps across 4 sites; global mean trajectory:",
          [f"{float(x):.4f}" for x in means.reshape(4, STEPS)[0][::2]])

    # DataGather scenario: ship the run's output file over the same 2-hop
    # route (mpw-cp — chunked, checksummed, per-hop telemetry)
    import tempfile

    import numpy as np

    from repro.core import file_sha256

    d = tempfile.mkdtemp()
    out_file = os.path.join(d, "tokyo_output.npy")
    np.save(out_file, np.asarray(u))
    mpw.setChunkSize(fwd, 1 << 16)
    res = mpw.FileCopy(fwd, out_file, os.path.join(d, "espoo_mirror.npy"))
    assert file_sha256(os.path.join(d, "espoo_mirror.npy")) == res.sha256
    print(f"\nshipped {res.nbytes} B of output in {res.n_chunks} chunks "
          f"over {len(res.hop_wire_bytes)} hops (bit-exact)")

    print("\nper-hop stats (MPW.Report):\n")
    print(mpw.Report(formatted=True))
    mpw.Finalize()
    print("\nmultisite_relay OK (2-hop Forwarder + site-hierarchical psum "
          "+ mpw-cp file ship)")


if __name__ == "__main__":
    main()
