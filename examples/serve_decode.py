"""Serve a small model with batched requests: greedy decode against a KV
cache through the sharded serve step.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs import (CommConfig, RunConfig, ShapeConfig, TrainConfig,
                           get_config, smoke_config)
from repro.runtime import Server


def main():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    B, cache_len, new_tokens = 8, 128, 24
    rc = RunConfig(model=cfg, shape=ShapeConfig("d", cache_len, B, "decode"),
                   comm=CommConfig(), train=TrainConfig(zero1=True))
    with jax.set_mesh(mesh):
        server = Server(rc, mesh)
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(B, 1)).astype(np.int32)
        t0 = time.perf_counter()
        res = server.generate(prompts, max_new=new_tokens)
        dt = time.perf_counter() - t0
    print(f"served {B} requests x {new_tokens} tokens in {dt:.2f}s "
          f"({B*new_tokens/dt:.1f} tok/s on fake CPU devices)")
    print("sample continuations:")
    for i in range(3):
        print(f"  req{i}: {res.tokens[i][:12].tolist()}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
