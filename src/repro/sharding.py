"""Mesh-aware sharding helpers.

Model code calls :func:`constrain` with *intent* (which logical mesh axes a
dim belongs to); the helper silently drops axes that are absent from the
current mesh (e.g. ``"pod"`` on a single-pod mesh) or that are *manual* in the
enclosing ``shard_map`` (where GSPMD must not see them).  Outside any mesh the
helpers are no-ops, so the same model code runs in single-device smoke tests.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

DP_AXES: tuple[str, ...] = ("pod", "data")   # data-parallel axes (outer first)
TP_AXIS: str = "model"                        # tensor/expert-parallel axis

AxisEntry = Union[None, str, Sequence[str]]


def _auto_axes() -> set[str]:
    """Mesh axes GSPMD may shard over (present and not shard_map-manual)."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return set()
    if m is None:
        return set()
    names = getattr(m, "axis_names", ()) or ()
    if not names:
        return set()
    types = getattr(m, "axis_types", None)
    out = set()
    for i, n in enumerate(names):
        t = types[i] if types is not None and i < len(types) else None
        if t is not None and "Manual" in str(t):
            continue
        out.add(n)
    return out


def filter_spec(*entries: AxisEntry) -> Optional[P]:
    """Build a PartitionSpec keeping only currently-usable axes.

    Returns None when no axis survives (caller should skip the constraint).
    """
    usable = _auto_axes()
    if not usable:
        return None
    fixed: list[AxisEntry] = []
    nontrivial = False
    for e in entries:
        if e is None:
            fixed.append(None)
        elif isinstance(e, str):
            if e in usable:
                fixed.append(e)
                nontrivial = True
            else:
                fixed.append(None)
        else:
            kept = tuple(a for a in e if a in usable)
            if kept:
                fixed.append(kept if len(kept) > 1 else kept[0])
                nontrivial = True
            else:
                fixed.append(None)
    if not nontrivial:
        return None
    return P(*fixed)


def constrain(x: jax.Array, *entries: AxisEntry) -> jax.Array:
    """`with_sharding_constraint` that degrades gracefully.

    ``constrain(x, DP_AXES, None, TP_AXIS)`` shards dim0 over ("pod","data")
    and dim2 over "model" — on whatever subset of those axes exists and is
    GSPMD-visible right now.
    """
    spec = filter_spec(*entries)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def manual_axes_present(*names: str) -> tuple[str, ...]:
    """Which of `names` are *manual* axes right now (i.e. usable by explicit
    collectives like psum/ppermute). Inside shard_map, only the axes in
    `axis_names` qualify; auto axes would raise 'unbound axis name'."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    axis_names = getattr(m, "axis_names", ()) or ()
    types = getattr(m, "axis_types", None)
    out = []
    for i, n in enumerate(axis_names):
        if n not in names:
            continue
        t = types[i] if types is not None and i < len(types) else None
        if t is not None and "Manual" in str(t):
            out.append(n)
    return tuple(n for n in names if n in out)


def axis_size(name: str) -> int:
    try:
        m = jax.sharding.get_abstract_mesh()
        names = list(getattr(m, "axis_names", ()) or ())
        if name in names:
            return int(m.shape[name])
    except Exception:
        pass
    return 1
