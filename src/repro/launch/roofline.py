"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:
  compute    = HLO_FLOPs / (chips * peak_flops)
  memory     = HLO_bytes / (chips * hbm_bw)
  collective = sum over collective ops of per-device link bytes / link bw
               (ICI within a pod, the inter-pod link across pods)

collective bytes are NOT in cost_analysis(): we parse the compiled HLO and
apply per-algorithm factors (ring all-reduce 2(P-1)/P, gather/scatter
(P-1)/P, permute 1) with the replica-group span deciding which link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo_analysis import analyze as analyze_hlo  # noqa: F401

# hardware constants (assignment: TPU v5e)
PEAK_FLOPS = 197e12            # bf16 / chip
HBM_BW = 819e9                 # bytes/s / chip
ICI_BW = 50e9                  # bytes/s / link (intra-pod)
INTERPOD_BW = 6.25e9           # bytes/s / chip (cross-pod link)
POD_SIZE = 256

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(stext: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(stext):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    ici_bytes: float = 0.0          # per-device bytes over intra-pod links
    interpod_bytes: float = 0.0     # per-device bytes over the cross-pod link
    by_kind: dict = field(default_factory=dict)
    n_ops: int = 0


def _group_info(line: str, pod_size: int = POD_SIZE):
    """(group_size, crosses_pod). Handles explicit and iota replica groups."""
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x.strip()]
        size = len(ids)
        crosses = len({i // pod_size for i in ids}) > 1
        return size, crosses
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):  # iota transpose: v2 syntax [N,G]<=[dims]T(perm)
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(ngroups, gsize)
        crosses = bool(np.any(groups // pod_size
                              != groups[:, :1] // pod_size))
        return gsize, crosses
    return 1, False


def collect_collectives(hlo_text: str, pod_size: int = POD_SIZE) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).replace("-start", "")
        out_shape = m.group(1) or m.group(2)
        nbytes = shape_bytes(out_shape)
        P, crosses = _group_info(line, pod_size)
        if P <= 1:
            continue
        if kind == "all-reduce":
            link = 2.0 * (P - 1) / P * nbytes
        elif kind == "all-gather":
            link = (P - 1) / P * nbytes          # output is the gathered size
        elif kind == "reduce-scatter":
            # output is the scattered size; each device receives (P-1) shards
            link = (P - 1) * nbytes
        elif kind == "all-to-all":
            link = (P - 1) / P * nbytes
        else:  # collective-permute
            link = float(nbytes)
        st.n_ops += 1
        st.by_kind[kind] = st.by_kind.get(kind, 0.0) + link
        if crosses:
            st.interpod_bytes += link
        else:
            st.ici_bytes += link
    return st


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll: CollectiveStats
    chips: int
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        # cost_analysis flops are per-device (the SPMD program one chip runs)
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll.ici_bytes / ICI_BW + self.coll.interpod_bytes / INTERPOD_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): remat/dispatch waste detector."""
        total = self.flops * self.chips
        return (self.model_flops / total) if total else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "ici_bytes": self.coll.ici_bytes,
            "interpod_bytes": self.coll.interpod_bytes,
            "coll_by_kind": self.coll.by_kind,
            "n_coll_ops": self.coll.n_ops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference); N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch * 1  # decode: one token


def modeled_compute_window(cfg, shape, *, n_chips: int,
                           microbatches: int = 1) -> float:
    """Seconds of compute one *microbatch* offers for hiding WAN transfers.

    The FLOPs-roofline term of one microbatch (6·N·B·S / m over the fleet's
    peak): the window `autotune_path(compute_window=)` optimizes exposure
    against, and the budget the bucketed backward flush spreads its
    transfers over.  Deliberately analytic (no compiled HLO needed) so the
    step builder can call it on every retune; the full per-executable
    roofline lives in `benchmarks/roofline_report.py`.
    """
    flops = model_flops_for(cfg, shape)
    return flops / max(1, int(microbatches)) / (max(1, int(n_chips)) * PEAK_FLOPS)
