"""Scan-aware HLO static analyzer.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` lowered to ``while`` contributes its body a single time, so
FLOPs/bytes/collectives of layer-scanned models are undercounted by ~L×.
This analyzer parses the post-optimization HLO text, attributes per-op
costs to their computation, resolves while/call/fusion/conditional call
graphs, multiplies while bodies by their parsed trip counts, and returns
roofline-grade totals:

  flops            dot/convolution MACs ×2 (per device, SPMD program)
  bytes            Σ over ops of operand+result bytes (same naive model XLA
                   uses for "bytes accessed")
  collectives      per-device link bytes by kind, split ICI vs inter-pod

It is the profiling backbone for §Perf: per-computation tables show where
compute/collective time concentrates.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|calls|branch_computations|called_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops whose operands/results move no real HBM bytes
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "parameter", "constant",
             "after-all", "partition-id", "replica-id", "iota"}


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        out.append((dt, dims))
    return out


def _nbytes(dt: str, dims: list[int]) -> int:
    return int(np.prod(dims)) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)      # kind -> (ici, cross) bytes
    calls: list = field(default_factory=list)     # (name, kind) kind: while|call
    trip_hint: float = 1.0                        # condition constants
    ds_trip: float = 1.0                          # leading dims sliced to 1
    ds_like: bool = False                         # contains slice-type ops


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_ici: float = 0.0
    coll_cross: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    n_coll_ops: int = 0
    per_comp: dict = field(default_factory=dict)


def _dot_flops(res_dims: list[int], lhs_dims: list[int], line: str) -> float:
    m = _DOT_DIMS.search(line)
    k = 1
    if m:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    return 2.0 * float(np.prod(res_dims) if res_dims else 1.0) * k


def _group_info(line: str, pod_size: int):
    m = re.search(r"replica_groups=\{(\{[^}]*\})", line)
    if m:
        ids = [int(x) for x in m.group(1).strip("{}").split(",") if x.strip()]
        crosses = len({i // pod_size for i in ids}) > 1
        return len(ids), crosses
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        groups = ids.reshape(ngroups, gsize)
        crosses = bool(np.any(groups // pod_size != groups[:, :1] // pod_size))
        return gsize, crosses
    return 1, False


def _coll_link_bytes(kind: str, nbytes: float, line: str, pod_size: int):
    """(link_bytes, crosses) for one collective op with result size nbytes.

    XLA-CPU's AllReducePromotion pass widens bf16 all-reduce/reduce-scatter
    to f32 (convert -> collective -> convert); the TPU target runs them
    native bf16, so promoted collectives count at half width."""
    P, crosses = _group_info(line, pod_size)
    if P <= 1:
        return 0.0, False
    if "promoted" in line and "to_apply=" in line:
        nbytes *= 0.5
    if kind == "all-reduce":
        link = 2.0 * (P - 1) / P * nbytes
    elif kind == "all-gather":
        link = (P - 1) / P * nbytes         # result is the gathered size
    elif kind == "reduce-scatter":
        link = (P - 1) * nbytes             # result is the scattered shard
    elif kind == "all-to-all":
        link = (P - 1) / P * nbytes
    else:
        link = float(nbytes)
    return link, crosses


def parse_hlo(text: str, pod_size: int = 256):
    """Returns (comps, entry_name). Two passes: first collect every op's
    result size into a module-wide name table (operands are referenced by
    name only in post-opt HLO), then attribute costs."""
    name_bytes: dict[str, float] = {}
    name_dims: dict[str, list[int]] = {}
    lines = text.splitlines()
    for raw in lines:
        m = _OP_RE.match(raw.rstrip())
        if not m:
            continue
        name, body = m.group(1), m.group(2)
        # result type(s) = text before the op token
        opm = re.search(r"([a-z][a-z0-9\-]*)\(", body)
        res_text = body[:opm.start()] if opm else body
        shapes = _shapes_in(res_text)
        name_bytes[name] = sum(_nbytes(dt, d) for dt, d in shapes)
        name_dims[name] = shapes[0][1] if shapes else []

    comps: dict[str, CompCost] = {}
    cur = None
    entry = None
    for raw in lines:
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = comps.setdefault(hdr.group(2), CompCost())
            if hdr.group(1):
                entry = hdr.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, body = m.group(1), m.group(2)
        opm = re.search(r"([a-z][a-z0-9\-]*)\(", body)
        kind = opm.group(1) if opm else ""
        if opm:
            close = body.find(")", opm.end())
            operands = _OPERANDS_RE.findall(
                body[opm.end():close if close >= 0 else len(body)])
        else:
            operands = []

        if kind in ("dot", "convolution") and operands:
            cur.flops += _dot_flops(name_dims.get(name, []),
                                    name_dims.get(operands[0], []), body)
        base = kind.replace("-start", "")
        if base in _COLL_KINDS and not kind.endswith("-done"):
            link, crosses = _coll_link_bytes(base, name_bytes.get(name, 0.0),
                                             body, pod_size)
            if link:
                k = (base, crosses)
                cur.coll[k] = cur.coll.get(k, 0.0) + link
        res_b = name_bytes.get(name, 0.0)
        if kind in ("dynamic-slice", "gather"):
            # reads/writes only the slice, not the sliced buffer
            cur.bytes += 2.0 * res_b
            cur.ds_like = True
            # trip-count hint: slicing [L, ...] down to [1, ...]
            if operands:
                big = name_dims.get(operands[0], [])
                out = name_dims.get(name, [])
                if (kind == "dynamic-slice" and len(big) == len(out)
                        and big and out and out[0] == 1 and big[0] > 1
                        and big[1:] == out[1:]):
                    cur.ds_trip = max(cur.ds_trip, float(big[0]))
        elif kind == "dynamic-update-slice":
            upd = name_bytes.get(operands[1], 0.0) if len(operands) > 1 else res_b
            cur.bytes += 2.0 * upd
            cur.ds_like = True
            if len(operands) > 1:
                big = name_dims.get(operands[0], [])
                u = name_dims.get(operands[1], [])
                if (len(big) == len(u) and big and u and u[0] == 1
                        and big[0] > 1 and big[1:] == u[1:]):
                    cur.ds_trip = max(cur.ds_trip, float(big[0]))
        elif kind == "scatter":
            upd = name_bytes.get(operands[2], 0.0) if len(operands) > 2 else res_b
            cur.bytes += 2.0 * upd + res_b
            cur.ds_like = True
        elif kind == "fusion":
            # boundary traffic; but a fusion wrapping a dynamic-slice reads
            # only the slice of its big operand, not the whole buffer —
            # operand bytes resolved in analyze() once the callee's flag is
            # known (definition order is not guaranteed)
            cur.bytes += res_b
            op_bytes = [name_bytes.get(o, 0.0) for o in operands
                        if not o.startswith("constant")]
            cur.calls.append(("__opbytes__", "opbytes", (op_bytes, res_b)))
        elif kind not in _FREE_OPS and kind:
            cur.bytes += res_b
            cur.bytes += sum(name_bytes.get(o, 0.0) for o in operands
                             if not o.startswith("constant"))
        if "constant(" in body:
            for c in _CONST_RE.finditer(body):
                v = float(c.group(1))
                if 1 < v <= 1_000_000:
                    cur.trip_hint = max(cur.trip_hint, v)
        if kind == "while":
            bm = _WHILE_BODY_RE.search(body)
            cm = _WHILE_COND_RE.search(body)
            if bm:
                cur.calls.append((bm.group(1), "while_body",
                                  cm.group(1) if cm else None))
        elif kind == "fusion":
            cm = _CALLED_RE.search(body)
            if cm:
                for n in cm.group(1).split(","):
                    # fused computations: count their dot flops, but their
                    # internal op "bytes" are registers, not HBM traffic
                    cur.calls.append((n.strip().lstrip("%"), "fusion", None))
        elif kind:
            cm = _CALLED_RE.search(body)
            if cm:
                for n in cm.group(1).split(","):
                    cur.calls.append((n.strip().lstrip("%"), "call", None))
    return comps, entry


def _trip_count(comps: dict, cond_name, body_name) -> float:
    """Trip count of a while: max of condition-constant and the structural
    hint (a scan body dynamic-slices its stacked xs [L, ...] to [1, ...] —
    robust even when XLA hoists the bound constant out of the condition)."""
    cand = 1.0
    cond = comps.get(cond_name) if cond_name else None
    if cond is not None:
        cand = max(cand, cond.trip_hint)
    body = comps.get(body_name) if body_name else None
    if body is not None:
        hint = body.ds_trip
        # ds hints may also live one fusion level down
        for callee, k, _ in body.calls:
            sub = comps.get(callee)
            if k in ("fusion", "call") and sub is not None:
                hint = max(hint, sub.ds_trip)
        cand = max(cand, hint)
    return cand


def analyze(text: str, pod_size: int = 256) -> HloCost:
    comps, entry = parse_hlo(text, pod_size)
    total = HloCost()
    seen_stack: set = set()

    def walk(name: str, mult: float, bytes_on: bool):
        if name in seen_stack:       # defensive: no recursion in HLO anyway
            return
        comp = comps.get(name)
        if comp is None:
            return
        seen_stack.add(name)
        total.flops += comp.flops * mult
        if bytes_on:
            total.bytes += comp.bytes * mult
        for (kind, crosses), nb in comp.coll.items():
            total.coll_by_kind[kind] = total.coll_by_kind.get(kind, 0.0) + nb * mult
            if crosses:
                total.coll_cross += nb * mult
            else:
                total.coll_ici += nb * mult
            total.n_coll_ops += 1
        pending = None
        for callee, ckind, extra in comp.calls:
            if ckind == "opbytes":
                pending = extra
                continue
            m = mult
            b = bytes_on
            if ckind == "while_body":
                m = mult * _trip_count(comps, extra, callee)
                # condition itself runs trip+1 times; negligible cost
            elif ckind == "fusion":
                b = False
                if bytes_on and pending is not None:
                    op_bytes, res_b = pending
                    callee_comp = comps.get(callee)
                    slicey = callee_comp.ds_like if callee_comp else False
                    for ob in op_bytes:
                        total.bytes += (min(ob, 2.0 * max(res_b, 1.0)) if slicey
                                        else ob) * mult
                    pending = None
            walk(callee, m, b)
        seen_stack.discard(name)
        total.per_comp[name] = {"flops": comp.flops, "bytes": comp.bytes,
                                "mult": mult}

    if entry:
        walk(entry, 1.0, True)
    return total


def xla_cost(compiled) -> dict:
    """compiled.cost_analysis() normalized to a flat dict.

    Older jaxlib returns a one-element list of dicts; newer returns the dict
    directly.  Callers index ["flops"] either way.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
