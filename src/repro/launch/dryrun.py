import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
# (This also means: no `from __future__ import annotations` in this module.)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this jit-lowers the real train_step / serve_step / prefill
with ShapeDtypeStruct inputs (no allocation), compiles for the production
mesh, prints memory_analysis() (proves it fits) and cost_analysis() (FLOPs /
bytes for the roofline), parses collective bytes out of the compiled HLO,
and appends everything to a JSON results file consumed by
benchmarks/roofline_report.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 4]   # full matrix
"""


import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import (SHAPES, CommConfig, RunConfig, TrainConfig,
                           cell_applicable, get_config, list_archs)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.param import tree_abstract
from repro.models.registry import batch_abstract


def run_cell(arch: str, shape_name: str, multi_pod: bool, comm: CommConfig,
             train: TrainConfig, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "why": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rc = RunConfig(model=cfg, shape=shape, comm=comm, train=train)
    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            from repro.runtime.step import build_train_step
            bundle = build_train_step(rc, mesh)
            state = bundle.abstract_state()
            batch = batch_abstract(cfg, shape)
            lowered = bundle.fn.lower(state, batch)
        elif shape.kind == "prefill":
            from repro.runtime.step import build_serve_step
            bundle = build_serve_step(rc, mesh, kind="prefill")
            params = tree_abstract(bundle.param_defs)
            batch = batch_abstract(cfg, shape)
            lowered = bundle.fn.lower(params, batch)
        else:  # decode
            from repro.runtime.step import build_serve_step
            import jax.numpy as jnp
            bundle = build_serve_step(rc, mesh, kind="decode")
            params = tree_abstract(bundle.param_defs)
            cache = tree_abstract(bundle.cache_defs)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            lowered = bundle.fn.lower(params, cache, pos, tokens)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from repro.launch.hlo_analysis import xla_cost
    cost = xla_cost(compiled)
    hlo = compiled.as_text()
    # scan-aware analysis: XLA's cost_analysis counts while bodies once, so
    # layer-scanned models are undercounted ~L×; hlo_analysis multiplies by
    # parsed trip counts (see launch/hlo_analysis.py).
    hc = rl.analyze_hlo(hlo)
    chips = mesh.devices.size
    roof = rl.Roofline(
        flops=hc.flops,
        hbm_bytes=hc.bytes,
        coll=rl.CollectiveStats(ici_bytes=hc.coll_ici,
                                interpod_bytes=hc.coll_cross,
                                by_kind=hc.coll_by_kind,
                                n_ops=hc.n_coll_ops),
        chips=chips,
        model_flops=rl.model_flops_for(cfg, shape))
    xla_flops = float(cost.get("flops", 0.0))

    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "status": "ok",
        "comm_mode": comm.mode, "streams": comm.streams,
        "chunk_mb": comm.chunk_mb, "compress": comm.compress,
        "zero1": train.zero1, "microbatches": train.microbatches,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "per_device_total": per_dev_bytes,
        },
        "xla_flops_while_once": xla_flops,
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] compile ok "
              f"({t_compile:.0f}s)")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: flops/chip={roof.flops:.3e} "
              f"hbm_bytes/chip={roof.hbm_bytes:.3e}")
        print(f"  collectives: ici={roof.coll.ici_bytes/2**20:.1f}MiB "
              f"interpod={roof.coll.interpod_bytes/2**20:.1f}MiB "
              f"ops={roof.coll.n_ops}")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} "
              f"useful_flops={roof.useful_flops_frac:.2%}")
    return rec


def append_result(path: str, rec: dict):
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("comm_mode"),
           rec.get("compress"), rec.get("streams"), rec.get("microbatches"))
    data = [r for r in data if (r["arch"], r["shape"], r["mesh"],
                                r.get("comm_mode"), r.get("compress"),
                                r.get("streams"), r.get("microbatches")) != key]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="hierarchical",
                    choices=["flat", "hierarchical", "gateway"])
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--chunk-mb", type=float, default=8.0)
    ap.add_argument("--compress", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--all", action="store_true", help="full cell matrix (subprocess per cell)")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    if args.all:
        return run_matrix(args)

    comm = CommConfig(mode=args.mode, streams=args.streams,
                      chunk_mb=args.chunk_mb, compress=args.compress,
                      autotune=not args.no_autotune)
    train = TrainConfig(zero1=not args.no_zero, microbatches=args.microbatches)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                try:
                    rec = run_cell(arch, shape, m == "multi", comm, train)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": m,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                append_result(args.out, rec)
    sys.exit(1 if failures else 0)


def run_matrix(args):
    """Full matrix, one subprocess per cell (isolates compiles, bounds RAM)."""
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            for m in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
                cells.append((arch, shape, m))
    procs: list[tuple] = []
    failures = []
    done = 0

    def launch(cell):
        arch, shape, m = cell
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", m,
               "--mode", args.mode, "--streams", str(args.streams),
               "--chunk-mb", str(args.chunk_mb), "--compress", args.compress,
               "--microbatches", str(args.microbatches), "--out", args.out]
        if args.no_zero:
            cmd.append("--no-zero")
        if args.no_autotune:
            cmd.append("--no-autotune")
        return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

    queue = list(cells)
    while queue or procs:
        while queue and len(procs) < args.jobs:
            cell = queue.pop(0)
            procs.append((cell, launch(cell), time.time()))
        still = []
        for cell, p, t0 in procs:
            if p.poll() is None:
                if time.time() - t0 > args.timeout:
                    p.kill()
                    failures.append((cell, "timeout"))
                    print(f"TIMEOUT {cell}")
                else:
                    still.append((cell, p, t0))
                continue
            done += 1
            out = p.stdout.read() if p.stdout else ""
            tail = [ln for ln in out.splitlines() if ln.strip()][-6:]
            print(f"--- [{done}/{len(cells)}] {cell} rc={p.returncode}")
            print("\n".join("    " + ln for ln in tail))
            if p.returncode != 0:
                failures.append((cell, out[-2000:]))
        procs = still
        time.sleep(2)
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells ok")
    for cell, err in failures:
        print("FAILED:", cell)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
