"""Production training launcher.

On a real cluster each host runs this with coordinator env vars set
(JAX_COORDINATOR, JAX_NUM_PROCESSES, JAX_PROCESS_ID) and the production
mesh; in this container it runs a reduced config on the local device(s).

Examples:
  python -m repro.launch.train --arch qwen1.5-0.5b --steps 100 --smoke
  python -m repro.launch.train --arch llama3.2-3b --shape train_4k \
      --mode hierarchical --streams 32 --ckpt-dir /ckpt --replica-dir /backup
  # WAN-routed with chaos: drop the direct link at step 20, self-heal
  python -m repro.launch.train --arch qwen1.5-0.5b --smoke --pods 4 \
      --route amsterdam:tokyo --backup-links --chaos-drop 20
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.configs import (SHAPES, CommConfig, RunConfig, ShapeConfig,
                           TrainConfig, get_config, smoke_config)
from repro.data import DataConfig, make_pipeline
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.runtime import Trainer


def maybe_init_distributed():
    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize(
            coordinator_address=os.environ["JAX_COORDINATOR"],
            num_processes=int(os.environ["JAX_NUM_PROCESSES"]),
            process_id=int(os.environ["JAX_PROCESS_ID"]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", default="hierarchical",
                    choices=["flat", "hierarchical", "gateway"])
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--chunk-mb", type=float, default=8.0)
    ap.add_argument("--compress", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--replica-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model + small shapes for local devices")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod axis of the local mesh (4 for CosmoGrid routes)")
    ap.add_argument("--route", default=None, metavar="SRC:DST",
                    help="plan the train path over the CosmoGrid testbed "
                         "(e.g. amsterdam:tokyo); needs a pod axis of 4")
    ap.add_argument("--backup-links", action="store_true",
                    help="add the tokyo-edinburgh backup to the testbed")
    ap.add_argument("--chaos-drop", type=int, default=None, metavar="STEP",
                    help="drop the route's direct link at STEP and attach "
                         "the self-healing ChaosMonitor (re-route/failover)")
    ap.add_argument("--local-steps", type=int, default=1, metavar="K",
                    help="local-SGD cadence: K local steps per site between "
                         "cross-site delta syncs (1 = fully synchronous)")
    ap.add_argument("--coordinator", default=None, metavar="SITE",
                    help="attach elastic membership (lease-based liveness, "
                         "evict/rejoin world resize) coordinated from SITE; "
                         "needs --route")
    ap.add_argument("--lease-steps", type=int, default=4,
                    help="probe failures a suspect site survives before "
                         "eviction (with --coordinator)")
    ap.add_argument("--data", default="synthetic", choices=["synthetic", "binary"])
    ap.add_argument("--data-path", default=None)
    args = ap.parse_args()

    maybe_init_distributed()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    base = SHAPES[args.shape]
    seq = args.seq_len or (64 if args.smoke else base.seq_len)
    gb = args.global_batch or (8 if args.smoke else base.global_batch)
    shape = ShapeConfig(base.name, seq, gb, "train")

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = len(jax.devices())
        model_par = 1
        data_par = n // args.pods
        mesh = make_local_mesh(data=data_par, model=model_par, pod=args.pods)

    route = site_groups = chaos = membership = None
    if args.route:
        from repro.core import ChaosMonitor, SiteMembership, cosmogrid_topology
        src, dst = args.route.split(":")
        topo = cosmogrid_topology(backup_links=args.backup_links)
        if args.chaos_drop is not None:
            direct = topo.link(src, dst)
            if direct is None:
                ap.error(f"--chaos-drop needs a direct {src}-{dst} link")
            topo.connect(src, dst, direct.drop(args.chaos_drop))
            chaos = ChaosMonitor(topo, src, dst)
        if args.coordinator:
            membership = SiteMembership(topo, args.coordinator,
                                        lease_steps=args.lease_steps)
        route = topo.route(src, dst)
        site_groups = topo.pod_groups()
        print(f"[train] WAN route: {route.describe()}"
              + (f"; chaos drop at step {args.chaos_drop}"
                 if args.chaos_drop is not None else "")
              + (f"; membership coordinated by {args.coordinator}"
                 if args.coordinator else ""))
    elif args.coordinator:
        ap.error("--coordinator needs --route (a multi-site topology)")

    rc = RunConfig(
        model=cfg, shape=shape,
        comm=CommConfig(mode=args.mode, streams=args.streams,
                        chunk_mb=args.chunk_mb, compress=args.compress,
                        local_steps=args.local_steps),
        train=TrainConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1),
                          microbatches=args.microbatches))
    data = make_pipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=gb,
        kind=args.data, path=args.data_path))

    with jax.set_mesh(mesh):
        trainer = Trainer(rc, mesh, ckpt_dir=args.ckpt_dir,
                          replica_dir=args.replica_dir,
                          ckpt_every=args.ckpt_every,
                          route=route, site_groups=site_groups, chaos=chaos,
                          membership=membership)
        print(f"[train] {args.arch} params={cfg.param_count():,} mesh={mesh.shape} "
              f"mode={args.mode} zero={trainer.bundle.zero}"
              + (f" local_steps={args.local_steps}"
                 if args.local_steps > 1 else ""))
        print(f"[train] {trainer.init_or_restore()} at step {trainer.step}")
        hist = trainer.run(data, args.steps)
        print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
              f"stragglers flagged: {len(trainer.detector.flagged)}")
        trainer.close()


if __name__ == "__main__":
    main()
