"""Serving launcher: fixed-batch greedy decode, or the continuous-batching
serving tier (monolithic or disaggregated prefill/decode over a WAN path).

  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --tokens 16
  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --engine disagg \
      --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import (SHAPES, CommConfig, RunConfig, ShapeConfig,
                           TrainConfig, get_config, smoke_config)
from repro.core.path import WAN_LONDON_POZNAN, WidePath
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.runtime import Server, ServingEngine


def _run_engine(rc, mesh, args) -> None:
    path = None
    route = topo = log = None
    if args.engine == "disagg":
        if args.chaos_drop is not None:
            # CosmoGrid testbed with the backup detour; the primary
            # amsterdam->tokyo light path drops for the scheduled window
            from repro.core.chaos import IncidentLog
            from repro.core.topology import Fault, cosmogrid_topology
            topo = cosmogrid_topology(backup_links=True)
            start, stop = args.chaos_drop
            prof = topo.link("amsterdam", "tokyo").with_fault(
                Fault("drop", start=start, stop=stop))
            topo.connect("amsterdam", "tokyo", prof)
            route = topo.route("amsterdam", "tokyo")
            log = IncidentLog()
            path = WidePath(axis="pod",
                            comm=CommConfig(streams=args.streams),
                            hops=route.as_hops(), name="kvship")
        else:
            path = WidePath(axis="pod", comm=CommConfig(streams=args.streams),
                            link=WAN_LONDON_POZNAN, name="kvship")
    eng = ServingEngine(rc, mesh, mode=args.engine, path=path,
                        route=route, topo=topo, log=log, ship_timeout_s=0.5,
                        deadline_steps=args.deadline_steps,
                        prefill_site="amsterdam" if topo else None,
                        decode_site="tokyo" if topo else None)
    rng = np.random.default_rng(args.seed)
    S = rc.shape.seq_len
    for _ in range(args.requests):
        plen = int(rng.integers(4, max(5, S // 4)))
        mnew = int(rng.integers(1, max(2, min(args.tokens, S - plen))))
        prompt = rng.integers(1, rc.model.vocab_size, size=plen)
        eng.submit(prompt, mnew)
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    dt = time.perf_counter() - t0
    print(f"[serve] engine={args.engine} slots={rc.shape.global_batch} "
          f"completed={stats['completed']} tokens={stats['total_tokens']} "
          f"in {dt:.2f}s wall")
    print(f"[serve] modeled: p50={stats['latency_p50_s']*1e3:.1f}ms "
          f"p99={stats['latency_p99_s']*1e3:.1f}ms "
          f"ttft_p50={stats['ttft_p50_s']*1e3:.1f}ms "
          f"goodput={stats['goodput_tok_s']:.1f} tok/s")
    if args.deadline_steps or args.chaos_drop is not None:
        print(f"[serve] slo: attainment={stats['slo_attainment']:.3f} "
              f"timed_out={stats['timed_out']} shed={stats['shed']} "
              f"reships={stats['reships']} reroutes={stats['reroutes']} "
              f"degraded={stats['degraded']}")
    if log is not None:
        for row in log.timeline():
            print(f"[serve] incident: step={row['step']} "
                  f"{row['event']} {row['subject']} {row['detail']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k", choices=list(SHAPES))
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--engine", choices=["fixed", "mono", "disagg"],
                    default="fixed",
                    help="fixed: legacy one-batch decode; mono/disagg: "
                         "the continuous-batching serving tier")
    ap.add_argument("--requests", type=int, default=8,
                    help="seeded request count for --engine mono/disagg")
    ap.add_argument("--streams", type=int, default=16,
                    help="WAN streams for the disaggregated KV ship")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request SLO in virtual steps (requests past "
                         "it TIMEOUT; admission sheds hopeless ones)")
    ap.add_argument("--chaos-drop", type=int, nargs=2, default=None,
                    metavar=("START", "STOP"),
                    help="disagg only: run on the CosmoGrid testbed and "
                         "drop the amsterdam->tokyo light path for steps "
                         "[START, STOP) — ships reship/reroute and the "
                         "incident timeline prints at the end")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.num_heads == 0 and cfg.family == "audio":
        raise SystemExit("decode not defined for this arch")
    if args.smoke:
        cfg = smoke_config(cfg)
    base = SHAPES[args.shape]
    B = args.batch or (4 if args.smoke else base.global_batch)
    S = args.cache_len or (128 if args.smoke else base.seq_len)
    shape = ShapeConfig(base.name, S, B, "decode")

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_local_mesh(data=len(jax.devices()), model=1)

    rc = RunConfig(model=cfg, shape=shape, comm=CommConfig(), train=TrainConfig())
    with jax.set_mesh(mesh):
        if args.engine != "fixed":
            _run_engine(rc, mesh, args)
            return
        server = Server(rc, mesh)
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(B, 1)).astype(np.int32)
        t0 = time.perf_counter()
        res = server.generate(prompts, max_new=args.tokens)
        dt = time.perf_counter() - t0
        print(f"[serve] {args.arch} B={B} cache={S} generated {res.steps} tokens "
              f"in {dt:.2f}s ({B*res.steps/dt:.1f} tok/s)")
        print("[serve] sample:", res.tokens[0][:8].tolist())


if __name__ == "__main__":
    main()
