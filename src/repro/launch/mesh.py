"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers control XLA_FLAGS/device counts before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (16,16) ("data","model") = 256 chips (one v5e pod);
    multi-pod:  (2,16,16) ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
