"""Chunked, multi-stream checkpoint store — the mpw-cp analogue.

Leaves are written as raw little-endian chunk files of `chunk_mb` each by a
pool of `streams` writer threads (mpw-cp's multi-stream file transfer), with
a JSON manifest carrying shapes/dtypes/chunk lists.  Restore is
resharding-aware: arrays are assembled on host and device_put with whatever
sharding the *current* mesh wants, so a run can restart on a different mesh
(elastic restart).
"""
from __future__ import annotations

import json
import os
import shutil
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(_key_str(k) for k in kp)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(tree, directory: str, *, step: int = 0, chunk_mb: float = 32.0,
         streams: int = 8, extra: Optional[dict] = None) -> dict:
    """Write a pytree checkpoint. Returns the manifest."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    chunk_bytes = max(1 << 10, int(chunk_mb * (1 << 20)))

    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    entries = []
    jobs = []
    for i, (name, arr) in enumerate(_leaf_paths(host_tree)):
        raw = arr.tobytes()
        chunks = []
        for c0 in range(0, max(len(raw), 1), chunk_bytes):
            fname = f"leaf{i:05d}_c{len(chunks):04d}.bin"
            chunks.append({"file": fname, "offset": c0,
                           "size": len(raw[c0:c0 + chunk_bytes])})
            jobs.append((os.path.join(tmp, fname), raw[c0:c0 + chunk_bytes]))
        entries.append({"name": name, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "chunks": chunks})

    def write(job):
        path, payload = job
        with open(path, "wb") as f:
            f.write(payload)

    with ThreadPoolExecutor(max_workers=max(1, streams)) as pool:
        list(pool.map(write, jobs))

    manifest = {"step": step, "leaves": entries, "extra": extra or {}}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)            # atomic publish
    return manifest


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, MANIFEST)) as f:
        return json.load(f)


def restore(directory: str, like, *, shardings=None, streams: int = 8):
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs).  `shardings`: matching tree of NamedShardings for
    resharded placement (or None for host arrays)."""
    manifest = load_manifest(directory)
    by_name = {e["name"]: e for e in manifest["leaves"]}

    def read_leaf(entry):
        buf = bytearray()
        for ch in entry["chunks"]:
            with open(os.path.join(directory, ch["file"]), "rb") as f:
                buf += f.read()
        arr = np.frombuffer(bytes(buf), dtype=entry["dtype"])
        return arr.reshape(entry["shape"])

    names = [n for n, _ in _leaf_paths(like)]
    with ThreadPoolExecutor(max_workers=max(1, streams)) as pool:
        arrays = list(pool.map(lambda n: read_leaf(by_name[n]), names))

    leaves_like, treedef = jax.tree.flatten(like)
    out = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out, manifest
