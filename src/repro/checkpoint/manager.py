"""Checkpoint manager: retention, latest-step discovery, async save,
optional DataGather replication to a peer location (local path or, with a
`transfer` engine, shipped across sites over a WidePath route)."""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Optional

from repro.checkpoint import store
from repro.checkpoint.replicate import DataGather


_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, chunk_mb: float = 32.0,
                 streams: int = 8, replica_dir: Optional[str] = None,
                 transfer=None):
        """`transfer` (a :class:`repro.core.filetransfer.FileTransfer`)
        routes replication through the WAN path machinery — chunked
        multi-stream transfers, per-hop telemetry, resumable jobs — instead
        of the local-copy fallback; this is how `Trainer` ships checkpoints
        to a peer site along a topology route."""
        self.dir = directory
        self.keep = keep
        self.chunk_mb = chunk_mb
        self.streams = streams
        os.makedirs(directory, exist_ok=True)
        self.transfer = transfer
        self.replica_dir = replica_dir
        # the gatherer starts lazily after the first COMPLETED save: a
        # manager whose primary directory is still empty (fresh restart, or
        # first save in flight) must not begin mirroring — the mirror prune
        # would wipe the very replica the restart may restore from
        self.gatherer = None
        # guards gatherer/_async_thread: _ensure_gatherer runs on the async
        # save thread while save()/wait()/close() run on the trainer thread
        self._state_lock = threading.Lock()
        self._async_thread: Optional[threading.Thread] = None

    def _ensure_gatherer(self):
        with self._state_lock:
            if self.replica_dir and self.gatherer is None:
                self.gatherer = DataGather(self.dir, self.replica_dir,
                                           transfer=self.transfer).start()

    # -- discovery -----------------------------------------------------------
    @staticmethod
    def _steps_in(directory: Optional[str]) -> list[int]:
        out = []
        if not directory or not os.path.isdir(directory):
            return out
        for d in os.listdir(directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(directory, d, store.MANIFEST)):
                out.append(int(m.group(1)))
        return sorted(out)

    def steps(self) -> list[int]:
        return self._steps_in(self.dir)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def has_checkpoint(self) -> bool:
        """Anything restorable — in the primary directory *or* the replica
        mirror (the restart-from-replica scenario)."""
        return bool(self.steps() or self._steps_in(self.replica_dir))

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    # -- save/restore ---------------------------------------------------------
    def save(self, step: int, state, *, extra: Optional[dict] = None,
             block: bool = True):
        """Save (optionally async: device_get happens now, file IO in a
        background thread — off the training critical path)."""
        import jax
        import numpy as np
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def run():
            store.save(host_state, self.path(step), step=step,
                       chunk_mb=self.chunk_mb, streams=self.streams, extra=extra)
            self._prune()
            # start mirroring only once the primary HOLDS a published
            # checkpoint: any earlier (top of save, __init__) and the
            # gatherer's first prune pass races the in-flight store.save
            # against a still-empty primary — wiping the very replica a
            # restarted pod may still need to restore from
            self._ensure_gatherer()

        # always drain a pending async save first: two writers on the same
        # step_N.tmp directory race rmtree/os.replace against each other
        self.wait()
        if block:
            run()
        else:
            with self._state_lock:
                self._async_thread = threading.Thread(target=run, daemon=True)
                self._async_thread.start()

    def wait(self):
        # join OUTSIDE the lock: run() takes it in _ensure_gatherer
        t = self._async_thread
        if t is not None:
            t.join()
            with self._state_lock:
                self._async_thread = None

    def replicate_now(self) -> int:
        """One synchronous mirror pass to the replica: ship the checkpoints
        across sites *now* (the final-save path) instead of waiting for the
        background gatherer's next tick.  Returns files shipped."""
        return self.gatherer.sync() if self.gatherer else 0

    def restore(self, like, *, step: Optional[int] = None, shardings=None
                ) -> tuple[Any, dict]:
        """Restore `step` (default: latest).  When the primary directory has
        no usable checkpoint — the whole-pod-loss scenario DataGather exists
        for — falls back to the replica mirror, so a pod that lost its local
        storage restarts from the copy its peer site gathered."""
        directory = None
        want = step if step is not None else self.latest_step()
        if want is not None and (step is None or want in self.steps()):
            directory = self.path(want)
        elif self.replica_dir:
            rsteps = self._steps_in(self.replica_dir)
            if step is not None and step in rsteps:
                want = step
            elif step is None and rsteps:
                want = rsteps[-1]
            if want is not None and want in rsteps:
                directory = os.path.join(self.replica_dir, f"step_{want:08d}")
        if directory is None:
            raise FileNotFoundError(
                f"no checkpoints under {self.dir}"
                + (f" or replica {self.replica_dir}" if self.replica_dir
                   else ""))
        return store.restore(directory, like, shardings=shardings,
                             streams=self.streams)

    def _prune(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self.path(s), ignore_errors=True)

    def close(self):
        self.wait()
        if self.gatherer:
            self.gatherer.stop()
