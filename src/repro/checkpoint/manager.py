"""Checkpoint manager: retention, latest-step discovery, async save,
optional DataGather replication to a peer location."""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Optional

from repro.checkpoint import store
from repro.checkpoint.replicate import DataGather

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, chunk_mb: float = 32.0,
                 streams: int = 8, replica_dir: Optional[str] = None):
        self.dir = directory
        self.keep = keep
        self.chunk_mb = chunk_mb
        self.streams = streams
        os.makedirs(directory, exist_ok=True)
        self.gatherer = None
        if replica_dir:
            self.gatherer = DataGather(directory, replica_dir).start()
        self._async_thread: Optional[threading.Thread] = None

    # -- discovery -----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, store.MANIFEST)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    # -- save/restore ---------------------------------------------------------
    def save(self, step: int, state, *, extra: Optional[dict] = None,
             block: bool = True):
        """Save (optionally async: device_get happens now, file IO in a
        background thread — off the training critical path)."""
        import jax
        import numpy as np
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def run():
            store.save(host_state, self.path(step), step=step,
                       chunk_mb=self.chunk_mb, streams=self.streams, extra=extra)
            self._prune()

        # always drain a pending async save first: two writers on the same
        # step_N.tmp directory race rmtree/os.replace against each other
        self.wait()
        if block:
            run()
        else:
            self._async_thread = threading.Thread(target=run, daemon=True)
            self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def restore(self, like, *, step: Optional[int] = None, shardings=None
                ) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return store.restore(self.path(step), like, shardings=shardings,
                             streams=self.streams)

    def _prune(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self.path(s), ignore_errors=True)

    def close(self):
        self.wait()
        if self.gatherer:
            self.gatherer.stop()
