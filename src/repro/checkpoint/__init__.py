from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.replicate import DataGather, sync_once  # noqa: F401
from repro.checkpoint.store import load_manifest, restore, save  # noqa: F401
