"""DataGather: continuous one-way directory synchronization over a WidePath.

The paper's DataGather keeps a remote directory mirrored while a simulation
runs, so output data accumulates at one site.  Here it mirrors checkpoint
directories to a replica location (a peer site's storage in production; any
path here), running concurrently with training — whole-pod loss then
restarts from the replica.

Since PR 4 the mirror's data plane is the mpw-cp engine
(:class:`repro.core.filetransfer.FileTransfer`): each pass is a manifest
diff (walk src, compare size/mtime against dst) whose stale entries become
:class:`FileJob`s — chunked, multi-stream, checksummed, optionally
compressed transfers that relay through whatever route the engine's path
carries and land in per-hop telemetry.  Without an explicit engine the
mirror degrades to a local single-stream transfer (same atomicity, no
telemetry), which is byte-for-byte what the old ``shutil.copy2`` walk did.
"""
from __future__ import annotations

import os
import threading

from repro.core.filetransfer import (
    PART_SUFFIX,
    SIDECAR_SUFFIX,
    TRANSIENT_SUFFIXES,
    ChecksumError,
    FileTransfer,
    local_transfer,
)


def sync_once(src: str, dst: str,
              transfer: FileTransfer | None = None) -> int:
    """One-way sync; returns number of files copied. Atomic per file.

    The copy condition is the mirror diff: a file ships when the mirror copy
    is missing, the source is *newer* (mtime), or the sizes differ — so a
    same-size rewrite with a newer mtime still overwrites (checkpoint files
    are fixed-shape: same size, new bytes).  Runs concurrently with the
    writer: a source file may vanish between the walk and the stat/copy
    (checkpoint GC deleting an old step), which must not crash the pass —
    the next prune removes its mirror copy.  Transient artifacts are never
    *mirrored* (``.tmp`` files, whole ``.tmp`` staging directories, engine
    droppings); in the destination, orphaned engine droppings (``.part``
    partials, ``.mpwcp.json`` sidecars left by an interrupted earlier
    pass) ARE pruned, so a killed mirror pass cannot leak
    checkpoint-sized partials into the replica forever.
    """
    if not os.path.isdir(src):
        return 0
    eng = transfer if transfer is not None else local_transfer()
    os.makedirs(dst, exist_ok=True)
    copied = 0
    for root, dirs, files in os.walk(src):
        # store.save stages whole checkpoints in `step_N.tmp/` directories
        # before its atomic rename: descending into one would ship partial
        # shards over the WAN and then ship the published copy again
        dirs[:] = [x for x in dirs if not x.endswith(TRANSIENT_SUFFIXES)]
        rel = os.path.relpath(root, src)
        troot = os.path.join(dst, rel) if rel != "." else dst
        os.makedirs(troot, exist_ok=True)
        for fn in files:
            if fn.endswith(TRANSIENT_SUFFIXES):
                continue
            s = os.path.join(root, fn)
            t = os.path.join(troot, fn)
            try:
                if (not os.path.exists(t)
                        or os.path.getmtime(s) > os.path.getmtime(t)
                        or os.path.getsize(s) != os.path.getsize(t)):
                    # mirror jobs never resume: the diff already skips files
                    # that are up to date, and a sidecar would itself show
                    # up as a mirror entry
                    eng.copy(s, t, resume=False)
                    copied += 1
            except FileNotFoundError:
                continue   # deleted from src mid-walk
    # prune deleted entries (keep mirror exact); bottom-up so directories
    # emptied by file pruning can be removed in the same pass
    for root, dirs, files in os.walk(dst, topdown=False):
        rel = os.path.relpath(root, dst)
        sroot = os.path.join(src, rel) if rel != "." else src
        for fn in files:
            if fn.endswith(".tmp"):
                continue                # a concurrent writer's staging file
            if not fn.endswith((PART_SUFFIX, SIDECAR_SUFFIX)) \
                    and os.path.exists(os.path.join(sroot, fn)):
                continue
            # mirrored entries whose source vanished, AND any engine
            # droppings (.part partials, .mpwcp.json sidecars): this pass's
            # own copies have completed before the prune runs (passes are
            # serialized), so a dropping here is an earlier interrupted
            # pass's orphan — without this, a checkpoint-sized .part could
            # sit in the replica forever.  (The mirror owns its dst: don't
            # point resumable user transfers at a DataGather destination.)
            try:
                os.remove(os.path.join(root, fn))
            except FileNotFoundError:
                pass
        if root != dst and not os.path.isdir(sroot):
            try:
                os.rmdir(root)          # only succeeds once empty
            except OSError:
                pass                    # still holds live entries
    return copied


class DataGather:
    """Background mirroring thread (start/stop).

    `transfer` routes the mirror's bytes over a WidePath (multi-stream,
    compressed, multi-hop — the WAN checkpoint-shipping configuration);
    None keeps the local fallback.
    """

    def __init__(self, src: str, dst: str, interval_s: float = 2.0,
                 transfer: FileTransfer | None = None):
        self.src, self.dst = src, dst
        self.interval_s = interval_s
        self.transfer = transfer
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._sync_lock = threading.Lock()
        self.copied_total = 0

    def sync(self) -> int:
        """One synchronous mirror pass (the loop body; also what
        `CheckpointManager.replicate_now` and the `stop()` drain run).
        Serialized: a caller-driven pass must not overlap the background
        tick on the same destination — two concurrent copies of one file
        race part-file truncation against chunk writes."""
        with self._sync_lock:
            n = sync_once(self.src, self.dst, transfer=self.transfer)
            self.copied_total += n
        return n

    def _safe_sync(self) -> int:
        """sync() that survives transient failures: a bad pass (I/O error,
        a chunk exhausting its checksum retries) must not kill the mirror
        thread — the next tick retries.  The WAN data plane can raise
        ChecksumError, which the old OSError-only guard let escape."""
        try:
            return self.sync()
        except (OSError, ChecksumError):
            return 0

    def start(self):
        def loop():
            while not self._stop.is_set():
                self._safe_sync()
                self._stop.wait(self.interval_s)

        with self._sync_lock:
            self._thread = threading.Thread(target=loop, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=10)
        self._safe_sync()           # drain; must not throw out of shutdown
