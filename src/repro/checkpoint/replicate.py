"""DataGather: continuous one-way directory synchronization.

The paper's DataGather keeps a remote directory mirrored while a simulation
runs, so output data accumulates at one site.  Here it mirrors checkpoint
directories to a replica location (a peer pod's storage in production; any
path here), running concurrently with training — whole-pod loss then
restarts from the replica.
"""
from __future__ import annotations

import os
import shutil
import threading
import time


def sync_once(src: str, dst: str) -> int:
    """One-way sync; returns number of files copied. Atomic per file.

    Runs concurrently with the writer: a source file may vanish between the
    walk and the stat/copy (checkpoint GC deleting an old step), which must
    not crash the pass — the next prune removes its mirror copy.
    """
    if not os.path.isdir(src):
        return 0
    os.makedirs(dst, exist_ok=True)
    copied = 0
    for root, _, files in os.walk(src):
        rel = os.path.relpath(root, src)
        troot = os.path.join(dst, rel) if rel != "." else dst
        os.makedirs(troot, exist_ok=True)
        for fn in files:
            s = os.path.join(root, fn)
            t = os.path.join(troot, fn)
            try:
                if (not os.path.exists(t)
                        or os.path.getmtime(s) > os.path.getmtime(t)
                        or os.path.getsize(s) != os.path.getsize(t)):
                    tmp = t + ".tmp"
                    shutil.copy2(s, tmp)
                    os.replace(tmp, t)
                    copied += 1
            except FileNotFoundError:
                continue   # deleted from src mid-walk
    # prune deleted entries (keep mirror exact); bottom-up so directories
    # emptied by file pruning can be removed in the same pass
    for root, dirs, files in os.walk(dst, topdown=False):
        rel = os.path.relpath(root, dst)
        sroot = os.path.join(src, rel) if rel != "." else src
        for fn in files:
            if fn.endswith(".tmp"):
                continue
            if not os.path.exists(os.path.join(sroot, fn)):
                try:
                    os.remove(os.path.join(root, fn))
                except FileNotFoundError:
                    pass
        if root != dst and not os.path.isdir(sroot):
            try:
                os.rmdir(root)          # only succeeds once empty
            except OSError:
                pass                    # still holds live entries
    return copied


class DataGather:
    """Background mirroring thread (start/stop)."""

    def __init__(self, src: str, dst: str, interval_s: float = 2.0):
        self.src, self.dst = src, dst
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.copied_total = 0

    def start(self):
        def loop():
            while not self._stop.is_set():
                try:
                    self.copied_total += sync_once(self.src, self.dst)
                except OSError:
                    pass
                self._stop.wait(self.interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=10)
        self.copied_total += sync_once(self.src, self.dst)
