"""Shared neural-net layers: norms, RoPE, attention (train + cached decode),
SwiGLU MLP, chunked cross-entropy.

Conventions:
  * activations are (B, S, ...) with B local under the manual-DP shard_map;
    sharding constraints mention only GSPMD-visible axes (usually "model").
  * attention params: wq (d, n_q), wk/wv (d, n_kv), wo (n_q, d), optional
    bq/bk/bv; n_q = H*Dh and n_kv = KH*Dh are the fused head dims (always
    divisible by the TP axis, unlike raw head counts).
"""
from __future__ import annotations

import functools
import math
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding import TP_AXIS, constrain


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    return ops.rmsnorm(x, w, eps=eps)


def _rms_fwd(x, w, eps):
    return rms_norm(x, w, eps), (x, w)


def _rms_bwd(eps, res, g):
    """Hand-written backward returning dx in the INPUT dtype.

    Autodiff of the f32-upcast reference keeps the activation cotangent in
    f32, doubling every backward activation all-reduce/all-gather; measured
    in §Perf P5 this was most of the residual collective traffic.
    """
    x, w = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = xf * r
    gw = gf * wf
    dx = (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True)) * r
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(w.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Absolute sinusoidal embeddings (whisper-style). positions: (S,)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) absolute positions, or (B, S)
    per-sequence positions (continuous batching: each slot sits at its own
    depth)."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S|B,S, half)
    if ang.ndim == 2:
        cos = jnp.cos(ang)[None, :, None, :]
        sin = jnp.sin(ang)[None, :, None, :]
    else:
        cos = jnp.cos(ang)[:, :, None, :]
        sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * half < D:  # odd head dims (not used by assigned archs, kept safe)
        rot = jnp.concatenate([rot, xf[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


class AttnDims(NamedTuple):
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float
    window: Optional[int]
    causal: bool = True


def _qkv_constrain(t: jax.Array, mode: Optional[str]) -> jax.Array:
    """(B,S,h,D) constraint consistent with the attention shard mode —
    conflicting head constraints trigger SPMD involuntary remat."""
    if mode == "batch":
        return constrain(t, TP_AXIS, None, None, None)
    if mode == "seq":
        return constrain(t, None, TP_AXIS, None, None)
    return constrain(t, None, None, TP_AXIS, None)   # heads / legacy


def _project_qkv(p, x, dims: AttnDims, positions: Optional[jax.Array]):
    from repro.kernels.ops import attn_shard_mode
    B, S, _ = x.shape
    H, KH, Dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    mode = attn_shard_mode(B, KH)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = _qkv_constrain(q.reshape(B, S, H, Dh), mode)
    # k/v: batch-sharded in batch mode; in seq mode every rank needs the
    # full K/V (q-slices attend everywhere) — leave unconstrained so GSPMD
    # gathers once rather than fighting a head constraint.
    k = k.reshape(B, S, KH, Dh)
    v = v.reshape(B, S, KH, Dh)
    if mode != "seq":
        k = _qkv_constrain(k, mode)
        v = _qkv_constrain(v, mode)
    if dims.rope_theta and positions is not None:
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
    return q, k, v


def attention(p: dict, x: jax.Array, dims: AttnDims, *,
              positions: Optional[jax.Array] = None,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (train / prefill). Cross-attention when
    kv_x is given (whisper decoder): k/v projected from kv_x, non-causal."""
    B, S, d = x.shape
    H, KH, Dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    if kv_x is None:
        q, k, v = _project_qkv(p, x, dims, positions)
        causal, window = dims.causal, dims.window
    else:
        Skv = kv_x.shape[1]
        q = (x @ p["wq"]).reshape(B, S, H, Dh)
        q = constrain(q, None, None, TP_AXIS, None)
        k = (kv_x @ p["wk"]).reshape(B, Skv, KH, Dh)
        v = (kv_x @ p["wv"]).reshape(B, Skv, KH, Dh)
        k = constrain(k, None, None, TP_AXIS, None)
        v = constrain(v, None, None, TP_AXIS, None)
        if dims.rope_theta and positions is not None:
            q = apply_rope(q, positions, dims.rope_theta)
            if kv_positions is not None:
                k = apply_rope(k, kv_positions, dims.rope_theta)
        causal, window = False, None
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    o = _qkv_constrain(o, ops.attn_shard_mode(B, KH))
    out = o.reshape(B, S, H * Dh) @ p["wo"]
    return constrain(out, None, None, None)


def decode_attention(p: dict, x: jax.Array, dims: AttnDims, *,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array,
                     ring: bool = False) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a cache.

    x: (B, 1, d); k_cache/v_cache: (B, W, KH, Dh).  `pos` is the number of
    tokens already in the cache (the new token's absolute position) — a
    scalar when every row sits at the same depth, or a (B,) vector when the
    serving tier's continuous batcher has each slot at its own depth.  When
    `ring` (sliding window), the cache is a ring buffer of width W and keys
    were rope'd at insertion; otherwise W == max_len and slot i == position i.
    Returns (attn_out (B,1,n_q), new_k_cache, new_v_cache).
    """
    B, _, _ = x.shape
    H, KH, Dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    W = k_cache.shape[1]
    g = H // KH
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, Dh)
    k = k.reshape(B, 1, KH, Dh)
    v = v.reshape(B, 1, KH, Dh)
    vec = getattr(pos, "ndim", 0) >= 1       # per-sequence positions (B,)
    if dims.rope_theta:
        if vec:
            ppos = pos.astype(jnp.int32).reshape(B, 1)
        else:
            ppos = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, ppos, dims.rope_theta)
        k = apply_rope(k, ppos, dims.rope_theta)
    if vec:
        slot_v = jnp.mod(pos, W) if ring else jnp.minimum(pos, W - 1)
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, slot_v].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, slot_v].set(v[:, 0].astype(v_cache.dtype))
    else:
        slot = jnp.where(ring, pos % W, jnp.minimum(pos, W - 1)) if ring else pos
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))

    qf = (q.astype(jnp.float32) * Dh ** -0.5).reshape(B, 1, KH, g, Dh)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)          # (B,KH,g,1,W)
    s = constrain(s, None, None, None, None, TP_AXIS)
    idx = jnp.arange(W)
    if vec:
        pb = pos[:, None]                                # (B, 1)
        if ring:
            valid = (pb - jnp.mod(pb - idx[None, :], W)) >= 0
        else:
            valid = idx[None, :] <= pb                   # (B, W)
        s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    else:
        if ring:
            # slot j holds absolute position pos - ((pos - j) mod W); valid iff >= 0
            absp = pos - jnp.mod(pos - idx, W)
            valid = absp >= 0
        else:
            valid = idx <= pos
        s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p_attn, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, H * Dh).astype(x.dtype)
    return o @ p["wo"], k_cache, v_cache


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = constrain(h, None, None, TP_AXIS)
    return constrain(h @ p["down"], None, None, None)


def chunked_ce_loss(x: jax.Array, head: jax.Array, labels: jax.Array, *,
                    mask: Optional[jax.Array] = None,
                    chunk: Optional[int] = None) -> tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing full (B,S,V) logits.

    Scans over sequence chunks; each chunk's logits are rematerialized in the
    backward pass (jax.checkpoint), bounding live logits to (B,chunk,V).
    Returns (sum_loss, sum_count) — caller normalizes (and psums over DP).
    """
    B, S, d = x.shape
    if chunk is None:
        chunk = int(os.environ.get("REPRO_CE_CHUNK", "512"))  # memory knob
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mpad = jnp.pad(mask if mask is not None else jnp.ones((B, S), bool),
                       ((0, 0), (0, pad)))
    else:
        mpad = mask if mask is not None else jnp.ones((B, S), bool)
    nc = (S + pad) // chunk
    xs = jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mpad.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def chunk_loss(xc, lc, mc):
        # matmul stays bf16 (XLA accumulates f32 internally) and the upcast
        # happens AFTER: the head cotangent and its cross-chunk accumulation
        # then stay bf16 — the f32 (d,V) grad was gigabytes (§Perf P5)
        logits = (xc @ head).astype(jnp.float32)          # (B,chunk,V)
        logits = constrain(logits, None, None, TP_AXIS)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction, NOT take_along_axis: gathering along the
        # vocab-sharded dim makes GSPMD all-gather the logits (GBs/layer);
        # the masked sum stays local and all-reduces two scalars per token.
        onehot = (lc[..., None] == jnp.arange(logits.shape[-1])[None, None, :])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = (lse - gold) * mc
        return jnp.sum(nll), jnp.sum(mc)

    def body(carry, inp):
        sl, sc = carry
        l, c = chunk_loss(*inp)
        return (sl + l, sc + c.astype(jnp.float32)), None

    (sum_loss, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                        (xs, ls, ms))
    return sum_loss, count
