"""Top-k MoE layer with scatter-based (FLOP-free) dispatch and expert
parallelism over the TP mesh axis.

Dispatch is linear-cost: tokens are routed to per-expert capacity buffers via
scatter-add, experts run as one batched einsum over the expert dim (sharded
over "model" => expert parallelism), and outputs gather back.  No O(T^2)
one-hot dispatch einsums.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.sharding import TP_AXIS, constrain


def moe_ffn(p: dict, x: jax.Array, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B,S,d), aux_loss scalar).

    params: router (d, E), gate/up (E, d, f), down (E, f, d).

    Dispatches to the explicit expert-parallel all-to-all implementation
    (moe_ep.py) whenever the shapes tile the TP axis — the GSPMD scatter
    formulation below costs TBs of all-gather per step (§Perf P6) and is
    kept as the fallback (single device, decode, odd meshes) and baseline
    (REPRO_MOE_EP=0).
    """
    import os

    from repro.models import moe_ep
    B, S, d = x.shape
    if (os.environ.get("REPRO_MOE_EP", "1") == "1"
            and moe_ep.ep_applicable(cfg.num_experts, S)):
        return moe_ep.moe_ffn_ep(p, x, cfg)
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E

    # capacity positions: exclusive running count of prior assignments to the
    # same expert, in (token-major, slot-minor) order.
    C = int(max(1, round(cfg.capacity_factor * k * T / E)))
    flat_ids = ids.reshape(T * k)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)         # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot                  # exclusive
    pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < C                                                 # overflow drop
    gates = gates * keep.reshape(T, k)

    # dispatch: scatter tokens into (E, C, d) buffers (expert-parallel)
    safe_pos = jnp.where(keep, pos, C - 1)
    contrib = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E, C, d), xt.dtype).at[flat_ids, safe_pos].add(contrib)
    buf = constrain(buf, TP_AXIS, None, None)

    # expert FFN, batched over E (sharded over "model" => one expert group/rank)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = constrain(h, TP_AXIS, None, None)
    out = jnp.einsum("ecf,efd->ecd", h, p["down"])
    out = constrain(out, TP_AXIS, None, None)

    # combine: gather each token's k expert outputs, weight by gates
    picked = out[flat_ids, safe_pos]                               # (T*k, d)
    picked = picked * (gates.reshape(T * k)[:, None]).astype(picked.dtype)
    y = jnp.sum(picked.reshape(T, k, d), axis=1)
    return y.reshape(B, S, d), aux
