"""Model registry: build a model object for any registered arch, plus the
input/batch specs (ShapeDtypeStructs) for each (arch × shape) cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.hybrid import HybridLM
from repro.models.mamba2 import MambaLM
from repro.models.transformer import Transformer


def build_model(cfg: ModelConfig):
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    return Transformer(cfg)


def batch_abstract(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    B, S = shape.global_batch, shape.seq_len
    bf16 = jnp.bfloat16
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode: one new token against a cache of length S
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.vision_tokens and shape.kind != "decode":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), bf16)
    if cfg.encoder_layers and shape.kind != "decode":
        batch["source_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.source_len, cfg.d_model), bf16)
    return batch


def batch_concrete(cfg: ModelConfig, shape_kind: str, batch_size: int,
                   seq_len: int, seed: int = 0) -> dict:
    """Small concrete batch for smoke tests / examples."""
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 3)
    S = seq_len + 1 if shape_kind == "train" else seq_len
    batch = {"tokens": jax.random.randint(ks[0], (batch_size, S), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.vision_tokens and shape_kind != "decode":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (batch_size, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers and shape_kind != "decode":
        batch["source_frames"] = jax.random.normal(
            ks[2], (batch_size, cfg.source_len, cfg.d_model), jnp.bfloat16)
    return batch
