"""Explicit expert-parallel MoE via a nested shard_map over the TP axis.

The GSPMD formulation in moe.py scatters tokens into an expert-sharded
capacity buffer; the partitioner reconciles sharded scatter/gather with
all-gathers of the whole buffer (§Perf P6: ~4 TB/chip/step on dbrx).  The
textbook fix is explicit all-to-alls over the expert-parallel axis:

  per rank: route local tokens -> per-destination-expert capacity buffers
  -> all_to_all (tokens travel to their expert's rank)
  -> dense local expert FFN
  -> all_to_all back -> weighted combine.

Link bytes per rank per layer = 2 * k * cf * T_local * d — two orders of
magnitude below the GSPMD scatter lowering.  Falls back to moe.py when the
shapes don't tile (decode, odd meshes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.sharding import TP_AXIS, axis_size


def ep_applicable(E: int, S: int) -> bool:
    n = axis_size(TP_AXIS)
    return n > 1 and E % n == 0 and S % n == 0


def moe_ffn_ep(p: dict, x: jax.Array, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) with S divisible by the TP axis. Returns (y, aux)."""
    E, k = cfg.num_experts, cfg.top_k
    n = axis_size(TP_AXIS)

    def body(xs, router, gate, up, down):
        # xs: (B, S/n, d) local; gate/up/down: (E/n, d, f) local experts
        B, Sl, d = xs.shape
        T = B * Sl
        xt = xs.reshape(T, d)
        logits = (xt @ router).astype(jnp.float32)            # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, k)                  # (T, k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

        density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
        density_proxy = jnp.mean(probs, axis=0)
        aux = jnp.sum(density * density_proxy) * E
        aux = jax.lax.pmean(aux, TP_AXIS)

        # local capacity per (destination expert): C tokens
        C = int(max(1, round(cfg.capacity_factor * k * T / E)))
        flat_ids = ids.reshape(T * k)
        onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
        keep = pos < C
        gates = gates * keep.reshape(T, k)
        safe_pos = jnp.where(keep, pos, C - 1)

        contrib = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
        send = jnp.zeros((E, C, d), xt.dtype).at[flat_ids, safe_pos].add(contrib)

        # tokens travel to their expert's rank: (E, C, d) -> regroup by rank
        e_local = E // n
        send = send.reshape(n, e_local, C, d)
        recv = jax.lax.all_to_all(send, TP_AXIS, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (n_sources, e_local, C, d) — rows destined for MY experts.
        # cast back to the weight dtype: XLA-CPU promotes bf16 scatter-add
        # to f32 and the upcast must not spread into the expert matmuls
        # (it would drag the gathered weights to f32 — §Perf P7).
        h_in = jnp.moveaxis(recv, 1, 0).reshape(e_local, n * C, d)
        h_in = h_in.astype(gate.dtype)
        hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h_in, gate))
        hg = hg * jnp.einsum("ecd,edf->ecf", h_in, up)
        out = jnp.einsum("ecf,efd->ecd", hg, down)            # (e_local, n*C, d)
        out = jnp.moveaxis(out.reshape(e_local, n, C, d), 1, 0)
        back = jax.lax.all_to_all(out, TP_AXIS, split_axis=0, concat_axis=0,
                                  tiled=False)
        back = back.reshape(E, C, d)                          # send-layout again

        picked = back[flat_ids, safe_pos]
        picked = picked * gates.reshape(T * k)[:, None].astype(picked.dtype)
        y = jnp.sum(picked.reshape(T, k, d), axis=1)
        return y.reshape(B, Sl, d), aux

    inner = jax.shard_map(
        body,
        in_specs=(P(None, TP_AXIS, None), P(), P(TP_AXIS, None, None),
                  P(TP_AXIS, None, None), P(TP_AXIS, None, None)),
        out_specs=(P(None, TP_AXIS, None), P()),
        axis_names={TP_AXIS}, check_vma=False)
    # the ZeRO gather hook (custom_vjp) is opaque to sharding propagation:
    # without explicit constraints GSPMD replicates the expert weights over
    # "model" before slicing them back for the inner shard_map (§Perf P7)
    from repro.sharding import constrain
    gate = constrain(p["gate"], TP_AXIS, None, None)
    up = constrain(p["up"], TP_AXIS, None, None)
    down = constrain(p["down"], TP_AXIS, None, None)
    xs = constrain(x, None, TP_AXIS, None)
    return inner(xs, p["router"], gate, up, down)
