"""Declarative parameter definitions.

Each model builds one nested-dict tree of :class:`PD` (param defs); from that
single source we derive initialization, PartitionSpecs (TP + optional
FSDP/ZeRO dims), shard_map in_specs for the manual-DP training step, and
abstract shapes for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# logical axis names that map to the tensor-parallel mesh axis
TP_LOGICAL = {"vocab", "heads", "kv_heads", "ff", "experts", "d_inner", "ssm_heads"}
# logical axes eligible to carry the FSDP ("data") sharding dim
FSDP_LOGICAL = {"d_model", "vocab", "ff", "d_inner", "heads", "kv_heads", "conv_ch", "source"}


@dataclass(frozen=True)
class PD:
    """One parameter definition."""
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "normal"                     # normal | zeros | ones | ssm_a | arange
    scale: Optional[float] = None            # stddev; default fan-in
    dtype: str = "bfloat16"

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"PD: shape {self.shape} and axes {self.axes} "
                             f"must have the same rank")


def _fan_in(pd: PD) -> int:
    # fan-in = product of non-output dims; heuristically first non-layer dim
    dims = [s for s, a in zip(pd.shape, pd.axes) if a not in (None, "layers")]
    return dims[0] if dims else 1


def init_one(pd: PD, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(pd.dtype)
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dt)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dt)
    if pd.init == "ssm_a":
        # mamba2: A = -exp(uniform log) in (-16, -1)
        u = jax.random.uniform(key, pd.shape, jnp.float32, 1.0, 16.0)
        return (-u).astype(dt)
    if pd.init == "arange":
        n = pd.shape[-1]
        return jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), pd.shape).astype(dt)
    std = pd.scale if pd.scale is not None else _fan_in(pd) ** -0.5
    return (jax.random.normal(key, pd.shape, jnp.float32) * std).astype(dt)


def is_pd_leaf(x) -> bool:
    return isinstance(x, PD)


def tree_init(defs, seed: int = 0):
    """Initialize a full param tree from PDs (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_pd_leaf)
    base = jax.random.PRNGKey(seed)
    keys = jax.random.split(base, max(len(leaves), 1))
    arrs = [init_one(pd, k) for pd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def tree_abstract(defs):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, jnp.dtype(pd.dtype)),
        defs, is_leaf=is_pd_leaf)


def fsdp_dim(pd: PD, fsdp_size: int, tp_size: int = 16) -> Optional[int]:
    """Pick the dim that carries the FSDP/"data" sharding for this param.

    Prefer the *last* eligible dim (usually the largest feature dim) that is
    divisible by the fsdp axis size and not already TP-sharded.  None when no
    dim qualifies (param stays replicated over data; e.g. tiny scalars).
    """
    cand = [i for i in range(len(pd.shape))
            if pd.axes[i] in FSDP_LOGICAL
            and pd.axes[i] not in TP_LOGICAL
            and pd.shape[i] % fsdp_size == 0]
    if not cand:
        # allow fsdp on a TP-logical dim when it is large and divisible by
        # (tp*fsdp) — GSPMD composes both axes on one dim.
        cand = [i for i in range(len(pd.shape))
                if pd.axes[i] in TP_LOGICAL
                and pd.shape[i] % (fsdp_size * max(tp_size, 1)) == 0]
        return cand[-1] if cand else None
    return cand[-1]


def spec_for(pd: PD, *, tp_axis: str = "model", fsdp_axes: tuple[str, ...] = (),
             fsdp_size: int = 1, tp_size: int = 16) -> P:
    """PartitionSpec for one param: TP on logical TP dims, FSDP on one dim.

    TP applies only when the dim divides evenly (e.g. mamba2's vocab 50280
    is not divisible by 16 => the embedding stays replicated over model)."""
    entries: list = []
    for a, s in zip(pd.axes, pd.shape):
        entries.append(tp_axis if (a in TP_LOGICAL and tp_size > 0
                                   and s % max(tp_size, 1) == 0) else None)
    if fsdp_axes and fsdp_size > 1:
        d = fsdp_dim(pd, fsdp_size, tp_size)
        if d is not None:
            cur = entries[d]
            if cur is None:
                entries[d] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            else:
                entries[d] = (cur,) + tuple(fsdp_axes)
    return P(*entries)


def tree_specs(defs, *, tp_axis: str = "model", fsdp_axes: tuple[str, ...] = (),
               fsdp_size: int = 1, tp_size: int = 16):
    return jax.tree.map(
        lambda pd: spec_for(pd, tp_axis=tp_axis, fsdp_axes=fsdp_axes,
                            fsdp_size=fsdp_size, tp_size=tp_size),
        defs, is_leaf=is_pd_leaf)


def tree_fsdp_dims(defs, fsdp_size: int, tp_size: int = 16):
    """Per-param FSDP dim index (or None) — used by the manual-DP train step
    to all-gather shards at use and reduce-scatter grads."""
    return jax.tree.map(lambda pd: fsdp_dim(pd, fsdp_size, tp_size),
                        defs, is_leaf=is_pd_leaf)


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_pd_leaf)
    return int(sum(int(np.prod(pd.shape)) for pd in leaves))


def leaf_bytes_pd(pd: PD) -> int:
    return int(np.prod(pd.shape)) * jnp.dtype(pd.dtype).itemsize
