"""Mamba2 / SSD (state-space duality) blocks, pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060):
quadratic attention *within* chunks of length Q, linear state recurrence
*across* chunks (lax.scan over S/Q chunk states).  Decode uses the O(1)
recurrent update.  SSD heads are sharded over the TP axis (d_inner dims);
B/C are group-shared (ngroups=1, MQA-like) and replicated.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import PD
from repro.sharding import TP_AXIS, constrain

Gather = Optional[Callable]


def mamba_block_defs(cfg: ModelConfig, n_layers: int) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    H = d_in // s.head_dim
    gN = s.ngroups * s.state_dim
    lay = ("layers",)
    return {
        "w_z": PD((n_layers, d, d_in), lay + ("d_model", "d_inner")),
        "w_x": PD((n_layers, d, d_in), lay + ("d_model", "d_inner")),
        "w_B": PD((n_layers, d, gN), lay + ("d_model", None)),
        "w_C": PD((n_layers, d, gN), lay + ("d_model", None)),
        "w_dt": PD((n_layers, d, H), lay + ("d_model", "ssm_heads")),
        "conv_x": PD((n_layers, s.conv_width, d_in), lay + ("conv", "d_inner"),
                     scale=s.conv_width ** -0.5),
        "conv_B": PD((n_layers, s.conv_width, gN), lay + ("conv", None),
                     scale=s.conv_width ** -0.5),
        "conv_C": PD((n_layers, s.conv_width, gN), lay + ("conv", None),
                     scale=s.conv_width ** -0.5),
        "conv_x_b": PD((n_layers, d_in), lay + ("d_inner",), init="zeros"),
        "conv_B_b": PD((n_layers, gN), lay + (None,), init="zeros"),
        "conv_C_b": PD((n_layers, gN), lay + (None,), init="zeros"),
        "A": PD((n_layers, H), lay + ("ssm_heads",), init="ssm_a", dtype="float32"),
        "dt_bias": PD((n_layers, H), lay + ("ssm_heads",), init="zeros", dtype="float32"),
        "norm": PD((n_layers, d_in), lay + ("d_inner",), init="ones"),
        "w_out": PD((n_layers, d_in, d), lay + ("d_inner", "d_model"),
                    scale=(d_in ** -0.5) / (2 * max(cfg.num_layers, 1)) ** 0.5),
        "ln": PD((n_layers, d), lay + ("d_model",), init="ones"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C); w: (W,C); b: (C,)."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        xi = x if shift == 0 else jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out + b


def _ssd_chunked(xh, dt, A, Bm, Cm, Q: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) softplus'd; A: (H,) negative;
    Bm/Cm: (B,S,N) (ngroups=1, broadcast over heads). Returns y (B,S,H,P).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xh = xh.reshape(Bsz, nc, Q, H, P)
    dt = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    dA = dt * A[None, None, None, :]                     # (B,nc,Q,H) negative
    dA_cs = jnp.cumsum(dA, axis=2)                        # inclusive cumsum
    seg_sum = dA_cs[:, :, -1, :]                          # (B,nc,H)

    # intra-chunk (quadratic within chunk): y_i += sum_{j<=i} C_i.B_j *
    #   exp(dAcs_i - dAcs_j) * dt_j * x_j
    scores = jnp.einsum("bcqn,bckn->bcqk", Cm, Bm)        # (B,nc,Q,Q)
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    # mask in log domain BEFORE exp: exp of the masked (positive) exponents
    # would be inf, and inf*0 in the backward pass is NaN.
    logdecay = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    logdecay = jnp.where(causal[None, None, :, :, None], logdecay, -1e30)
    decay = jnp.exp(logdecay)
    w = scores[..., None] * decay                         # (B,nc,Q,Q,H)
    xdt = xh.astype(jnp.float32) * dt[..., None]          # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w, xdt)

    # chunk states: S_c = sum_j B_j (x_j dt_j) exp(seg_sum - dAcs_j)
    decay_to_end = jnp.exp(seg_sum[:, :, None, :] - dA_cs)            # (B,nc,Q,H)
    state_c = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bm, decay_to_end, xdt)

    # inter-chunk recurrence: h_{c} = exp(seg_sum_{c-1}) h_{c-1} + S_{c-1}
    def step(h, inp):
        s_c, seg = inp
        h_next = h * jnp.exp(seg)[:, :, None, None] + s_c
        return h_next, h                                   # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, h_prefix = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(seg_sum, 1, 0)))
    h_prefix = jnp.moveaxis(h_prefix, 0, 1)                # (B,nc,H,P,N)

    # y_inter_i = C_i . (exp(dAcs_i) * h_prefix)
    decay_from_start = jnp.exp(dA_cs)                      # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cm, decay_from_start, h_prefix)

    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(xh.dtype)


def gated_rmsnorm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """Mamba2 output norm: RMSNorm(y * silu(z)) * w over the channel dim."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(y.dtype)


def mamba_forward(lp: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One mamba2 block (pre-norm residual included). x: (B,S,d)."""
    s = cfg.ssm
    B_, S, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    z = h @ lp["w_z"]
    xs = h @ lp["w_x"]
    Bm = h @ lp["w_B"]
    Cm = h @ lp["w_C"]
    dt = (h @ lp["w_dt"]).astype(jnp.float32)
    xs = constrain(xs, None, None, TP_AXIS)
    z = constrain(z, None, None, TP_AXIS)
    xs = jax.nn.silu(_causal_conv(xs, lp["conv_x"], lp["conv_x_b"]))
    Bm = jax.nn.silu(_causal_conv(Bm, lp["conv_B"], lp["conv_B_b"]))
    Cm = jax.nn.silu(_causal_conv(Cm, lp["conv_C"], lp["conv_C_b"]))
    dt = jax.nn.softplus(dt + lp["dt_bias"])
    xh = xs.reshape(B_, S, H, s.head_dim)
    y = _ssd_chunked(xh, dt, lp["A"], Bm, Cm, s.chunk)
    y = constrain(y.reshape(B_, S, d_in), None, None, TP_AXIS)
    y = gated_rmsnorm(y, z, lp["norm"], cfg.norm_eps)
    return x + y @ lp["w_out"]


# ---------------------------------------------------------------------------
# decode (recurrent form)
# ---------------------------------------------------------------------------

def mamba_state_defs(cfg: ModelConfig, n_layers: int, batch: int) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    gN = s.ngroups * s.state_dim
    conv_ch = d_in + 2 * gN
    return {
        "ssm": PD((n_layers, batch, H, s.head_dim, s.state_dim),
                  ("layers", "batch", "ssm_heads", None, None), init="zeros",
                  dtype="float32"),
        "conv": PD((n_layers, batch, s.conv_width - 1, conv_ch),
                   ("layers", "batch", None, "conv_ch"), init="zeros",
                   dtype="float32"),
    }


def mamba_decode(lp: dict, state: dict, x: jax.Array, cfg: ModelConfig
                 ) -> tuple[jax.Array, dict]:
    """One-token recurrent update. x: (B,1,d); state: {"ssm","conv"} slices."""
    s = cfg.ssm
    B_, _, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    gN = s.ngroups * s.state_dim
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)[:, 0]       # (B,d)
    z = h @ lp["w_z"]
    xs = h @ lp["w_x"]
    Bm = h @ lp["w_B"]
    Cm = h @ lp["w_C"]
    dt = jax.nn.softplus((h @ lp["w_dt"]).astype(jnp.float32) + lp["dt_bias"])

    # conv ring: state["conv"] holds the last (W-1) pre-activation inputs
    cur = jnp.concatenate([xs, Bm, Cm], axis=-1)          # (B, conv_ch)
    hist = state["conv"]                                   # (B, W-1, conv_ch)
    wfull = jnp.concatenate([lp["conv_x"], lp["conv_B"], lp["conv_C"]], axis=-1)
    bfull = jnp.concatenate([lp["conv_x_b"], lp["conv_B_b"], lp["conv_C_b"]], axis=-1)
    window = jnp.concatenate([hist, cur[:, None]], axis=1)  # (B, W, conv_ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, wfull) + bfull
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]
    xs_c = conv_out[:, :d_in]
    Bm_c = conv_out[:, d_in:d_in + gN]
    Cm_c = conv_out[:, d_in + gN:]

    xh = xs_c.reshape(B_, H, s.head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * lp["A"][None])                      # (B,H)
    ssm = state["ssm"]                                     # (B,H,P,N)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bm_c.astype(jnp.float32))
    ssm = ssm * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm_c.astype(jnp.float32))
    y = y.reshape(B_, d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z, lp["norm"], cfg.norm_eps)
    out = x + (y @ lp["w_out"])[:, None]
    return out, {"ssm": ssm, "conv": new_conv}


# ---------------------------------------------------------------------------
# full attention-free model (mamba2-780m)
# ---------------------------------------------------------------------------

class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_defs(self) -> dict:
        c = self.cfg
        defs = {
            "blocks": mamba_block_defs(c, c.num_layers),
            "embed": PD((c.vocab_size, c.d_model), ("vocab", "d_model"), scale=0.02),
            "ln_f": PD((c.d_model,), ("d_model",), init="ones"),
        }
        if not c.tie_embeddings:
            defs["head"] = PD((c.d_model, c.vocab_size), ("d_model", "vocab"))
        return defs

    def _head(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["head"]

    def hidden_states(self, params, batch, *, gather: Gather = None):
        c = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        gather = gather or (lambda p: p)
        body = functools.partial(self._apply, gather=gather)
        if c.remat:
            body = jax.checkpoint(body)

        def step(x, lp):
            return body(lp, x), None

        x, _ = jax.lax.scan(step, x, params["blocks"])
        return L.rms_norm(x, params["ln_f"], c.norm_eps), jnp.float32(0.0), 0

    def _apply(self, lp, x, *, gather):
        return mamba_forward(gather(lp), x, self.cfg)

    def loss(self, params, batch, *, gather: Gather = None):
        tokens = batch["tokens"]
        x, _, _ = self.hidden_states(params, {**batch, "tokens": tokens[:, :-1]},
                                     gather=gather)
        sum_loss, count = L.chunked_ce_loss(x, self._head(params), tokens[:, 1:])
        loss = sum_loss / jnp.maximum(count, 1.0)
        return loss, {"ce_loss": loss, "aux_loss": jnp.float32(0.0), "tokens": count}

    def logits(self, params, batch, *, gather: Gather = None):
        x, _, _ = self.hidden_states(params, batch, gather=gather)
        return constrain((x @ self._head(params)).astype(jnp.float32),
                         None, None, TP_AXIS)

    def cache_defs(self, batch_size: int, max_len: int) -> dict:
        return mamba_state_defs(self.cfg, self.cfg.num_layers, batch_size)

    def decode_step(self, params, cache, pos, tokens, *, gather: Gather = None):
        c = self.cfg
        gather = gather or (lambda p: p)
        x = jnp.take(params["embed"], tokens, axis=0)

        def step(x, inp):
            lp, ssm, conv = inp
            x, new = mamba_decode(gather(lp), {"ssm": ssm, "conv": conv}, x, c)
            return x, (new["ssm"], new["conv"])

        x, (ssm_new, conv_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["ssm"], cache["conv"]))
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        logits = (x @ self._head(params)).astype(jnp.float32)
        return constrain(logits, None, None, TP_AXIS), {"ssm": ssm_new, "conv": conv_new}

    def prefill(self, params, batch, *, gather: Gather = None):
        """Sequential-scan prefill producing the recurrent state.

        For the dry-run we run the chunked forward for logits and a compact
        recurrent pass for the final state; a production system would fuse
        them (the chunked scan already computes chunk states).
        """
        c = self.cfg
        gather = gather or (lambda p: p)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)

        def body(x, lp):
            lp = gather(lp)
            y = mamba_forward(lp, x, c)
            st = _final_state(lp, x, c)
            return y, st

        x, states = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        logits = (x[:, -1:] @ self._head(params)).astype(jnp.float32)
        return logits, states


def _final_state(lp: dict, x: jax.Array, cfg: ModelConfig) -> dict:
    """Final (ssm, conv) state after processing x through one block."""
    s = cfg.ssm
    B_, S, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    gN = s.ngroups * s.state_dim
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    xs = h @ lp["w_x"]
    Bm = h @ lp["w_B"]
    Cm = h @ lp["w_C"]
    dt = jax.nn.softplus((h @ lp["w_dt"]).astype(jnp.float32) + lp["dt_bias"])
    pre = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = pre[:, -(s.conv_width - 1):]
    xs = jax.nn.silu(_causal_conv(xs, lp["conv_x"], lp["conv_x_b"]))
    Bm = jax.nn.silu(_causal_conv(Bm, lp["conv_B"], lp["conv_B_b"]))
    xh = (xs.reshape(B_, S, H, s.head_dim).astype(jnp.float32)
          * dt[..., None])
    dA = dt * lp["A"][None, None]                          # (B,S,H) log-decay
    # suffix decay: exp(sum_{j>t} dA_j)
    suffix = jnp.cumsum(dA[:, ::-1], axis=1)[:, ::-1] - dA
    w = jnp.exp(suffix)
    ssm = jnp.einsum("bsh,bshp,bsn->bhpn", w, xh, Bm.astype(jnp.float32))
    return {"ssm": ssm, "conv": conv_state}
