"""Zamba2-style hybrid: Mamba2 backbone with one weight-shared attention+MLP
block applied every `attn_every` mamba blocks.

The shared block's params are NOT stacked (one copy); inside the layer scan a
lax.cond applies it at interleave sites.  Its KV caches ARE per-site (the
block re-reads different depths), stacked on a leading sites dim.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.param import PD
from repro.sharding import TP_AXIS, constrain

Gather = Optional[Callable]


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dims = L.AttnDims(
            num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            window=None,
        )
        self.n_sites = cfg.num_layers // cfg.attn_every

    def param_defs(self) -> dict:
        c = self.cfg
        d, f = c.d_model, c.d_ff
        Dh = c.resolved_head_dim
        nq, nkv = c.num_heads * Dh, c.num_kv_heads * Dh
        shared = {
            "attn": {
                "wq": PD((d, nq), ("d_model", "heads")),
                "wk": PD((d, nkv), ("d_model", "kv_heads")),
                "wv": PD((d, nkv), ("d_model", "kv_heads")),
                "wo": PD((nq, d), ("heads", "d_model"), scale=nq ** -0.5),
            },
            "ffn": {
                "gate": PD((d, f), ("d_model", "ff")),
                "up": PD((d, f), ("d_model", "ff")),
                "down": PD((f, d), ("ff", "d_model"), scale=f ** -0.5),
            },
            "ln1": PD((d,), ("d_model",), init="ones"),
            "ln2": PD((d,), ("d_model",), init="ones"),
        }
        return {
            "blocks": M.mamba_block_defs(c, c.num_layers),
            "shared": shared,
            "embed": PD((c.vocab_size, d), ("vocab", "d_model"), scale=0.02),
            "head": PD((d, c.vocab_size), ("d_model", "vocab")),
            "ln_f": PD((d,), ("d_model",), init="ones"),
        }

    def _shared_apply(self, sp: dict, x: jax.Array, positions) -> jax.Array:
        c = self.cfg
        h = L.rms_norm(x, sp["ln1"], c.norm_eps)
        x = x + L.attention(sp["attn"], h, self.dims, positions=positions)
        h = L.rms_norm(x, sp["ln2"], c.norm_eps)
        return x + L.swiglu(sp["ffn"], h)

    def hidden_states(self, params, batch, *, gather: Gather = None):
        c = self.cfg
        gather = gather or (lambda p: p)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        S = x.shape[1]
        positions = jnp.arange(S)
        sp = params["shared"]

        def body(lp, x, i):
            x = M.mamba_forward(gather(lp), x, c)
            x = jax.lax.cond(
                (i % c.attn_every) == (c.attn_every - 1),
                lambda xx: self._shared_apply(sp, xx, positions),
                lambda xx: xx,
                x)
            return x

        if c.remat:
            body = jax.checkpoint(body)

        def step(carry, lp):
            x, i = carry
            return (body(lp, x, i), i + 1), None

        (x, _), _ = jax.lax.scan(step, (x, jnp.int32(0)), params["blocks"])
        return L.rms_norm(x, params["ln_f"], c.norm_eps), jnp.float32(0.0), 0

    def loss(self, params, batch, *, gather: Gather = None):
        tokens = batch["tokens"]
        x, _, _ = self.hidden_states(params, {**batch, "tokens": tokens[:, :-1]},
                                     gather=gather)
        sum_loss, count = L.chunked_ce_loss(x, params["head"], tokens[:, 1:])
        loss = sum_loss / jnp.maximum(count, 1.0)
        return loss, {"ce_loss": loss, "aux_loss": jnp.float32(0.0), "tokens": count}

    def logits(self, params, batch, *, gather: Gather = None):
        x, _, _ = self.hidden_states(params, batch, gather=gather)
        return constrain((x @ params["head"]).astype(jnp.float32),
                         None, None, TP_AXIS)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def cache_defs(self, batch_size: int, max_len: int) -> dict:
        c = self.cfg
        Dh = c.resolved_head_dim
        defs = M.mamba_state_defs(c, c.num_layers, batch_size)
        kv = ("sites", "batch", "seq", "kv_heads", None)
        defs["shared_k"] = PD((self.n_sites, batch_size, max_len, c.num_kv_heads, Dh),
                              kv, init="zeros")
        defs["shared_v"] = PD((self.n_sites, batch_size, max_len, c.num_kv_heads, Dh),
                              kv, init="zeros")
        return defs

    def decode_step(self, params, cache, pos, tokens, *, gather: Gather = None):
        c = self.cfg
        gather = gather or (lambda p: p)
        x = jnp.take(params["embed"], tokens, axis=0)
        sp = params["shared"]
        n_sites = self.n_sites

        def mamba_step(x, inp):
            lp, ssm, conv = inp
            x, new = M.mamba_decode(gather(lp), {"ssm": ssm, "conv": conv}, x, c)
            return x, (new["ssm"], new["conv"])

        # interleave: run groups of attn_every mamba layers, then a shared
        # attention site.  Python loop over sites (static, small).
        ssm_out, conv_out, k_out, v_out = [], [], [], []
        per = c.attn_every
        for site in range(n_sites):
            sl = slice(site * per, (site + 1) * per)
            seg = jax.tree.map(lambda a: a[sl], params["blocks"])
            x, (ssm_n, conv_n) = jax.lax.scan(
                mamba_step, x, (seg, cache["ssm"][sl], cache["conv"][sl]))
            ssm_out.append(ssm_n)
            conv_out.append(conv_n)
            h = L.rms_norm(x, sp["ln1"], c.norm_eps)
            a, kc, vc = L.decode_attention(
                sp["attn"], h, self.dims,
                k_cache=cache["shared_k"][site], v_cache=cache["shared_v"][site],
                pos=pos, ring=False)
            x = x + a
            h = L.rms_norm(x, sp["ln2"], c.norm_eps)
            x = x + L.swiglu(sp["ffn"], h)
            k_out.append(kc)
            v_out.append(vc)
        # trailing mamba layers (if num_layers % attn_every)
        rem = c.num_layers - n_sites * per
        if rem:
            sl = slice(n_sites * per, c.num_layers)
            seg = jax.tree.map(lambda a: a[sl], params["blocks"])
            x, (ssm_n, conv_n) = jax.lax.scan(
                mamba_step, x, (seg, cache["ssm"][sl], cache["conv"][sl]))
            ssm_out.append(ssm_n)
            conv_out.append(conv_n)
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        logits = constrain((x @ params["head"]).astype(jnp.float32),
                           None, None, TP_AXIS)
        new_cache = {
            "ssm": jnp.concatenate(ssm_out, axis=0),
            "conv": jnp.concatenate(conv_out, axis=0),
            "shared_k": jnp.stack(k_out, axis=0),
            "shared_v": jnp.stack(v_out, axis=0),
        }
        return logits, new_cache

    def prefill(self, params, batch, *, gather: Gather = None):
        """Full-prompt pass producing mamba states + shared-site KV caches."""
        c = self.cfg
        gather = gather or (lambda p: p)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        positions = jnp.arange(S)
        sp = params["shared"]
        per = c.attn_every

        def seg_step(x, lp):
            lp = gather(lp)
            y = M.mamba_forward(lp, x, c)
            st = M._final_state(lp, x, c)
            return y, (st["ssm"], st["conv"])

        ssm_out, conv_out, k_out, v_out = [], [], [], []
        n_full = self.n_sites
        for site in range(n_full):
            sl = slice(site * per, (site + 1) * per)
            seg = jax.tree.map(lambda a: a[sl], params["blocks"])
            x, (ssm_n, conv_n) = jax.lax.scan(seg_step, x, seg)
            ssm_out.append(ssm_n)
            conv_out.append(conv_n)
            h = L.rms_norm(x, sp["ln1"], c.norm_eps)
            q, k, v = L._project_qkv(sp["attn"], h, self.dims, positions)
            from repro.kernels import ops
            o = ops.flash_attention(q, k, v, causal=True)
            x = x + o.reshape(B, S, -1) @ sp["attn"]["wo"]
            h = L.rms_norm(x, sp["ln2"], c.norm_eps)
            x = x + L.swiglu(sp["ffn"], h)
            k_out.append(k)
            v_out.append(v)
        rem = c.num_layers - n_full * per
        if rem:
            sl = slice(n_full * per, c.num_layers)
            seg = jax.tree.map(lambda a: a[sl], params["blocks"])
            x, (ssm_n, conv_n) = jax.lax.scan(seg_step, x, seg)
            ssm_out.append(ssm_n)
            conv_out.append(conv_n)
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        logits = constrain((x[:, -1:] @ params["head"]).astype(jnp.float32),
                           None, None, TP_AXIS)
        cache = {
            "ssm": jnp.concatenate(ssm_out, axis=0),
            "conv": jnp.concatenate(conv_out, axis=0),
            "shared_k": jnp.stack(k_out, axis=0),
            "shared_v": jnp.stack(v_out, axis=0),
        }
        return logits, cache
