from repro.models.registry import batch_abstract, batch_concrete, build_model  # noqa: F401
