"""Decoder-only (and encoder-decoder) transformer LM.

Covers the dense, moe, vlm (stub patch-embedding inputs) and audio (stub
frame-embedding inputs, encoder-decoder) families.  Layers are scanned with
stacked params (compile time independent of depth); an optional `gather`
callable is applied to each layer's params inside the scan body — the
ZeRO-3/FSDP hook: the train step passes an all-gather-over-"data", and
because it sits inside jax.checkpoint, backward re-gathers and autodiff
turns the gather into a reduce-scatter of gradients.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.param import PD
from repro.sharding import TP_AXIS, constrain

Gather = Optional[Callable]


def _identity_gather(p):
    return p


class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dims = L.AttnDims(
            num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            window=cfg.sliding_window,
        )

    # ------------------------------------------------------------------
    # parameter definitions
    # ------------------------------------------------------------------

    def _attn_defs(self, n_layers: int) -> dict:
        c = self.cfg
        Dh = c.resolved_head_dim
        nq, nkv = c.num_heads * Dh, c.num_kv_heads * Dh
        d = c.d_model
        defs = {
            "wq": PD((n_layers, d, nq), ("layers", "d_model", "heads")),
            "wk": PD((n_layers, d, nkv), ("layers", "d_model", "kv_heads")),
            "wv": PD((n_layers, d, nkv), ("layers", "d_model", "kv_heads")),
            "wo": PD((n_layers, nq, d), ("layers", "heads", "d_model"),
                     scale=(nq ** -0.5) / (2 * c.num_layers) ** 0.5),
        }
        if c.qkv_bias:
            defs["bq"] = PD((n_layers, nq), ("layers", "heads"), init="zeros")
            defs["bk"] = PD((n_layers, nkv), ("layers", "kv_heads"), init="zeros")
            defs["bv"] = PD((n_layers, nkv), ("layers", "kv_heads"), init="zeros")
        return defs

    def _ffn_defs(self, n_layers: int) -> dict:
        c = self.cfg
        d, f = c.d_model, c.d_ff
        if c.moe is not None:
            E = c.moe.num_experts
            return {
                "router": PD((n_layers, d, E), ("layers", "d_model", None)),
                "gate": PD((n_layers, E, d, f), ("layers", "experts", "d_model", None)),
                "up": PD((n_layers, E, d, f), ("layers", "experts", "d_model", None)),
                "down": PD((n_layers, E, f, d), ("layers", "experts", None, "d_model"),
                           scale=(f ** -0.5) / (2 * c.num_layers) ** 0.5),
            }
        return {
            "gate": PD((n_layers, d, f), ("layers", "d_model", "ff")),
            "up": PD((n_layers, d, f), ("layers", "d_model", "ff")),
            "down": PD((n_layers, f, d), ("layers", "ff", "d_model"),
                       scale=(f ** -0.5) / (2 * c.num_layers) ** 0.5),
        }

    def param_defs(self) -> dict:
        c = self.cfg
        d, V, nL = c.d_model, c.vocab_size, c.num_layers
        blocks = {
            "attn": self._attn_defs(nL),
            "ffn": self._ffn_defs(nL),
            "ln1": PD((nL, d), ("layers", "d_model"), init="ones"),
            "ln2": PD((nL, d), ("layers", "d_model"), init="ones"),
        }
        if c.encoder_layers:
            blocks["xattn"] = self._attn_defs(nL)
            blocks["lnx"] = PD((nL, d), ("layers", "d_model"), init="ones")
        defs = {
            "blocks": blocks,
            "embed": PD((V, d), ("vocab", "d_model"), scale=0.02),
            "ln_f": PD((d,), ("d_model",), init="ones"),
        }
        if not c.tie_embeddings:
            defs["head"] = PD((d, V), ("d_model", "vocab"))
        if c.encoder_layers:
            eL = c.encoder_layers
            defs["encoder"] = {
                "attn": self._attn_defs(eL),
                "ffn": {
                    "gate": PD((eL, d, c.d_ff), ("layers", "d_model", "ff")),
                    "up": PD((eL, d, c.d_ff), ("layers", "d_model", "ff")),
                    "down": PD((eL, c.d_ff, d), ("layers", "ff", "d_model")),
                },
                "ln1": PD((eL, d), ("layers", "d_model"), init="ones"),
                "ln2": PD((eL, d), ("layers", "d_model"), init="ones"),
                "ln_f": PD((d,), ("d_model",), init="ones"),
            }
        return defs

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------

    def _block(self, lp: dict, x: jax.Array, positions: jax.Array,
               enc_out: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
        c = self.cfg
        h = L.rms_norm(x, lp["ln1"], c.norm_eps)
        x = x + L.attention(lp["attn"], h, self.dims, positions=positions)
        if enc_out is not None:
            h = L.rms_norm(x, lp["lnx"], c.norm_eps)
            x = x + L.attention(lp["xattn"], h, self.dims, kv_x=enc_out)
        h = L.rms_norm(x, lp["ln2"], c.norm_eps)
        aux = jnp.float32(0.0)
        if c.moe is not None:
            y, aux = moe_lib.moe_ffn(lp["ffn"], h, c.moe)
            x = x + y
        else:
            x = x + L.swiglu(lp["ffn"], h)
        return x, aux

    def _stack(self, blocks: dict, x: jax.Array, positions: jax.Array,
               enc_out: Optional[jax.Array], gather: Gather,
               flush_segments=None) -> tuple[jax.Array, jax.Array]:
        gather = gather or _identity_gather
        body = functools.partial(self._apply_block, positions=positions,
                                 enc_out=enc_out, gather=gather)
        if self.cfg.remat:
            body = jax.checkpoint(body)

        def step(carry, lp):
            x, aux = carry
            # sequence-parallel residual stream: the per-layer remat residual
            # (this carry) is saved S/tp-sharded instead of replicated —
            # activation memory drops by the TP width.
            x = constrain(x, None, TP_AXIS, None)
            x2, a = body(lp, x)
            return (x2, aux + a), None

        if flush_segments is None:
            (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)), blocks)
            return x, aux

        # bucketed backward overlap: the scan is split at bucket boundaries
        # and each segment's stacked params pass through a flush hook (a
        # custom_vjp identity whose backward syncs that bucket's gradients
        # cross-pod the moment its backward slice is produced — see
        # repro.core.overlap.flush_hook).  Forward math is identical to the
        # single scan: the segments traverse the same layers in order.
        bounds, hooks = flush_segments
        carry = (x, jnp.float32(0.0))
        for (lo, hi), hook in zip(bounds, hooks):
            seg = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, lo, hi, axis=0), blocks)
            seg = hook(seg)
            carry, _ = jax.lax.scan(step, carry, seg)
        x, aux = carry
        return x, aux

    def _apply_block(self, lp, x, *, positions, enc_out, gather):
        return self._block(gather(lp), x, positions, enc_out)

    def _embed_inputs(self, params: dict, batch: dict) -> tuple[jax.Array, jax.Array, int]:
        """Token (+stub modality) embedding. Returns (x, positions, n_prefix)."""
        c = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, None, None, None)
        n_prefix = 0
        if c.vision_tokens:
            patches = batch["patch_embeds"].astype(x.dtype)   # (B, n_vis, d)
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        S = x.shape[1]
        positions = jnp.arange(S)
        if not c.rope_theta:  # sinusoidal absolute positions (whisper)
            x = x + L.sinusoidal_positions(positions, c.d_model).astype(x.dtype)[None]
        return x, positions, n_prefix

    def _encode(self, params: dict, batch: dict, gather: Gather) -> Optional[jax.Array]:
        c = self.cfg
        if not c.encoder_layers:
            return None
        src = batch["source_frames"]                     # (B, src_len, d) stub
        pos = jnp.arange(src.shape[1])
        x = src + L.sinusoidal_positions(pos, c.d_model).astype(src.dtype)[None]
        enc_dims = self.dims._replace(causal=False, window=None)
        gather = gather or _identity_gather

        def body(lp, x):
            lp = gather(lp)
            h = L.rms_norm(x, lp["ln1"], c.norm_eps)
            x = x + L.attention(lp["attn"], h, enc_dims, positions=None)
            h = L.rms_norm(x, lp["ln2"], c.norm_eps)
            return x + L.swiglu(lp["ffn"], h)

        if c.remat:
            body = jax.checkpoint(body)

        def step(x, lp):
            return body(lp, x), None

        enc = params["encoder"]
        blocks = {k: enc[k] for k in ("attn", "ffn", "ln1", "ln2")}
        x, _ = jax.lax.scan(step, x, blocks)
        return L.rms_norm(x, enc["ln_f"], c.norm_eps)

    def hidden_states(self, params: dict, batch: dict, *, gather: Gather = None,
                      flush_segments=None) -> tuple[jax.Array, jax.Array, int]:
        """Full-sequence forward to final-norm hidden states.

        `flush_segments` = (layer bounds, per-bucket flush hooks) splits the
        layer scan at gradient-bucket boundaries for backward-side sync
        overlap (see :meth:`_stack`); None keeps the single fused scan."""
        enc_out = self._encode(params, batch, gather)
        x, positions, n_prefix = self._embed_inputs(params, batch)
        x, aux = self._stack(params["blocks"], x, positions, enc_out, gather,
                             flush_segments=flush_segments)
        x = L.rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        return x, aux, n_prefix

    def _head(self, params: dict) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def loss(self, params: dict, batch: dict, *, gather: Gather = None,
             flush_segments=None) -> tuple[jax.Array, dict]:
        """batch["tokens"]: (B, S+1) — teacher forcing; extra stub inputs as
        required by the family. Returns (mean_local_loss, metrics)."""
        c = self.cfg
        tokens = batch["tokens"]
        inputs = {**batch, "tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
        x, aux, n_prefix = self.hidden_states(params, inputs, gather=gather,
                                              flush_segments=flush_segments)
        if n_prefix:
            x = x[:, n_prefix:]
        sum_loss, count = L.chunked_ce_loss(x, self._head(params), labels)
        loss = sum_loss / jnp.maximum(count, 1.0)
        metrics = {"ce_loss": loss, "aux_loss": aux, "tokens": count}
        if c.moe is not None:
            loss = loss + 0.01 * aux / c.num_layers
        return loss, metrics

    def logits(self, params: dict, batch: dict, *, gather: Gather = None) -> jax.Array:
        x, _, n_prefix = self.hidden_states(params, batch, gather=gather)
        if n_prefix:
            x = x[:, n_prefix:]
        out = (x @ self._head(params)).astype(jnp.float32)
        return constrain(out, None, None, TP_AXIS)

    # ------------------------------------------------------------------
    # decode (serve_step)
    # ------------------------------------------------------------------

    def cache_width(self, max_len: int) -> int:
        c = self.cfg
        if c.sliding_window is not None:
            return min(max_len, c.sliding_window)
        return max_len

    def cache_defs(self, batch_size: int, max_len: int) -> dict:
        c = self.cfg
        Dh = c.resolved_head_dim
        W = self.cache_width(max_len)
        nL = c.num_layers
        kv = ("layers", "batch", "seq", "kv_heads", None)
        defs = {
            "k": PD((nL, batch_size, W, c.num_kv_heads, Dh), kv, init="zeros"),
            "v": PD((nL, batch_size, W, c.num_kv_heads, Dh), kv, init="zeros"),
        }
        if c.encoder_layers:
            src = c.source_len
            defs["xk"] = PD((nL, batch_size, src, c.num_kv_heads, Dh), kv, init="zeros")
            defs["xv"] = PD((nL, batch_size, src, c.num_kv_heads, Dh), kv, init="zeros")
        return defs

    def decode_step(self, params: dict, cache: dict, pos: jax.Array,
                    tokens: jax.Array, *, gather: Gather = None) -> tuple[jax.Array, dict]:
        """One-token decode. tokens: (B, 1); pos: scalar int32 (tokens already
        in cache), or a (B,) int32 vector when continuous batching has each
        slot at its own depth.  Returns (logits (B,1,V), updated cache)."""
        c = self.cfg
        gather = gather or _identity_gather
        x = jnp.take(params["embed"], tokens, axis=0)
        if not c.rope_theta:
            if getattr(pos, "ndim", 0) >= 1:
                x = x + L.sinusoidal_positions(pos, c.d_model).astype(x.dtype)[:, None, :]
            else:
                x = x + L.sinusoidal_positions(jnp.full((1,), pos), c.d_model).astype(x.dtype)[None]
        ring = c.sliding_window is not None
        has_cross = bool(c.encoder_layers)

        def step(x, inp):
            lp, kc, vc, xk, xv = inp
            lp = gather(lp)
            h = L.rms_norm(x, lp["ln1"], c.norm_eps)
            a, kc, vc = L.decode_attention(lp["attn"], h, self.dims,
                                           k_cache=kc, v_cache=vc, pos=pos, ring=ring)
            x = x + a
            if has_cross:
                h = L.rms_norm(x, lp["lnx"], c.norm_eps)
                x = x + self._cross_decode(lp["xattn"], h, xk, xv)
            h = L.rms_norm(x, lp["ln2"], c.norm_eps)
            if c.moe is not None:
                y, _ = moe_lib.moe_ffn(lp["ffn"], h, c.moe)
                x = x + y
            else:
                x = x + L.swiglu(lp["ffn"], h)
            return x, (kc, vc)

        xk = cache.get("xk", cache["k"])   # placeholder when no cross-attn
        xv = cache.get("xv", cache["v"])
        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["k"], cache["v"], xk, xv))
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        logits = (x @ self._head(params)).astype(jnp.float32)
        logits = constrain(logits, None, None, TP_AXIS)
        new_cache = dict(cache, k=k_new, v=v_new)
        return logits, new_cache

    def _cross_decode(self, p: dict, x: jax.Array, xk: jax.Array, xv: jax.Array) -> jax.Array:
        dims = self.dims
        B = x.shape[0]
        H, KH, Dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
        g = H // KH
        q = (x @ p["wq"]).reshape(B, 1, KH, g, Dh).astype(jnp.float32) * Dh ** -0.5
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, xk.astype(jnp.float32))
        p_attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p_attn, xv.astype(jnp.float32))
        o = o.reshape(B, 1, H * Dh).astype(x.dtype)
        return o @ p["wo"]

    def prefill(self, params: dict, batch: dict, *, gather: Gather = None
                ) -> tuple[jax.Array, dict]:
        """Run the full prompt, build the KV cache, return last-token logits."""
        c = self.cfg
        gather = gather or _identity_gather
        enc_out = self._encode(params, batch, gather)
        x, positions, n_prefix = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        W = self.cache_width(S)
        dims = self.dims

        def body(lp, x):
            lp = gather(lp)
            h = L.rms_norm(x, lp["ln1"], c.norm_eps)
            q, k, v = L._project_qkv(lp["attn"], h, dims, positions)
            attn_out = self._prefill_attn(q, k, v)
            x = x + attn_out.reshape(B, S, -1) @ lp["attn"]["wo"]
            if enc_out is not None:
                h = L.rms_norm(x, lp["lnx"], c.norm_eps)
                x = x + L.attention(lp["xattn"], h, dims, kv_x=enc_out)
                xk = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, c.num_kv_heads, dims.head_dim)
                xv = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, c.num_kv_heads, dims.head_dim)
            else:
                xk = xv = None
            h = L.rms_norm(x, lp["ln2"], c.norm_eps)
            if c.moe is not None:
                y, _ = moe_lib.moe_ffn(lp["ffn"], h, c.moe)
                x = x + y
            else:
                x = x + L.swiglu(lp["ffn"], h)
            kc, vc = self._to_ring(k, W, S), self._to_ring(v, W, S)
            ys = (kc, vc) if xk is None else (kc, vc, xk, xv)
            return x, ys

        x, ys = jax.lax.scan(lambda x, lp: body(lp, x), x, params["blocks"])
        x = L.rms_norm(x, params["ln_f"], c.norm_eps)
        last = x[:, -1:, :]
        logits = (last @ self._head(params)).astype(jnp.float32)
        if c.encoder_layers:
            cache = {"k": ys[0], "v": ys[1], "xk": ys[2], "xv": ys[3]}
        else:
            cache = {"k": ys[0], "v": ys[1]}
        return logits, cache

    def _prefill_attn(self, q, k, v):
        from repro.kernels import ops
        o = ops.flash_attention(q, k, v, causal=self.dims.causal,
                                window=self.dims.window)
        return constrain(o, None, None, TP_AXIS, None)

    def _to_ring(self, k: jax.Array, W: int, S: int) -> jax.Array:
        """Arrange the last W positions into ring-buffer slot order."""
        if W >= S:
            return k
        lastW = k[:, S - W:]
        return jnp.roll(lastW, shift=S % W, axis=1)
