"""Jit-friendly dispatch wrappers around the Pallas kernels.

On TPU the Pallas path runs; everywhere else (this container is CPU-only) a
memory-efficient pure-jnp implementation lowers instead, so the dry-run HLO
has bounded working sets (the kv-block-scan below is the jnp mirror of the
flash kernel's online softmax).  `impl=` overrides for tests:
"pallas_interpret" executes the actual kernel body in Python on CPU.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import quant as _q
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.sharding import TP_AXIS, constrain


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def attn_shard_mode(B: int, KH: int = 0) -> Optional[str]:
    """How to shard attention internals over the TP axis (beyond-paper).

    Without constraints GSPMD replicates the (B,KH,g,Sq,bk) score tensors
    whenever head counts don't divide the TP axis — TBs of all-gather per
    step on 40/24-head archs.  Preference order:

      "heads"  KV heads divisible by TP: classic head parallelism — zero
               collective bytes in both directions (MHA archs).
      "batch"  local batch divisible by TP: embarrassingly parallel too.
      "seq"    fallback: shard the query-sequence dim (always divisible);
               k/v stay replicated, costing dK/dV partial-sum all-reduces
               in backward (measured in §Perf P1/P2).

    REPRO_ATTN_SP=0 restores the unconstrained baseline for comparison.
    """
    if os.environ.get("REPRO_ATTN_SP", "1") != "1":
        return None
    from repro.sharding import axis_size
    tp = axis_size(TP_AXIS)
    if tp <= 1:
        return None
    if KH and KH % tp == 0:
        return "heads"
    return "batch" if (B % tp == 0 and B >= tp) else "seq"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attention_kvscan(q, k, v, *, causal, window, scale, block_k=None):
    """Online-softmax scan over KV blocks: O(Sq*D) live memory, GQA-aware.

    q: (B,Sq,H,D); k,v: (B,Sk,KH,D).
    """
    if block_k is None:
        block_k = int(os.environ.get("REPRO_ATTN_BK", "1024"))  # memory knob
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    bk = min(block_k, Sk)
    pk = (-Sk) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nb = (Sk + pk) // bk
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KH, g, D)
    mode = attn_shard_mode(B, KH)
    if mode == "batch":
        qf = constrain(qf, TP_AXIS, None, None, None, None)
    elif mode == "seq":
        qf = constrain(qf, None, TP_AXIS, None, None, None)
    elif mode == "heads":
        qf = constrain(qf, None, None, TP_AXIS, None, None)
    ks = jnp.moveaxis(k.reshape(B, nb, bk, KH, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nb, bk, KH, D), 1, 0)
    if mode == "batch":
        ks = constrain(ks, None, TP_AXIS, None, None, None)
        vs = constrain(vs, None, TP_AXIS, None, None, None)
    elif mode == "heads":
        ks = constrain(ks, None, None, None, TP_AXIS, None)
        vs = constrain(vs, None, None, None, TP_AXIS, None)
    qpos = jnp.arange(Sq) + (Sk - Sq)

    def step(carry, inp):
        acc, m, l = carry
        kb, vb, j0 = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kb.astype(jnp.float32))
        if mode == "batch":
            s = constrain(s, TP_AXIS, None, None, None, None)
        elif mode == "seq":
            s = constrain(s, None, None, None, TP_AXIS, None)
        elif mode == "heads":
            s = constrain(s, None, TP_AXIS, None, None, None)
        kpos = j0 + jnp.arange(bk)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        # guard rows that are still fully masked (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(jnp.isfinite(s), s - m_safe, -jnp.inf))
        p = jnp.where(jnp.isnan(p), 0.0, p)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l2 = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc2 = acc * alpha + jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
        return (acc2, m_new, l2), None

    acc0 = jnp.zeros((B, KH, g, Sq, D), jnp.float32)
    m0 = jnp.full((B, KH, g, Sq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KH, g, Sq, 1), jnp.float32)
    if mode == "batch":
        acc0 = constrain(acc0, TP_AXIS, None, None, None, None)
        m0 = constrain(m0, TP_AXIS, None, None, None, None)
        l0 = constrain(l0, TP_AXIS, None, None, None, None)
    elif mode == "seq":
        acc0 = constrain(acc0, None, None, None, TP_AXIS, None)
        m0 = constrain(m0, None, None, None, TP_AXIS, None)
        l0 = constrain(l0, None, None, None, TP_AXIS, None)
    elif mode == "heads":
        acc0 = constrain(acc0, None, TP_AXIS, None, None, None)
        m0 = constrain(m0, None, TP_AXIS, None, None, None)
        l0 = constrain(l0, None, TP_AXIS, None, None, None)
    (acc, _, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (ks, vs, jnp.arange(nb) * bk))
    l = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l).astype(q.dtype)                        # (B,KH,g,Sq,D)
    o = jnp.moveaxis(o.reshape(B, H, Sq, D), 1, 2)       # (B,Sq,H,D)
    if mode == "batch":
        o = constrain(o, TP_AXIS, None, None, None)
    elif mode == "seq":
        o = constrain(o, None, TP_AXIS, None, None)
    elif mode == "heads":
        o = constrain(o, None, None, TP_AXIS, None)
    return o


def _attention_causal_blocked(q, k, v, *, causal, window, scale, block_q=None,
                              block_k=None):
    """Beyond-baseline CPU/HLO impl: unrolled lower-triangular q-blocks.

    Each q block attends only to k[: (i+1)*bq] (static slice), so compiled
    HLO FLOPs follow the causal triangle (~2x fewer than the rectangle the
    kv-scan computes).  Falls back to kvscan when non-causal.
    """
    if block_q is None:
        block_q = int(os.environ.get("REPRO_ATTN_BQ", "2048"))
    if block_k is None:
        block_k = int(os.environ.get("REPRO_ATTN_BK", "1024"))
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    if not causal or Sq != Sk or Sq % block_q:
        return _attention_kvscan(q, k, v, causal=causal, window=window,
                                 scale=scale, block_k=block_k)
    outs = []
    for i in range(Sq // block_q):
        lo, hi = i * block_q, (i + 1) * block_q
        klo = 0 if window is None else max(0, lo - (window - 1))
        klo = (klo // block_k) * block_k
        outs.append(_attention_kvscan(
            q[:, lo:hi], k[:, klo:hi], v[:, klo:hi],
            causal=True, window=window, scale=scale, block_k=block_k))
    return jnp.concatenate(outs, axis=1)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    impl: str = "auto",
                    block_q: int = 512,
                    block_k: int = 512) -> jax.Array:
    """Batched multi-head attention. q: (B,Sq,H,D); k,v: (B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    if H % KH:
        raise ValueError(f"attention: q heads {H} must be a multiple of "
                         f"kv heads {KH} (GQA group size)")
    g = H // KH
    if scale is None:
        scale = D ** -0.5
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "causal_blocked"
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "kvscan":
        return _attention_kvscan(q, k, v, causal=causal, window=window, scale=scale,
                                 block_k=block_k)
    if impl == "causal_blocked":
        return _attention_causal_blocked(q, k, v, causal=causal, window=window,
                                         scale=scale)
    if impl in ("pallas", "pallas_interpret"):
        q3 = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
        k3 = k.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
        v3 = v.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
        o3 = _fa.flash_attention_bhsd(
            q3, k3, v3, group=g, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k,
            interpret=(impl == "pallas_interpret"))
        return o3.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            impl: str = "auto") -> jax.Array:
    """x: (..., d); w: (d,)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.rmsnorm_ref(x, w, eps)
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    y = _rn.rmsnorm_rows(x2, w, eps=eps, interpret=(impl == "pallas_interpret"))
    return y.reshape(*lead, d)


# ---------------------------------------------------------------------------
# int8 blockwise quantization (cross-pod compression)
# ---------------------------------------------------------------------------

def quant_int8(x: jax.Array, *, block: int = 256, impl: str = "auto"):
    """x: (..., n) with n % block == 0 -> (int8, f32 scales (..., n/block)).

    Raises ValueError (not a bare assert) on a ragged trailing dim: callers
    must pad to the block size first (compress.quant_chunk does), and layer-
    bucketed slicing makes ragged trailing dims easy to hit by accident.
    """
    n_last = x.shape[-1] if x.ndim else 0
    if x.ndim == 0 or n_last % block != 0:
        raise ValueError(
            f"quant_int8: leaf of shape {tuple(x.shape)} has trailing dim "
            f"{n_last}, not divisible by block={block}; pad the trailing "
            f"dim to a multiple of the quantization block (see "
            f"repro.core.compress.quant_chunk)")
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.quant_int8_ref(x, block)
    lead = x.shape[:-1]
    n = x.shape[-1]
    q, s = _q.quant_int8_2d(x.reshape(-1, n), block=block,
                            interpret=(impl == "pallas_interpret"))
    return q.reshape(*lead, n), s.reshape(*lead, n // block)


def dequant_int8(q: jax.Array, s: jax.Array, *, block: int = 256,
                 dtype=jnp.float32, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.dequant_int8_ref(q, s, block, dtype)
    lead = q.shape[:-1]
    n = q.shape[-1]
    x = _q.dequant_int8_2d(q.reshape(-1, n), s.reshape(-1, n // block),
                           block=block, dtype=dtype,
                           interpret=(impl == "pallas_interpret"))
    return x.reshape(*lead, n)
