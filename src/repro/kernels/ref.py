"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references: naive, materializing, obviously-right
implementations.  Kernel tests sweep shapes/dtypes and assert_allclose
against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """Naive attention. q: (B,Sq,H,D); k,v: (B,Sk,KH,D) with H % KH == 0.

    `window` is a sliding-attention width (queries attend to the last
    `window` keys, inclusive of self). Causal offset assumes Sq == Sk or a
    pure-decode Sq==1 suffix.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, KH, g, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)          # (B,KH,g,Sq,Sk)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)           # align ends
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def quant_int8_ref(x: jax.Array, block: int = 256):
    """Blockwise absmax int8 quantization along the last dim.

    Returns (q: int8 same shape, scales: float32 shape[..., n/block]).
    """
    *lead, n = x.shape
    if n % block:
        raise ValueError(f"quant_int8_ref: last dim {n} must be a multiple "
                         f"of block {block}")
    xb = x.astype(jnp.float32).reshape(*lead, n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, n), scale.squeeze(-1)


def dequant_int8_ref(q: jax.Array, scales: jax.Array, block: int = 256,
                     dtype=jnp.float32) -> jax.Array:
    *lead, n = q.shape
    qb = q.astype(jnp.float32).reshape(*lead, n // block, block)
    x = qb * scales[..., None]
    return x.reshape(*lead, n).astype(dtype)
