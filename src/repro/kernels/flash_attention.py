"""Flash attention Pallas TPU kernel (causal + sliding-window, GQA-aware).

Layout: q (B, H, Sq, D), k/v (B, KH, Sk, D).  Grid (B*H, Sq/bq, Sk/bk) with
the k-block dimension innermost; online-softmax running stats live in VMEM
scratch across k-blocks.  Block sizes are MXU-aligned (multiples of 128 on
the sequence dims; D is the lane dim and is padded by Mosaic if needed).

VMEM working set per program ≈ (bq + 2*bk) * D * 2B + bq*bk*4B + bq*D*4B —
with bq=bk=512, D=128 that is ~1.7 MiB, comfortably inside the ~16 MiB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)                     # (bk, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(kj == nk - 1)
    def _fin():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         group: int = 1,
                         causal: bool = True,
                         window: Optional[int] = None,
                         scale: Optional[float] = None,
                         block_q: int = 512,
                         block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B*H, Sq, D), k/v: (B*KH, Sk, D) with H == KH*group.

    GQA is handled index-map-side: q program `b` reads k/v row `b // group`
    (standard head order h -> h // group), so k/v are never materialized
    per-q-head.
    """
    BH, Sq, D = q.shape
    BKH, Sk, _ = k.shape
    if BH != BKH * group:
        raise ValueError(f"flash attention: q heads {BH} != kv heads {BKH} "
                         f"* group {group}")
    if scale is None:
        scale = D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad sequence dims to block multiples (masked out by kpos < seq_k)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    grid = (BH, (Sq + pq) // bq, (Sk + pk) // bk)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_q=Sq, seq_k=Sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
