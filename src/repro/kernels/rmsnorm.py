"""Fused RMSNorm Pallas kernel — bandwidth-bound row kernel.

Grid over row tiles; each program normalizes (block_rows, d) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                 block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (R, d); w: (d,)."""
    R, d = x.shape
    br = min(block_rows, R)
    pr = (-R) % br
    if pr:
        x = jnp.pad(x, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((R + pr) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pr, d), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:R]
