"""Blockwise int8 quant/dequant Pallas kernels.

Used by the cross-pod compression stage (core/compress.py): gradients are
quantized to int8 with per-`block`-lane float32 scales before traversing the
inter-pod ("WAN") link, cutting link bytes ~3.8x.  Bandwidth-bound; tiles are
(rows, block) VMEM panels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (rows, block)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(o_ref.dtype)


def quant_int8_2d(x: jax.Array, *, block: int = 256, rows: int = 256,
                  interpret: bool = False):
    """x: (R, n) with n % block == 0 -> (int8 (R,n), f32 scales (R, n/block))."""
    R, n = x.shape
    if n % block:
        raise ValueError(f"quant_int8_2d: last dim {n} must be a multiple "
                         f"of block {block}")
    nb = n // block
    br = min(rows, R)
    pr = (-R) % br
    if pr:
        x = jnp.pad(x, ((0, pr), (0, 0)))
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=((R + pr) // br, nb),
        in_specs=[pl.BlockSpec((br, block), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((br, block), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R + pr, n), jnp.int8),
            jax.ShapeDtypeStruct((R + pr, nb), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:R], s[:R]


def dequant_int8_2d(q: jax.Array, s: jax.Array, *, block: int = 256,
                    rows: int = 256, dtype=jnp.float32,
                    interpret: bool = False) -> jax.Array:
    R, n = q.shape
    nb = n // block
    br = min(rows, R)
    pr = (-R) % br
    if pr:
        q = jnp.pad(q, ((0, pr), (0, 0)))
        s = jnp.pad(s, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        _dequant_kernel,
        grid=((R + pr) // br, nb),
        in_specs=[
            pl.BlockSpec((br, block), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R + pr, n), dtype),
        interpret=interpret,
    )(q, s)
    return out[:R]
