"""Point-to-point and relay primitives over the pod ring (MPW_Send/Recv
between endpoints, MPW_Cycle, MPW_Relay), plus the multi-hop Forwarder data
plane (`forward`).

Pods form a ring over the "pod" mesh axis; sends are collective_permute
(ppermute) shifts.  Inside the manual-DP shard_map these are the explicit
cross-pod messages of the paper — used by the coupled-application example
(the bloodflow scenario) and by the relay benchmarks.  A multi-hop
:class:`~repro.core.path.WidePath` (a Forwarder route) executes as one
store-and-forward `pod_shift` per hop, each with that hop's own chunking and
stream knobs and its own telemetry slot.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import streams as st
from repro.core import telemetry as tel
from repro.core.path import WidePath
from repro.sharding import manual_axes_present


def _ring_perm(n: int, shift: int) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def pod_shift(tree, path: WidePath, shift: int = 1, dims=None,
              chunk_bytes: Optional[int] = None,
              streams: Optional[int] = None,
              tel_key: Optional[str] = None, pacing: Optional[float] = None):
    """Send the payload to the pod `shift` positions ahead on the ring,
    receive from the one behind (chunked over the path's streams).

    `dims` carries each leaf's scatter dim (the dim that is *not* TP-sharded
    — streams.py's chunking contract), exactly as `streamed_psum` takes it;
    leaves without a stated dim fall back to dim 0, which is only correct
    for unsharded/replicated leaves.  Multi-hop paths relay hop by hop
    (store-and-forward); `shift` then scales the whole route.
    """
    if path.axis not in manual_axes_present(path.axis):
        return tree
    if path.hops:
        out = tree
        for _ in range(max(1, abs(int(shift)))):
            out = forward(out, path, dims=dims, reverse=shift < 0)
        return out
    n = jax.lax.axis_size(path.axis)
    perm = _ring_perm(n, shift)

    leaves, treedef = jax.tree.flatten(tree)
    dim_list = st.normalize_dims(leaves, dims)
    cb = chunk_bytes if chunk_bytes is not None else path.chunk_bytes
    ns = streams if streams is not None else path.streams
    pc = pacing if pacing is not None else path.comm.pacing
    chunks = st.plan_chunks(leaves, dim_list, cb)
    buckets = st.assign_streams(chunks, ns)
    tel.note_plan(tel_key or path.key,
                  **st.plan_summary(chunks, buckets, ns, cb, pc,
                                    algo="shift", world=n))
    done: dict[int, list] = {i: [] for i in range(len(leaves))}
    for bucket in buckets:
        dep = jnp.zeros((), jnp.float32)
        for c in bucket:
            x = st.slice_chunk(leaves[c.leaf], c)
            x, _ = jax.lax.optimization_barrier((x, dep))
            r = jax.lax.ppermute(x, path.axis, perm)
            done[c.leaf].append((c, r))
            dep = r.reshape(-1)[0].astype(jnp.float32) if r.ndim else r.astype(jnp.float32)
    out = [st.stitch_leaf(l, done[i]) if done[i] else l
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def forward(tree, path: WidePath, dims=None, reverse: bool = False):
    """Store-and-forward relay along `path.route` (the Forwarder data plane).

    Each hop is an independent chunked transfer with the hop's own knobs: the
    relay site holds the full message between hops, exactly as the paper's
    Forwarder process does with its receive/send buffer pair.  Per-hop
    traffic plans land in per-hop telemetry slots (`path.hop_key(i)`).
    `reverse` runs the route back to front with negated shifts (the return
    direction of a bidirectional route).
    """
    if path.axis not in manual_axes_present(path.axis):
        return tree
    route = path.route
    order = range(len(route) - 1, -1, -1) if reverse else range(len(route))
    out = tree
    for i in order:
        hop = route[i]
        out = pod_shift(out, path.with_(hops=()), -hop.shift if reverse else hop.shift,
                        dims=dims, chunk_bytes=hop.chunk_bytes,
                        streams=hop.streams, pacing=hop.comm.pacing,
                        tel_key=path.hop_key(i))
    return out


def sendrecv(send_tree, path: WidePath, shift: int = 1, dims=None):
    """MPW_SendRecv: symmetric exchange with the ring neighbour.

    Returns the payload received from the pod `shift` behind.
    """
    return pod_shift(send_tree, path, shift, dims=dims)


def cycle(recv_from_path: WidePath, send_on_path: WidePath, tree, dims=None):
    """MPW_Cycle: receive a buffer over one path, forward it over another.

    On a pod ring this composes two shifts: data arrives from the previous
    pod on path A and continues to the next pod on path B — the building
    block of sustained relays across >2 machines (the paper's 3- and
    4-supercomputer runs).
    """
    received = pod_shift(tree, recv_from_path, 1, dims=dims)
    return pod_shift(received, send_on_path, 1, dims=dims)


def relay(tree, path: WidePath, hops: int, dims=None):
    """MPW_Relay: sustained forwarding for `hops` ring steps.  A multi-hop
    path relays along its own route instead (its hop count governs)."""
    if path.hops:
        return forward(tree, path, dims=dims)
    out = tree
    for _ in range(max(1, hops)):
        out = pod_shift(out, path, 1, dims=dims)
    return out


def barrier(axes: Sequence[str] = ("pod", "data")) -> jax.Array:
    """MPW_Barrier: synchronize across the wide area (scalar psum)."""
    axes = manual_axes_present(*axes)
    tok = jnp.ones((), jnp.float32)
    if axes:
        tok = jax.lax.psum(tok, axes)
    return tok
