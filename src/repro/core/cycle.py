"""Point-to-point and relay primitives over the pod ring (MPW_Send/Recv
between endpoints, MPW_Cycle, MPW_Relay).

Pods form a ring over the "pod" mesh axis; sends are collective_permute
(ppermute) shifts.  Inside the manual-DP shard_map these are the explicit
cross-pod messages of the paper — used by the coupled-application example
(the bloodflow scenario) and by the relay benchmarks.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import streams as st
from repro.core import telemetry as tel
from repro.core.path import WidePath
from repro.sharding import manual_axes_present


def _ring_perm(n: int, shift: int) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def pod_shift(tree, path: WidePath, shift: int = 1):
    """Send the payload to the pod `shift` positions ahead on the ring,
    receive from the one behind (chunked over the path's streams)."""
    if path.axis not in manual_axes_present(path.axis):
        return tree
    n = jax.lax.axis_size(path.axis)
    perm = _ring_perm(n, shift)

    leaves, treedef = jax.tree.flatten(tree)
    dims = [0 if l.ndim else None for l in leaves]
    chunks = st.plan_chunks(leaves, dims, path.chunk_bytes)
    buckets = st.assign_streams(chunks, path.streams)
    tel.note_plan(path.key, **st.plan_summary(
        chunks, buckets, path.streams, path.chunk_bytes, path.comm.pacing))
    done: dict[int, list] = {i: [] for i in range(len(leaves))}
    for bucket in buckets:
        dep = jnp.zeros((), jnp.float32)
        for c in bucket:
            x = st.slice_chunk(leaves[c.leaf], c)
            x, _ = jax.lax.optimization_barrier((x, dep))
            r = jax.lax.ppermute(x, path.axis, perm)
            done[c.leaf].append((c, r))
            dep = r.reshape(-1)[0].astype(jnp.float32) if r.ndim else r.astype(jnp.float32)
    out = [st.stitch_leaf(l, done[i]) if done[i] else l
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def sendrecv(send_tree, path: WidePath, shift: int = 1):
    """MPW_SendRecv: symmetric exchange with the ring neighbour.

    Returns the payload received from the pod `shift` behind.
    """
    return pod_shift(send_tree, path, shift)


def cycle(recv_from_path: WidePath, send_on_path: WidePath, tree):
    """MPW_Cycle: receive a buffer over one path, forward it over another.

    On a pod ring this composes two shifts: data arrives from the previous
    pod on path A and continues to the next pod on path B — the building
    block of sustained relays across >2 machines (the paper's 3- and
    4-supercomputer runs).
    """
    received = pod_shift(tree, recv_from_path, 1)
    return pod_shift(received, send_on_path, 1)


def relay(tree, path: WidePath, hops: int):
    """MPW_Relay: sustained forwarding for `hops` ring steps."""
    out = tree
    for _ in range(max(1, hops)):
        out = pod_shift(out, path, 1)
    return out


def barrier(axes: Sequence[str] = ("pod", "data")) -> jax.Array:
    """MPW_Barrier: synchronize across the wide area (scalar psum)."""
    axes = manual_axes_present(*axes)
    tok = jnp.ones((), jnp.float32)
    if axes:
        tok = jax.lax.psum(tok, axes)
    return tok
