"""Continuous-batching serving scheduler over WidePaths (deterministic).

The paper's third claim is "very fast connections in client-server
environments"; this module is the client-server tier's brain.  A
:class:`ContinuousBatcher` owns a fixed set of *decode slots* and fills free
slots from an admission-controlled request queue every step — instead of
running fixed batches to completion — while each admitted request walks the
disaggregated pipeline::

    queued -> prefill (site A) -> ship (KV over the WidePath) -> decode
           (site B) -> done

All time is a virtual step clock (one decode token per step per slot); WAN
legs take :func:`modeled_ship_steps` derived from the deterministic
alpha-beta link model (`repro.core.autotune.simulate_transfer_s`) — no wall
clock anywhere, so a given arrival trace replays bit-identically (the golden
schedule test in tests/test_serving.py pins one).  The runtime engine
(`repro.runtime.serving.ServingEngine`) drives the same bookkeeping with
*real* prefill/ship/decode work instead of modeled durations.

Fault tolerance (tests/test_serve_chaos.py pins the golden timeline):

* **Deadlines** — a request carries ``deadline_steps``; the per-step sweep
  moves anything older to the terminal ``TIMEOUT`` state, freeing whatever
  stage resource it held.
* **SLO-aware admission** — `submit` *sheds* (terminal ``SHED``) when the
  modeled queue + prefill + ship + decode delay under current link health
  already blows the deadline, so a degraded WAN degrades goodput gracefully
  instead of building an unbounded queue.
* **Fault-aware shipping** — a :class:`FaultAwareShipper` walks the
  topology route under the `LinkProfile` fault schedules: a failed hop
  retries through a seeded :class:`~repro.core.retry.RetryPolicy`
  (``reship``), reroutes over surviving links after ``max_reships``
  (``reroute``, mirroring PR-6's ``healing_transfer``), and recovers to the
  primary route once it heals.
* **Serve failover** — on a `SiteMembership` eviction of the prefill or
  decode site, in-flight requests drain back to QUEUED and the role moves
  to a surviving member (``serve_failover``); with no surviving pair the
  batcher collocates both roles and flags itself ``degraded`` in
  :meth:`ContinuousBatcher.stats`.

Every transition lands in the PR-6 :class:`~repro.core.chaos.IncidentLog`
(kinds ``timeout``/``shed``/``reship``/``reroute``/``serve_failover``/
``degrade``) and therefore in ``MPW.Report``.

Thread-safety: `submit` may be called from a frontend thread while a driver
thread steps the clock, so every state transition runs under the instance
lock (mpwlint R2; an RLock — helpers re-enter it so their writes stay
lexically under a ``with`` block).
"""
from __future__ import annotations

import inspect
import math
import threading
from dataclasses import dataclass
from typing import Callable, Optional, Union

import numpy as np

from repro.core import telemetry as tel
from repro.core.autotune import simulate_hop_s, simulate_transfer_s
from repro.core.path import WidePath
from repro.core.retry import KVSHIP_RETRY, RetryPolicy

# request lifecycle states
QUEUED = "queued"        # admitted, waiting for a free decode slot
PREFILL = "prefill"      # slot claimed; waiting for / running site-A prefill
SHIP = "ship"            # KV cache in flight over the WidePath
DECODE = "decode"        # occupying a decode slot on site B
DONE = "done"
REJECTED = "rejected"    # admission: queue full
TIMEOUT = "timeout"      # blew its deadline_steps mid-flight
SHED = "shed"            # admission: modeled completion blows the deadline

_TERMINAL = (DONE, REJECTED, TIMEOUT, SHED)

# an unroutable ship models as "longer than any deadline" — the admission
# path sheds against it and the deadline sweep times out anything in flight
_UNROUTABLE_STEPS = 1 << 30
# safety cap on fault responses within one modeled ship: a schedule that
# keeps cutting every attempt ends the ship as failed instead of spinning
_MAX_SHIP_FAULTS = 64


@dataclass(frozen=True)
class Request:
    """One serving request (arrival is a virtual step index).

    ``deadline_steps`` is the SLO: the request must be DONE strictly fewer
    than that many steps after arrival, or the sweep times it out (None —
    no deadline)."""
    rid: int
    arrival: int
    prompt_len: int
    max_new: int
    deadline_steps: Optional[int] = None


@dataclass
class _Track:
    """Mutable per-request bookkeeping (timestamps are virtual steps)."""
    req: Request
    state: str = QUEUED
    slot: Optional[int] = None
    tokens: int = 0                  # generated so far (first from prefill)
    t_prefill: Optional[int] = None  # prefill started
    t_ship: Optional[int] = None     # prefill done / ship started
    t_ship_end: Optional[int] = None
    t_decode: Optional[int] = None   # decode started == first token
    t_done: Optional[int] = None
    reships: int = 0                 # ship retries this request needed
    reroutes: int = 0                # route replans this request needed


def _wants_step(fn: Callable) -> bool:
    """Duration callables may take (req) or (req, step); the two-argument
    form gets the virtual step clock threaded through, so a modeled
    duration can consult the fault schedules."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    n = 0
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind == p.VAR_POSITIONAL:
            return True
    return n >= 2


def modeled_ship_steps(kv_bytes: int, path: Optional[WidePath] = None,
                       step_s: float = 1e-2, *,
                       step: Optional[int] = None,
                       route=None, timeout_s: float = 30.0) -> int:
    """Virtual steps one request's KV cache spends on the wire.

    Sums the deterministic per-hop transfer model over the path's route
    (store-and-forward, like `Forward`), then quantizes to the decode step
    clock.  0 bytes ship for free (the monolithic baseline).

    With ``route`` (a :class:`~repro.core.topology.Route`, whose hops carry
    `LinkProfile` fault schedules) and ``step``, the *fault clock* applies:
    a hop dead at `step` models as a transfer that hangs for ``timeout_s``
    (the watchdog), a degraded hop as proportionally less capacity — this
    is the naive "wait the fault out" model; :class:`FaultAwareShipper`
    layers retries and reroutes on top of it."""
    if kv_bytes <= 0:
        return 0
    if step_s <= 0:
        raise ValueError(f"step_s must be > 0 to quantize ship time, "
                         f"got {step_s}")
    total = 0.0
    if route is not None:
        at = 0 if step is None else int(step)
        for prof in route.profiles:
            total += simulate_hop_s(kv_bytes, prof, at, timeout_s=timeout_s)
        return max(1, int(math.ceil(total / step_s)))
    if path is None:
        raise ValueError(f"modeled_ship_steps needs a WidePath or a topology "
                         f"route, got path={path!r} route={route!r}")
    for hop in path.route:
        total += simulate_transfer_s(
            kv_bytes, hop.link, streams=hop.streams,
            chunk_bytes=hop.chunk_bytes, pacing=hop.comm.pacing)
    return max(1, int(math.ceil(total / step_s)))


def _percentile(xs: list, q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclass(frozen=True)
class ShipOutcome:
    """Result of one modeled fault-aware KV ship.

    ``events`` holds (kind, step, subject, detail) incident rows in
    occurrence order — the shipper replays them into the attached
    :class:`~repro.core.chaos.IncidentLog`."""
    ok: bool
    steps: int
    modeled_s: float
    reships: int = 0
    reroutes: int = 0
    route: tuple = ()
    events: tuple = ()


class FaultAwareShipper:
    """Models KV-cache shipping src -> dst under the topology's fault
    schedules, with seeded retries and reroutes (deterministic, replayable).

    A ship walks the current route store-and-forward on the virtual step
    clock.  A hop that is dead at its start — or cut by a ``drop`` fault
    while the transfer is still on the wire — costs the elapsed progress
    plus the ``timeout_s`` watchdog, then retries after a seeded
    `RetryPolicy` backoff (``reship``); after ``max_reships`` failures on
    one hop the route replans from the stranded site over surviving links
    (``reroute``, mirroring PR-6's ``healing_transfer``).  When no route
    survives the ship reports ``ok=False`` and the batcher degrades to
    collocated serving.  Once every primary hop is healthy again
    :meth:`on_step` falls back to the primary route and logs ``recover``.

    Per-request modeled seconds/bytes land in telemetry under
    ``serve/req{rid}/kv``; reship/reroute counts via
    `telemetry.note_ship_retry`.
    """

    def __init__(self, topo, src: str, dst: str, *,
                 kv_bytes: Union[int, Callable[[Request], int]],
                 step_s: float = 1e-2, metric: str = "latency",
                 retry: Optional[RetryPolicy] = None, max_reships: int = 2,
                 timeout_s: float = 0.5, log=None, seed: int = 0,
                 name: str = "serve"):
        if step_s <= 0:
            raise ValueError(f"step_s must be > 0, got {step_s}")
        if max_reships < 0:
            raise ValueError(f"max_reships must be >= 0, got {max_reships}")
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        self.topo = topo
        self.src, self.dst = src, dst
        self.metric = metric
        self.step_s = float(step_s)
        self.timeout_s = float(timeout_s)
        self.max_reships = int(max_reships)
        self.retry = KVSHIP_RETRY if retry is None else retry
        self.log = log
        self.seed = int(seed)
        self.name = name
        self._kv_bytes = kv_bytes
        self._lock = threading.RLock()
        primary = topo.route(src, dst, metric)
        self._primary = (primary.sites, primary.profiles)
        self._names = primary.sites
        self._profiles = primary.profiles
        self._avoid: set = set()
        self._detour_step: Optional[int] = None
        self._last_inject: Optional[int] = None
        self._injected: set = set()
        self.reships = 0
        self.reroutes = 0

    # -- route state ---------------------------------------------------------
    @property
    def route_names(self) -> tuple:
        """Site names of the route the next ship will attempt."""
        with self._lock:
            return tuple(self._names)

    @property
    def detoured(self) -> bool:
        with self._lock:
            return self._detour_step is not None

    def can_route(self, src: str, dst: str,
                  avoid: frozenset = frozenset()) -> bool:
        """True when the topology still offers a src -> dst route."""
        try:
            self.topo.route(src, dst, self.metric, avoid=frozenset(avoid))
        except (KeyError, ValueError):
            return False
        return True

    def retarget(self, src: Optional[str] = None, dst: Optional[str] = None,
                 avoid: frozenset = frozenset()) -> bool:
        """Re-plan the primary route (serve failover moved an endpoint).
        Returns False — state untouched — when no route survives."""
        with self._lock:
            nsrc = self.src if src is None else src
            ndst = self.dst if dst is None else dst
            try:
                r = self.topo.route(nsrc, ndst, self.metric,
                                    avoid=frozenset(avoid))
            except (KeyError, ValueError):
                return False
            self.src, self.dst = nsrc, ndst
            self._primary = (r.sites, r.profiles)
            self._names, self._profiles = r.sites, r.profiles
            self._avoid = set(avoid)
            self._detour_step = None
            return True

    # -- fault bookkeeping ---------------------------------------------------
    def _note_injections(self, step: int) -> None:
        """Log `inject` once per fault the first time it is seen active on
        the primary or current route (mirrors ChaosMonitor)."""
        with self._lock:
            profs = {}
            for prof in tuple(self._primary[1]) + tuple(self._profiles):
                profs[prof.name] = prof
            for prof in profs.values():
                for f in prof.faults:
                    fkey = (prof.name, f.kind, f.start, f.stop)
                    if fkey in self._injected or not f.active(step):
                        continue
                    self._injected.add(fkey)
                    self._last_inject = int(step)
                    if self.log is not None:
                        self.log.add(step, "inject", prof.name,
                                     {"kind": f.kind, "start": f.start,
                                      "stop": f.stop, "factor": f.factor,
                                      "error_rate": f.error_rate})

    def on_step(self, step: int) -> None:
        """Per-step housekeeping: log newly-active faults; once every
        primary hop is healthy again, abandon the detour and log
        ``recover`` (latency measured from the fault injection)."""
        with self._lock:
            self._note_injections(step)
            if self._detour_step is None or self._last_inject is None:
                # a detour taken against a not-yet-active fault (the ship
                # simulated into the fault window) holds until the fault is
                # actually observed — reverting early would just re-detour
                return
            names, profiles = self._primary
            if not all(p.health(step).alive for p in profiles):
                return
            self._names, self._profiles = names, profiles
            self._avoid.clear()
            detour_at = self._detour_step
            self._detour_step = None
            since = detour_at if self._last_inject is None \
                else self._last_inject
            if self.log is not None:
                self.log.add(step, "recover", f"{self.src}->{self.dst}",
                             {"mode": "reroute",
                              "latency_steps": int(step - since)})

    # -- shipping ------------------------------------------------------------
    def _nbytes(self, req: Request) -> int:
        n = (self._kv_bytes(req) if callable(self._kv_bytes)
             else int(self._kv_bytes))
        if n < 0:
            raise ValueError(f"kv_bytes must be >= 0, got {n} "
                             f"for req{req.rid}")
        return n

    def estimate_steps(self, req: Request, step: int) -> int:
        """Modeled ship steps under the fault schedules at `step` — the
        admission model's view.  Never logs or mutates; an unroutable ship
        reports a deadline-blowing duration."""
        with self._lock:
            out = self._simulate(self._nbytes(req), int(step), req.rid)
        return out.steps if out.ok else _UNROUTABLE_STEPS

    def ship(self, req: Request, step: int) -> ShipOutcome:
        """Run the modeled ship at `step`: log reship/reroute incidents,
        commit a route change, record ``serve/req{rid}/kv`` telemetry."""
        with self._lock:
            self._note_injections(step)
            nbytes = self._nbytes(req)
            out = self._simulate(nbytes, int(step), req.rid)
            if self.log is not None:
                for kind, at, subject, detail in out.events:
                    self.log.add(at, kind, subject, detail)
            if out.ok:
                if out.reroutes:
                    self._commit_route(out.route, int(step))
                self.reships += out.reships
                self.reroutes += out.reroutes
                tel.record(f"serve/req{req.rid}/kv", out.modeled_s,
                           nbytes=nbytes, step=step)
                if out.reships or out.reroutes:
                    tel.note_ship_retry(f"serve/req{req.rid}/kv",
                                        reships=out.reships,
                                        reroutes=out.reroutes)
            return out

    def _commit_route(self, names: tuple, step: int) -> None:
        """Adopt a rerouted path for subsequent ships (until recovery)."""
        with self._lock:
            profiles = tuple(self.topo.link(a, b)
                             for a, b in zip(names, names[1:]))
            self._names = tuple(names)
            self._profiles = profiles
            if self._detour_step is None:
                self._detour_step = int(step)

    def _cut_step(self, prof, hop_step: int, nbytes: int) -> Optional[int]:
        """Step at which this hop attempt fails, or None when it completes.
        Dead at the start fails immediately; a drop activating while the
        transfer is still on the wire cuts it mid-ship."""
        if not prof.health(hop_step).alive:
            return hop_step
        secs = simulate_hop_s(nbytes, prof, hop_step,
                              timeout_s=self.timeout_s, seed=self.seed)
        last = hop_step + int(math.ceil(secs / self.step_s))
        for s in range(hop_step + 1, last + 1):
            if not prof.health(s).alive:
                return s
        return None

    def _simulate(self, nbytes: int, start_step: int, key: int) -> ShipOutcome:
        """Deterministically walk the current route hop by hop under the
        fault schedules (store-and-forward).  Pure with respect to shipper
        state: `ship` commits the outcome, `estimate_steps` discards it."""
        if nbytes <= 0:
            return ShipOutcome(True, 0, 0.0, route=tuple(self._names))
        names = list(self._names)
        profiles = list(self._profiles)
        avoid = set(self._avoid)
        events: list = []
        t = 0.0
        i = 0
        attempts = 0
        reships = reroutes = failures = 0
        while i < len(profiles):
            if failures > _MAX_SHIP_FAULTS:
                return ShipOutcome(False, _UNROUTABLE_STEPS, t, reships,
                                   reroutes, tuple(names), tuple(events))
            prof = profiles[i]
            hop_step = start_step + int(t / self.step_s)
            cut = self._cut_step(prof, hop_step, nbytes)
            if cut is None:
                t += simulate_hop_s(nbytes, prof, hop_step,
                                    timeout_s=self.timeout_s, seed=self.seed)
                i += 1
                attempts = 0
                continue
            # the attempt failed: progress up to the cut is lost, then the
            # watchdog burns timeout_s before the sender learns
            failures += 1
            t += (cut - hop_step) * self.step_s + self.timeout_s
            subject = f"{names[i]}->{names[i + 1]}"
            now = start_step + int(t / self.step_s)
            if attempts < self.max_reships:
                delay = self.retry.delay_s(attempts, key=key * 31 + i)
                t += delay
                attempts += 1
                reships += 1
                events.append(("reship", now, subject,
                               {"rid": key, "attempt": attempts,
                                "backoff_s": round(delay, 6)}))
                continue
            # reships exhausted: replan from the stranded site over
            # whatever still routes (the PR-6 healing_transfer move)
            avoid.add((names[i], names[i + 1]))
            avoid.add((names[i + 1], names[i]))
            try:
                nr = self.topo.route(names[i], names[-1], self.metric,
                                     avoid=frozenset(avoid))
            except (KeyError, ValueError):
                return ShipOutcome(False, _UNROUTABLE_STEPS, t, reships,
                                   reroutes, tuple(names), tuple(events))
            reroutes += 1
            attempts = 0
            events.append(("reroute", now, subject,
                           {"rid": key, "route": list(nr.sites)}))
            names = names[:i] + list(nr.sites)
            profiles = profiles[:i] + list(nr.profiles)
        steps = max(1, int(math.ceil(t / self.step_s)))
        return ShipOutcome(True, steps, t, reships, reroutes,
                           tuple(names), tuple(events))


class ContinuousBatcher:
    """Slot-based continuous batching with admission control.

    Parameters
    ----------
    max_slots: decode slots (the fixed decode batch width).
    queue_limit: queued requests beyond which `submit` rejects.
    prefill_steps: virtual steps one prefill takes — an int, or a callable
        of the :class:`Request` (optionally ``(req, step)``).  Prefill is a
        single site-A server: one request prefills at a time, but the
        decode slots keep ticking underneath — the disaggregation win.
    ship_steps: virtual steps the KV ship takes (int or callable); use
        :func:`modeled_ship_steps` to derive it from a real WidePath.
    step_s: modeled wall seconds of one decode step (converts the virtual
        clock into latency/goodput figures; never read from a real clock).
    deadline_steps: default SLO for requests submitted without one (int or
        callable of the Request; None — no deadline).
    shed: when True (default) admission sheds requests whose modeled
        completion under current link health already blows their deadline.
    shipper: a :class:`FaultAwareShipper` — overrides `ship_steps` with the
        fault-aware model and drives reship/reroute/recover incidents.
    log: a :class:`~repro.core.chaos.IncidentLog` every transition lands in.
    membership: a :class:`~repro.core.membership.SiteMembership` ticked each
        step; eviction of `prefill_site`/`decode_site` triggers failover.
    prefill_site / decode_site: the serving roles' site names (failover
        bookkeeping; the shipper holds the actual route).
    """

    def __init__(self, max_slots: int, queue_limit: int = 64, *,
                 prefill_steps: Union[int, Callable[[Request], int]] = 1,
                 ship_steps: Union[int, Callable[[Request], int]] = 0,
                 step_s: float = 1e-2, name: str = "serve",
                 deadline_steps: Union[int, Callable[[Request], int],
                                       None] = None,
                 shed: bool = True,
                 shipper: Optional[FaultAwareShipper] = None,
                 log=None, membership=None,
                 prefill_site: Optional[str] = None,
                 decode_site: Optional[str] = None):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if queue_limit < 0:
            raise ValueError(f"queue_limit must be >= 0, got {queue_limit}")
        if isinstance(deadline_steps, int) and deadline_steps < 1:
            raise ValueError(f"deadline_steps must be >= 1, "
                             f"got {deadline_steps}")
        self.max_slots = int(max_slots)
        self.queue_limit = int(queue_limit)
        self.step_s = float(step_s)
        self.name = name
        self._prefill_steps = prefill_steps
        self._ship_steps = ship_steps
        self._deadline_steps = deadline_steps
        self._shed = bool(shed)
        self._shipper = shipper
        self._log = log
        self._membership = membership
        self._prefill_site = prefill_site
        self._decode_site = decode_site
        self._home_prefill = prefill_site
        self._home_decode = decode_site
        self._member_epoch = membership.epoch if membership is not None else 0
        self._degraded = False
        self._reships = 0
        self._reroutes = 0
        self._failovers = 0
        self._lock = threading.RLock()
        self._step = 0                      # current virtual step
        self._next_rid = 0
        self._reqs: dict[int, _Track] = {}
        self._queue: list[int] = []         # FIFO of QUEUED rids
        self._slots: list[Optional[int]] = [None] * self.max_slots
        self._prefill_fifo: list[int] = []  # slotted rids awaiting prefill
        self._prefill_rid: Optional[int] = None   # rid on the prefill server
        self._prefill_end = 0
        self._events: list[list] = []       # [kind, "req{rid}", step]

    # -- helpers (call with self._lock held) --------------------------------
    def _emit(self, kind: str, rid: int, step: int) -> None:
        self._events.append([kind, f"req{rid}", step])

    def _n_steps(self, which, req: Request, step: int = 0) -> int:
        if callable(which):
            n = which(req, step) if _wants_step(which) else which(req)
        else:
            n = int(which)
        if n < 0:
            raise ValueError(f"modeled duration must be >= 0, got {n} "
                             f"for req{req.rid}")
        return n

    def _deadline_of(self, req: Request) -> Optional[int]:
        if req.deadline_steps is not None:
            return int(req.deadline_steps)
        d = self._deadline_steps
        if d is None:
            return None
        n = int(d(req)) if callable(d) else int(d)
        if n < 1:
            raise ValueError(f"deadline_steps must be >= 1, got {n} "
                             f"for req{req.rid}")
        return n

    def _modeled_completion_steps(self, req: Request, at: int) -> int:
        """Admission model: a lower bound on steps to completion under the
        current backlog and link health.  The prefill server is serial, so
        everything queued or slotted-but-unprefilled is ahead of this
        request; decode is one token per step; the ship estimate consults
        the fault schedules through the shipper when one is attached."""
        backlog = 0
        if self._prefill_rid is not None:
            backlog += max(0, self._prefill_end - at)
        for rid in self._prefill_fifo:
            backlog += max(1, self._n_steps(self._prefill_steps,
                                            self._reqs[rid].req, at))
        for rid in self._queue:
            backlog += max(1, self._n_steps(self._prefill_steps,
                                            self._reqs[rid].req, at))
        own = max(1, self._n_steps(self._prefill_steps, req, at))
        if self._degraded:
            ship = 0
        elif self._shipper is not None:
            ship = self._shipper.estimate_steps(req, at + backlog + own)
        else:
            ship = self._n_steps(self._ship_steps, req, at)
        return backlog + own + ship + max(0, req.max_new - 1)

    def _start_decode(self, tr: _Track, step: int) -> None:
        tr.state = DECODE
        tr.t_decode = step
        tr.tokens = 1          # first token rides on the prefill logits
        self._emit("decode", tr.req.rid, step)
        if tr.tokens >= tr.req.max_new:
            self._finish(tr, step)

    def _ship_duration(self, tr: _Track, step: int) -> int:
        """Modeled ship steps: 0 when degraded (collocated — no WAN leg),
        the fault-aware shipper's outcome when one is attached, else the
        static/callable `ship_steps`."""
        if self._degraded:
            return 0
        if self._shipper is None:
            return self._n_steps(self._ship_steps, tr.req, step)
        out = self._shipper.ship(tr.req, step)
        if not out.ok:
            self._enter_degraded(
                step, reason=f"req{tr.req.rid}: no surviving route")
            return 0
        with self._lock:
            tr.reships = out.reships
            tr.reroutes = out.reroutes
            self._reships += out.reships
            self._reroutes += out.reroutes
        return out.steps

    def _start_ship(self, tr: _Track, step: int) -> None:
        tr.state = SHIP
        tr.t_ship = step
        self._emit("ship", tr.req.rid, step)
        ss = self._ship_duration(tr, step)
        if ss == 0:
            self._start_decode(tr, step)
        else:
            tr.t_ship_end = step + ss

    def _finish(self, tr: _Track, step: int) -> None:
        tr.state = DONE
        tr.t_done = step
        if tr.slot is not None:
            self._slots[tr.slot] = None
            tr.slot = None
        self._emit("complete", tr.req.rid, step)

    def _timeout(self, tr: _Track, step: int) -> None:
        """Terminal: the request blew its deadline.  Frees whatever stage
        resource it held (queue position, prefill server, decode slot)."""
        with self._lock:
            rid = tr.req.rid
            stage = tr.state
            if tr.slot is not None:
                self._slots[tr.slot] = None
                tr.slot = None
            if self._prefill_rid == rid:
                self._prefill_rid = None
            if rid in self._queue:
                self._queue.remove(rid)
            if rid in self._prefill_fifo:
                self._prefill_fifo.remove(rid)
            tr.state = TIMEOUT
            tr.t_done = step
            self._emit("timeout", rid, step)
            if self._log is not None:
                self._log.add(step, "timeout", f"req{rid}",
                              {"stage": stage, "tokens": tr.tokens})

    def _enter_degraded(self, step: int, reason: str) -> None:
        """No cross-site route survives: collocate prefill+decode (ships
        become free) and flag it — `stats()["degraded"]`."""
        with self._lock:
            if self._degraded:
                return
            self._degraded = True
            if self._log is not None:
                self._log.add(step, "degrade", self.name, {"reason": reason})

    def _try_exit_degraded(self, step: int) -> None:
        """A membership epoch changed while degraded: re-disaggregate onto
        the home sites when both are members and a route survives."""
        ms, sh = self._membership, self._shipper
        hp, hd = self._home_prefill, self._home_decode
        if sh is None or hp is None or hd is None or hp == hd:
            return
        if ms is not None and not (ms.is_member(hp) and ms.is_member(hd)):
            return
        if not sh.retarget(src=hp, dst=hd):
            return
        with self._lock:
            self._prefill_site = hp
            self._decode_site = hd
            self._degraded = False
            if self._log is not None:
                self._log.add(step, "recover", f"{hp}->{hd}",
                              {"mode": "degrade"})

    def _drain_inflight(self, step: int) -> int:
        """Send every in-flight request back to QUEUED, front of the queue
        in rid order — prefill/ship work is lost, decode restarts."""
        with self._lock:
            drained = []
            for rid in sorted(self._reqs):
                tr = self._reqs[rid]
                if tr.state not in (PREFILL, SHIP, DECODE):
                    continue
                if tr.slot is not None:
                    self._slots[tr.slot] = None
                    tr.slot = None
                tr.state = QUEUED
                tr.tokens = 0
                tr.t_prefill = None
                tr.t_ship = None
                tr.t_ship_end = None
                tr.t_decode = None
                drained.append(rid)
                self._emit("requeue", rid, step)
            self._prefill_rid = None
            self._prefill_fifo.clear()
            self._queue[:0] = drained
            return len(drained)

    def _serve_failover(self, role: str, step: int) -> None:
        """Move a serving role off an evicted site: drain in-flight back to
        QUEUED and re-plan onto a surviving member (the chaos monitor's
        replan move applied to serving); with no surviving pair, collocate
        and degrade."""
        with self._lock:
            ms = self._membership
            old = (self._prefill_site if role == "prefill"
                   else self._decode_site)
            other = (self._decode_site if role == "prefill"
                     else self._prefill_site)
            new = None
            avoid: set = set()
            if self._shipper is not None and other is not None:
                evicted = set(ms.evicted())
                for e in evicted:
                    for nb in self._shipper.topo.neighbors(e):
                        avoid.add((e, nb))
                        avoid.add((nb, e))
                for m in ms.members():
                    if m == old or m == other:
                        continue
                    src = m if role == "prefill" else other
                    dst = other if role == "prefill" else m
                    if src != dst and self._shipper.can_route(
                            src, dst, frozenset(avoid)):
                        new = m
                        break
            drained = self._drain_inflight(step)
            self._failovers += 1
            if new is None:
                # no surviving disaggregated pair: collocate on the peer
                if role == "prefill":
                    self._prefill_site = other
                else:
                    self._decode_site = other
                if self._log is not None:
                    self._log.add(step, "serve_failover",
                                  f"{role}:{old}->{other}",
                                  {"requeued": drained, "epoch": ms.epoch,
                                   "collocated": True})
                self._enter_degraded(
                    step, reason=f"{role} site {old} evicted; "
                                 f"no surviving pair")
                return
            if role == "prefill":
                self._prefill_site = new
                self._shipper.retarget(src=new, dst=other,
                                       avoid=frozenset(avoid))
            else:
                self._decode_site = new
                self._shipper.retarget(src=other, dst=new,
                                       avoid=frozenset(avoid))
            if self._log is not None:
                self._log.add(step, "serve_failover", f"{role}:{old}->{new}",
                              {"requeued": drained, "epoch": ms.epoch})
            if self._degraded:
                # the new pair routes (can_route just said so): the
                # collocated fallback ends with this failover
                self._degraded = False
                if self._log is not None:
                    self._log.add(step, "recover",
                                  f"{self._prefill_site}->{self._decode_site}",
                                  {"mode": "degrade"})

    def _tick_membership(self, step: int) -> None:
        """Advance the liveness clock; on an epoch change, fail the serving
        roles over off any evicted site (or recover from degraded)."""
        ms = self._membership
        ms.on_step(step)
        if ms.epoch == self._member_epoch:
            return
        with self._lock:
            self._member_epoch = ms.epoch
            for role in ("prefill", "decode"):
                site = (self._prefill_site if role == "prefill"
                        else self._decode_site)
                if site is not None and not ms.is_member(site):
                    self._serve_failover(role, step)
            if self._degraded:
                self._try_exit_degraded(step)

    # -- public API ---------------------------------------------------------
    def degrade(self, step: Optional[int] = None, reason: str = "") -> None:
        """Enter the collocated mono-site fallback (the hook for runtime
        engines whose *real* KV ship failed with no surviving route)."""
        with self._lock:
            at = self._step if step is None else int(step)
            self._enter_degraded(at, reason or "runtime ship failed")

    def note_ship(self, rid: int, *, reships: int = 0,
                  reroutes: int = 0) -> None:
        """Account a *real* KV ship's retries/replans against the request
        and the scheduler counters (the hook for runtime engines that ship
        through `kvship.ship_kv` instead of the modeled shipper — without
        it `stats()['reships']` stays 0 while the incident log fills up)."""
        with self._lock:
            tr = self._reqs.get(rid)
            if tr is not None:
                tr.reships += int(reships)
                tr.reroutes += int(reroutes)
            self._reships += int(reships)
            self._reroutes += int(reroutes)

    def submit(self, prompt_len: int, max_new: int,
               step: Optional[int] = None, *,
               deadline_steps: Optional[int] = None) -> Optional[int]:
        """Admission control: enqueue a request, or reject it when the
        queue is full, or *shed* it when its modeled completion under
        current link health already blows its deadline.  Returns the rid,
        or None when rejected/shed."""
        if prompt_len < 1 or max_new < 1:
            raise ValueError(f"prompt_len and max_new must be >= 1, got "
                             f"prompt_len={prompt_len} max_new={max_new}")
        if deadline_steps is not None and int(deadline_steps) < 1:
            raise ValueError(f"deadline_steps must be >= 1, "
                             f"got {deadline_steps}")
        with self._lock:
            at = self._step if step is None else int(step)
            rid = self._next_rid
            self._next_rid = rid + 1
            req = Request(rid, at, int(prompt_len), int(max_new),
                          None if deadline_steps is None
                          else int(deadline_steps))
            tr = _Track(req)
            self._reqs[rid] = tr
            if len(self._queue) >= self.queue_limit:
                tr.state = REJECTED
                tr.t_done = at
                self._emit("reject", rid, at)
                if self._log is not None:
                    self._log.add(at, "shed", f"req{rid}",
                                  {"reason": "queue-full",
                                   "queued": len(self._queue)})
                return None
            deadline = self._deadline_of(req)
            if self._shed and deadline is not None:
                modeled = self._modeled_completion_steps(req, at)
                if modeled >= deadline:
                    tr.state = SHED
                    tr.t_done = at
                    self._emit("shed", rid, at)
                    if self._log is not None:
                        self._log.add(at, "shed", f"req{rid}",
                                      {"reason": "slo",
                                       "modeled_steps": int(modeled),
                                       "deadline_steps": int(deadline)})
                    return None
            self._queue.append(rid)
            self._emit("admit", rid, at)
            return rid

    def step_once(self) -> int:
        """Advance the virtual clock one step.  Transition order within a
        step: membership/fault housekeeping -> deadline sweep -> prefill
        completions -> ship completions -> decode token tick (completions
        free slots) -> slot fill from the queue -> prefill start.  Returns
        the step just processed."""
        with self._lock:
            step = self._step
            # (0) housekeeping: fault injections/recovery on the shipper,
            # liveness clock + failover on the membership, then the
            # deadline sweep — anything past its deadline times out before
            # it can consume another prefill/ship/decode step
            if self._shipper is not None:
                self._shipper.on_step(step)
            if self._membership is not None:
                self._tick_membership(step)
            for rid in sorted(self._reqs):
                tr = self._reqs[rid]
                if tr.state in _TERMINAL:
                    continue
                d = self._deadline_of(tr.req)
                if d is not None and step - tr.req.arrival >= d:
                    self._timeout(tr, step)
            # (1) prefill completion -> ship starts (frees the prefill server)
            if self._prefill_rid is not None and self._prefill_end == step:
                tr = self._reqs[self._prefill_rid]
                self._prefill_rid = None
                self._start_ship(tr, step)
            # (2) ship completions -> decode starts (first token lands)
            for rid in sorted(self._reqs):
                tr = self._reqs[rid]
                if tr.state == SHIP and tr.t_ship_end == step:
                    self._start_decode(tr, step)
            # (3) decode tick: one token per occupied slot (not the slot
            # whose first token arrived this very step)
            for slot, rid in enumerate(self._slots):
                if rid is None:
                    continue
                tr = self._reqs[rid]
                if tr.state != DECODE or tr.t_decode == step:
                    continue
                tr.tokens += 1
                if tr.tokens >= tr.req.max_new:
                    self._finish(tr, step)
            # (4) fill free decode slots from the queue, FIFO
            for slot in range(self.max_slots):
                if self._slots[slot] is not None or not self._queue:
                    continue
                rid = self._queue.pop(0)
                tr = self._reqs[rid]
                tr.slot = slot
                tr.state = PREFILL
                self._slots[slot] = rid
                self._prefill_fifo.append(rid)
            # (5) single prefill server picks up the next slotted request
            if self._prefill_rid is None and self._prefill_fifo:
                rid = self._prefill_fifo.pop(0)
                tr = self._reqs[rid]
                self._prefill_rid = rid
                ps = max(1, self._n_steps(self._prefill_steps, tr.req, step))
                self._prefill_end = step + ps
                tr.t_prefill = step
                self._emit("prefill", rid, step)
            self._step = step + 1
            return step

    def slot_of(self, rid: int) -> Optional[int]:
        """Decode slot a request occupies (None while queued/terminal)."""
        with self._lock:
            return self._reqs[rid].slot

    def now(self) -> int:
        """Current virtual step."""
        with self._lock:
            return self._step

    def active(self) -> int:
        """Requests not yet terminal (queued or in the pipeline)."""
        with self._lock:
            return sum(1 for t in self._reqs.values()
                       if t.state not in _TERMINAL)

    def active_slots(self) -> list:
        """Snapshot of slot occupancy (rid or None per slot)."""
        with self._lock:
            return list(self._slots)

    def drain(self, max_steps: int = 100_000) -> int:
        """Step until every submitted request is terminal.  Raises on
        starvation (the no-starvation invariant the property suite checks)."""
        steps = 0
        while self.active() > 0:
            if steps >= max_steps:
                raise RuntimeError(
                    f"batcher did not drain within {max_steps} steps: "
                    f"{self.active()} request(s) still live")
            self.step_once()
            steps += 1
        return steps

    def run(self, arrivals: list) -> dict:
        """Drive a full trace: `arrivals` is a list of (step, prompt_len,
        max_new) or (step, prompt_len, max_new, deadline_steps) tuples
        (sorted by step).  Submits each at its step, then drains.  Returns
        :meth:`stats`."""
        pending = sorted(arrivals, key=lambda a: a[0])
        i = 0
        while i < len(pending) or self.active() > 0:
            now = self._step
            while i < len(pending) and pending[i][0] <= now:
                a = pending[i]
                self.submit(a[1], a[2], step=now,
                            deadline_steps=a[3] if len(a) > 3 else None)
                i += 1
            self.step_once()
        return self.stats()

    def timeline(self) -> list:
        """The event log: [kind, "req{rid}", step] rows, in order."""
        with self._lock:
            return [list(e) for e in self._events]

    def stats(self) -> dict:
        """Latency/TTFT percentiles, goodput, and counters — in modeled
        seconds (virtual steps x step_s).  ``slo_attainment`` is completed
        over every terminal request (shed and timed-out count against it);
        ``degraded`` flags the collocated mono-site fallback."""
        with self._lock:
            tracks = list(self._reqs.values())
            reships, reroutes = self._reships, self._reroutes
            failovers, degraded = self._failovers, self._degraded
        done = [t for t in tracks if t.state == DONE]
        rejected = sum(1 for t in tracks if t.state == REJECTED)
        timed_out = sum(1 for t in tracks if t.state == TIMEOUT)
        shed = sum(1 for t in tracks if t.state == SHED)
        lat = [(t.t_done - t.req.arrival) * self.step_s for t in done]
        ttft = [(t.t_decode - t.req.arrival) * self.step_s for t in done]
        tokens = sum(t.tokens for t in done)
        if done:
            span = (max(t.t_done for t in done)
                    - min(t.req.arrival for t in done) + 1)
        else:
            span = 0
        makespan_s = span * self.step_s
        denom = len(done) + rejected + timed_out + shed
        return {
            "completed": len(done),
            "rejected": rejected,
            "timed_out": timed_out,
            "shed": shed,
            "reships": reships,
            "reroutes": reroutes,
            "failovers": failovers,
            "degraded": degraded,
            "slo_attainment": len(done) / denom if denom else 1.0,
            "total_tokens": tokens,
            "makespan_s": makespan_s,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "ttft_p99_s": _percentile(ttft, 99),
            "goodput_tok_s": tokens / makespan_s if makespan_s > 0 else 0.0,
        }


class FixedBatchScheduler:
    """Run-to-completion fixed batching — the baseline continuous batching
    beats.  Requests are grouped into consecutive batches of `max_slots` in
    arrival order; a batch prefills its members serially (monolithic: the
    same device prefills and decodes), then decodes until its *slowest*
    member finishes — freed rows idle, the queue waits."""

    def __init__(self, max_slots: int, *,
                 prefill_steps: Union[int, Callable[[Request], int]] = 1,
                 step_s: float = 1e-2):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.max_slots = int(max_slots)
        self.step_s = float(step_s)
        self._prefill_steps = prefill_steps

    def run(self, arrivals: list) -> dict:
        """Same trace format as :meth:`ContinuousBatcher.run` (a trailing
        deadline entry is ignored — this baseline has no SLO handling)."""
        reqs = [Request(i, int(a[0]), int(a[1]), int(a[2]))
                for i, a in enumerate(sorted(arrivals, key=lambda a: a[0]))]
        lat: list[float] = []
        ttft: list[float] = []
        tokens = 0
        prev_end = 0
        last_done = 0
        for b0 in range(0, len(reqs), self.max_slots):
            batch = reqs[b0:b0 + self.max_slots]
            start = max(prev_end, max(r.arrival for r in batch))
            psteps = sum(
                max(1, (self._prefill_steps(r)
                        if callable(self._prefill_steps)
                        else int(self._prefill_steps)))
                for r in batch)
            decode_start = start + psteps     # first token for every member
            end = decode_start + max(r.max_new for r in batch) - 1
            for r in batch:
                lat.append((end - r.arrival) * self.step_s)
                ttft.append((decode_start - r.arrival) * self.step_s)
                tokens += r.max_new
            prev_end = end + 1
            last_done = end
        span = (last_done - min(r.arrival for r in reqs) + 1) if reqs else 0
        makespan_s = span * self.step_s
        return {
            "completed": len(reqs),
            "rejected": 0,
            "timed_out": 0,
            "shed": 0,
            "reships": 0,
            "reroutes": 0,
            "failovers": 0,
            "degraded": False,
            "slo_attainment": 1.0,
            "total_tokens": tokens,
            "makespan_s": makespan_s,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "ttft_p99_s": _percentile(ttft, 99),
            "goodput_tok_s": tokens / makespan_s if makespan_s > 0 else 0.0,
        }
