"""Continuous-batching serving scheduler over WidePaths (deterministic).

The paper's third claim is "very fast connections in client-server
environments"; this module is the client-server tier's brain.  A
:class:`ContinuousBatcher` owns a fixed set of *decode slots* and fills free
slots from an admission-controlled request queue every step — instead of
running fixed batches to completion — while each admitted request walks the
disaggregated pipeline::

    queued -> prefill (site A) -> ship (KV over the WidePath) -> decode
           (site B) -> done

All time is a virtual step clock (one decode token per step per slot); WAN
legs take :func:`modeled_ship_steps` derived from the deterministic
alpha-beta link model (`repro.core.autotune.simulate_transfer_s`) — no wall
clock anywhere, so a given arrival trace replays bit-identically (the golden
schedule test in tests/test_serving.py pins one).  The runtime engine
(`repro.runtime.serving.ServingEngine`) drives the same bookkeeping with
*real* prefill/ship/decode work instead of modeled durations.

Thread-safety: `submit` may be called from a frontend thread while a driver
thread steps the clock, so every state transition runs under the instance
lock (mpwlint R2).
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Callable, Optional, Union

import numpy as np

from repro.core.autotune import simulate_transfer_s
from repro.core.path import WidePath

# request lifecycle states
QUEUED = "queued"        # admitted, waiting for a free decode slot
PREFILL = "prefill"      # slot claimed; waiting for / running site-A prefill
SHIP = "ship"            # KV cache in flight over the WidePath
DECODE = "decode"        # occupying a decode slot on site B
DONE = "done"
REJECTED = "rejected"

_TERMINAL = (DONE, REJECTED)


@dataclass(frozen=True)
class Request:
    """One serving request (arrival is a virtual step index)."""
    rid: int
    arrival: int
    prompt_len: int
    max_new: int


@dataclass
class _Track:
    """Mutable per-request bookkeeping (timestamps are virtual steps)."""
    req: Request
    state: str = QUEUED
    slot: Optional[int] = None
    tokens: int = 0                  # generated so far (first from prefill)
    t_prefill: Optional[int] = None  # prefill started
    t_ship: Optional[int] = None     # prefill done / ship started
    t_ship_end: Optional[int] = None
    t_decode: Optional[int] = None   # decode started == first token
    t_done: Optional[int] = None


def modeled_ship_steps(kv_bytes: int, path: WidePath, step_s: float) -> int:
    """Virtual steps one request's KV cache spends on the wire.

    Sums the deterministic per-hop transfer model over the path's route
    (store-and-forward, like `Forward`), then quantizes to the decode step
    clock.  0 bytes ship for free (the monolithic baseline)."""
    if kv_bytes <= 0:
        return 0
    if step_s <= 0:
        raise ValueError(f"step_s must be > 0 to quantize ship time, "
                         f"got {step_s}")
    total = 0.0
    for hop in path.route:
        total += simulate_transfer_s(
            kv_bytes, hop.link, streams=hop.streams,
            chunk_bytes=hop.chunk_bytes, pacing=hop.comm.pacing)
    return max(1, int(math.ceil(total / step_s)))


def _percentile(xs: list, q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


class ContinuousBatcher:
    """Slot-based continuous batching with admission control.

    Parameters
    ----------
    max_slots: decode slots (the fixed decode batch width).
    queue_limit: queued requests beyond which `submit` rejects.
    prefill_steps: virtual steps one prefill takes — an int, or a callable
        of the :class:`Request` (e.g. proportional to prompt_len).  Prefill
        is a single site-A server: one request prefills at a time, but the
        decode slots keep ticking underneath — the disaggregation win.
    ship_steps: virtual steps the KV ship takes (int or callable); use
        :func:`modeled_ship_steps` to derive it from a real WidePath.
    step_s: modeled wall seconds of one decode step (converts the virtual
        clock into latency/goodput figures; never read from a real clock).
    """

    def __init__(self, max_slots: int, queue_limit: int = 64, *,
                 prefill_steps: Union[int, Callable[[Request], int]] = 1,
                 ship_steps: Union[int, Callable[[Request], int]] = 0,
                 step_s: float = 1e-2, name: str = "serve"):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if queue_limit < 0:
            raise ValueError(f"queue_limit must be >= 0, got {queue_limit}")
        self.max_slots = int(max_slots)
        self.queue_limit = int(queue_limit)
        self.step_s = float(step_s)
        self.name = name
        self._prefill_steps = prefill_steps
        self._ship_steps = ship_steps
        self._lock = threading.Lock()
        self._step = 0                      # current virtual step
        self._next_rid = 0
        self._reqs: dict[int, _Track] = {}
        self._queue: list[int] = []         # FIFO of QUEUED rids
        self._slots: list[Optional[int]] = [None] * self.max_slots
        self._prefill_fifo: list[int] = []  # slotted rids awaiting prefill
        self._prefill_rid: Optional[int] = None   # rid on the prefill server
        self._prefill_end = 0
        self._events: list[list] = []       # [kind, "req{rid}", step]

    # -- helpers (call with self._lock held) --------------------------------
    def _emit(self, kind: str, rid: int, step: int) -> None:
        self._events.append([kind, f"req{rid}", step])

    def _n_steps(self, which, req: Request) -> int:
        n = which(req) if callable(which) else int(which)
        if n < 0:
            raise ValueError(f"modeled duration must be >= 0, got {n} "
                             f"for req{req.rid}")
        return n

    def _start_decode(self, tr: _Track, step: int) -> None:
        tr.state = DECODE
        tr.t_decode = step
        tr.tokens = 1          # first token rides on the prefill logits
        self._emit("decode", tr.req.rid, step)
        if tr.tokens >= tr.req.max_new:
            self._finish(tr, step)

    def _start_ship(self, tr: _Track, step: int) -> None:
        tr.state = SHIP
        tr.t_ship = step
        self._emit("ship", tr.req.rid, step)
        ss = self._n_steps(self._ship_steps, tr.req)
        if ss == 0:
            self._start_decode(tr, step)
        else:
            tr.t_ship_end = step + ss

    def _finish(self, tr: _Track, step: int) -> None:
        tr.state = DONE
        tr.t_done = step
        if tr.slot is not None:
            self._slots[tr.slot] = None
            tr.slot = None
        self._emit("complete", tr.req.rid, step)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt_len: int, max_new: int,
               step: Optional[int] = None) -> Optional[int]:
        """Admission control: enqueue a request, or reject it when the queue
        is full.  Returns the rid, or None when rejected."""
        if prompt_len < 1 or max_new < 1:
            raise ValueError(f"prompt_len and max_new must be >= 1, got "
                             f"prompt_len={prompt_len} max_new={max_new}")
        with self._lock:
            at = self._step if step is None else int(step)
            rid = self._next_rid
            self._next_rid = rid + 1
            req = Request(rid, at, int(prompt_len), int(max_new))
            tr = _Track(req)
            self._reqs[rid] = tr
            if len(self._queue) >= self.queue_limit:
                tr.state = REJECTED
                tr.t_done = at
                self._emit("reject", rid, at)
                return None
            self._queue.append(rid)
            self._emit("admit", rid, at)
            return rid

    def step_once(self) -> int:
        """Advance the virtual clock one step.  Transition order within a
        step: prefill completions -> ship completions -> decode token tick
        (completions free slots) -> slot fill from the queue -> prefill
        start.  Returns the step just processed."""
        with self._lock:
            step = self._step
            # (1) prefill completion -> ship starts (frees the prefill server)
            if self._prefill_rid is not None and self._prefill_end == step:
                tr = self._reqs[self._prefill_rid]
                self._prefill_rid = None
                self._start_ship(tr, step)
            # (2) ship completions -> decode starts (first token lands)
            for rid in sorted(self._reqs):
                tr = self._reqs[rid]
                if tr.state == SHIP and tr.t_ship_end == step:
                    self._start_decode(tr, step)
            # (3) decode tick: one token per occupied slot (not the slot
            # whose first token arrived this very step)
            for slot, rid in enumerate(self._slots):
                if rid is None:
                    continue
                tr = self._reqs[rid]
                if tr.state != DECODE or tr.t_decode == step:
                    continue
                tr.tokens += 1
                if tr.tokens >= tr.req.max_new:
                    self._finish(tr, step)
            # (4) fill free decode slots from the queue, FIFO
            for slot in range(self.max_slots):
                if self._slots[slot] is not None or not self._queue:
                    continue
                rid = self._queue.pop(0)
                tr = self._reqs[rid]
                tr.slot = slot
                tr.state = PREFILL
                self._slots[slot] = rid
                self._prefill_fifo.append(rid)
            # (5) single prefill server picks up the next slotted request
            if self._prefill_rid is None and self._prefill_fifo:
                rid = self._prefill_fifo.pop(0)
                tr = self._reqs[rid]
                self._prefill_rid = rid
                ps = max(1, self._n_steps(self._prefill_steps, tr.req))
                self._prefill_end = step + ps
                tr.t_prefill = step
                self._emit("prefill", rid, step)
            self._step = step + 1
            return step

    def slot_of(self, rid: int) -> Optional[int]:
        """Decode slot a request occupies (None while queued/terminal)."""
        with self._lock:
            return self._reqs[rid].slot

    def now(self) -> int:
        """Current virtual step."""
        with self._lock:
            return self._step

    def active(self) -> int:
        """Requests not yet terminal (queued or in the pipeline)."""
        with self._lock:
            return sum(1 for t in self._reqs.values()
                       if t.state not in _TERMINAL)

    def active_slots(self) -> list:
        """Snapshot of slot occupancy (rid or None per slot)."""
        with self._lock:
            return list(self._slots)

    def drain(self, max_steps: int = 100_000) -> int:
        """Step until every submitted request is terminal.  Raises on
        starvation (the no-starvation invariant the property suite checks)."""
        steps = 0
        while self.active() > 0:
            if steps >= max_steps:
                raise RuntimeError(
                    f"batcher did not drain within {max_steps} steps: "
                    f"{self.active()} request(s) still live")
            self.step_once()
            steps += 1
        return steps

    def run(self, arrivals: list) -> dict:
        """Drive a full trace: `arrivals` is a list of (step, prompt_len,
        max_new) tuples (sorted by step).  Submits each at its step, then
        drains.  Returns :meth:`stats`."""
        pending = sorted(arrivals, key=lambda a: a[0])
        i = 0
        while i < len(pending) or self.active() > 0:
            now = self._step
            while i < len(pending) and pending[i][0] <= now:
                _, plen, mnew = pending[i]
                self.submit(plen, mnew, step=now)
                i += 1
            self.step_once()
        return self.stats()

    def timeline(self) -> list:
        """The event log: [kind, "req{rid}", step] rows, in order."""
        with self._lock:
            return [list(e) for e in self._events]

    def stats(self) -> dict:
        """Latency/TTFT percentiles, goodput, and counters — in modeled
        seconds (virtual steps x step_s)."""
        with self._lock:
            tracks = list(self._reqs.values())
        done = [t for t in tracks if t.state == DONE]
        rejected = sum(1 for t in tracks if t.state == REJECTED)
        lat = [(t.t_done - t.req.arrival) * self.step_s for t in done]
        ttft = [(t.t_decode - t.req.arrival) * self.step_s for t in done]
        tokens = sum(t.tokens for t in done)
        if done:
            span = (max(t.t_done for t in done)
                    - min(t.req.arrival for t in done) + 1)
        else:
            span = 0
        makespan_s = span * self.step_s
        return {
            "completed": len(done),
            "rejected": rejected,
            "total_tokens": tokens,
            "makespan_s": makespan_s,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "ttft_p99_s": _percentile(ttft, 99),
            "goodput_tok_s": tokens / makespan_s if makespan_s > 0 else 0.0,
        }


class FixedBatchScheduler:
    """Run-to-completion fixed batching — the baseline continuous batching
    beats.  Requests are grouped into consecutive batches of `max_slots` in
    arrival order; a batch prefills its members serially (monolithic: the
    same device prefills and decodes), then decodes until its *slowest*
    member finishes — freed rows idle, the queue waits."""

    def __init__(self, max_slots: int, *,
                 prefill_steps: Union[int, Callable[[Request], int]] = 1,
                 step_s: float = 1e-2):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.max_slots = int(max_slots)
        self.step_s = float(step_s)
        self._prefill_steps = prefill_steps

    def run(self, arrivals: list) -> dict:
        """Same trace format as :meth:`ContinuousBatcher.run`."""
        reqs = [Request(i, int(a[0]), int(a[1]), int(a[2]))
                for i, a in enumerate(sorted(arrivals, key=lambda a: a[0]))]
        lat: list[float] = []
        ttft: list[float] = []
        tokens = 0
        prev_end = 0
        last_done = 0
        for b0 in range(0, len(reqs), self.max_slots):
            batch = reqs[b0:b0 + self.max_slots]
            start = max(prev_end, max(r.arrival for r in batch))
            psteps = sum(
                max(1, (self._prefill_steps(r)
                        if callable(self._prefill_steps)
                        else int(self._prefill_steps)))
                for r in batch)
            decode_start = start + psteps     # first token for every member
            end = decode_start + max(r.max_new for r in batch) - 1
            for r in batch:
                lat.append((end - r.arrival) * self.step_s)
                ttft.append((decode_start - r.arrival) * self.step_s)
                tokens += r.max_new
            prev_end = end + 1
            last_done = end
        span = (last_done - min(r.arrival for r in reqs) + 1) if reqs else 0
        makespan_s = span * self.step_s
        return {
            "completed": len(reqs),
            "rejected": 0,
            "total_tokens": tokens,
            "makespan_s": makespan_s,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "ttft_p99_s": _percentile(ttft, 99),
            "goodput_tok_s": tokens / makespan_s if makespan_s > 0 else 0.0,
        }
