"""Latency hiding: overlap cross-pod gradient sync with compute
(MPW_ISendRecv / MPW_Wait, the bloodflow-coupling trick).

`accum_grads` runs gradient accumulation where microbatch i's cross-pod sync
is issued *inside* iteration i+1: the sync has no data dependence on
iteration i+1's forward/backward, so the XLA latency-hiding scheduler can
run the collective concurrently with compute.  Only the final microbatch's
sync is exposed — 1/m of the naive exposure (paper: 11 ms RTT coupling
reduced to 6 ms exposed, 1.2% of runtime).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def accum_grads(grad_fn: Callable, params, microbatches, *, sync: Callable,
                dims=None, overlap: bool = True):
    """grad_fn(params, microbatch) -> ((loss, metrics), grads).

    microbatches: pytree whose leaves have a leading microbatch dim m.
    sync(grads) -> synced grads (the WidePath transfer).
    Returns (mean_loss, metrics_last, synced_grad_sum).

    With overlap=False this is plain accumulate-then-sync (the baseline the
    paper's latency hiding is measured against).
    """
    m = jax.tree.leaves(microbatches)[0].shape[0]

    def mb(i):
        return jax.tree.map(lambda x: x[i], microbatches)

    if not overlap or m == 1:
        total_loss = jnp.float32(0.0)
        acc = None
        metrics = None
        for i in range(m):
            (loss, metrics), g = grad_fn(params, mb(i))
            total_loss += loss
            acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
        return total_loss / m, metrics, sync(acc)

    # software-pipelined: sync microbatch i-1 while computing microbatch i
    (loss0, metrics), pending = grad_fn(params, mb(0))
    total_loss = loss0
    synced = None
    for i in range(1, m):
        (loss_i, metrics), g_i = grad_fn(params, mb(i))
        # sync(pending) is independent of g_i's computation; the scheduler
        # may overlap the cross-pod transfer with this iteration's compute.
        s = sync(pending)
        synced = s if synced is None else jax.tree.map(jnp.add, synced, s)
        pending = g_i
        total_loss = total_loss + loss_i
    s = sync(pending)                   # exposed tail (1/m of the naive cost)
    synced = s if synced is None else jax.tree.map(jnp.add, synced, s)
    return total_loss / m, metrics, synced
