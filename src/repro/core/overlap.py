"""Latency hiding: overlap cross-pod gradient sync with compute
(MPW_ISendRecv / MPW_Wait, the bloodflow-coupling trick).

`accum_grads` runs gradient accumulation where microbatch i's cross-pod sync
is issued *inside* iteration i+1: the sync has no data dependence on
iteration i+1's forward/backward, so the XLA latency-hiding scheduler can
run the collective concurrently with compute.  Only the final microbatch's
sync is exposed — 1/m of the naive exposure (paper: 11 ms RTT coupling
reduced to 6 ms exposed, 1.2% of runtime).

That exposure is attacked further by layer buckets (`repro.core.buckets`):

  * :func:`flush_hook` — a ``custom_vjp`` identity the train step wraps
    around each bucket's layer range; its *backward* runs the bucket's
    cross-pod sync, so the WAN transfer of late-layer gradients is issued
    while the backward of earlier layers is still computing.  This is what
    makes ``microbatches=1`` overlap at all.
  * :func:`modeled_exposure` — the alpha-beta/window schedule model of what
    the bucketed step exposes, feeding the ``exposed_s``/``overlapped_s``
    telemetry and `benchmarks/overlap_efficiency.py`.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp


def accum_grads(grad_fn: Callable, params, microbatches, *, sync: Callable,
                dims=None, overlap: bool = True):
    """grad_fn(params, microbatch) -> ((loss, metrics), grads).

    microbatches: pytree whose leaves have a leading microbatch dim m.
    sync(grads) -> synced grads (the WidePath transfer).
    Returns (mean_loss, metrics_last, synced_grad_sum).

    With overlap=False this is plain accumulate-then-sync (the baseline the
    paper's latency hiding is measured against).
    """
    m = jax.tree.leaves(microbatches)[0].shape[0]

    def mb(i):
        return jax.tree.map(lambda x: x[i], microbatches)

    if not overlap or m == 1:
        total_loss = jnp.float32(0.0)
        acc = None
        metrics = None
        for i in range(m):
            (loss, metrics), g = grad_fn(params, mb(i))
            total_loss += loss
            acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
        return total_loss / m, metrics, sync(acc)

    # software-pipelined: sync microbatch i-1 while computing microbatch i
    (loss0, metrics), pending = grad_fn(params, mb(0))
    total_loss = loss0
    synced = None
    for i in range(1, m):
        (loss_i, metrics), g_i = grad_fn(params, mb(i))
        # sync(pending) is independent of g_i's computation; the scheduler
        # may overlap the cross-pod transfer with this iteration's compute.
        s = sync(pending)
        synced = s if synced is None else jax.tree.map(jnp.add, synced, s)
        pending = g_i
        total_loss = total_loss + loss_i
    s = sync(pending)                   # exposed tail (1/m of the naive cost)
    synced = s if synced is None else jax.tree.map(jnp.add, synced, s)
    return total_loss / m, metrics, synced


# ---------------------------------------------------------------------------
# backward-side flush: sync-in-backward via custom_vjp
# ---------------------------------------------------------------------------

def flush_hook(sync_fn: Callable) -> Callable:
    """Identity-in-forward hook whose *backward* runs `sync_fn` on the
    cotangent tree.

    Wrapped around a bucket's (layer-sliced) params before the layer scan,
    the hook plants the bucket's cross-pod gradient sync exactly where the
    bucket's backward slice is produced: the transfer has no data dependence
    on the backward of earlier layers, so the latency-hiding scheduler can
    run it concurrently (pMR's halo-exchange-behind-stencil trick, applied
    to backprop).  `sync_fn` must return the same dtypes it receives —
    custom_vjp cotangents match primal dtypes, so cast to the f32 wire dtype
    and back inside.
    """
    @jax.custom_vjp
    def flush(tree):
        return tree

    def fwd(tree):
        return tree, None

    def bwd(_, g):
        return (sync_fn(g),)

    flush.defvjp(fwd, bwd)
    return flush


# ---------------------------------------------------------------------------
# modeled exposure: what the bucketed schedule leaves on the critical path
# ---------------------------------------------------------------------------

def modeled_exposure(payload_bytes: float, link, *, streams: int,
                     chunk_bytes: float, pacing: float = 1.0,
                     compute_window: float = 0.0, bucket_bytes: float = 0.0,
                     microbatches: int = 1, world: int = 2,
                     algo: str = "psum", compress: str = "none",
                     backward_frac: float = 2.0 / 3.0) -> dict:
    """Model one train step's cross-pod comm exposure.

    `payload_bytes` is one microbatch's gradient payload; `compute_window`
    the modeled compute seconds of one microbatch (fwd+bwd — from
    `repro.launch.roofline.modeled_compute_window`).  Per-transfer wall
    seconds come from :func:`repro.core.autotune.simulate_transfer_s` (the
    window-capped WAN landscape, algo/compress/world aware).

    Schedule:
      * microbatches 1..m-1 sync pipelined under the next microbatch's full
        compute window (`accum_grads`); each exposes max(0, T - W).
      * the FINAL microbatch has no following compute.  Without buckets its
        whole sync T is exposed.  With buckets, bucket k's transfer is
        issued when its layer range finishes backward (k-th fraction of the
        backward window `backward_frac * W`), transfers serialize on the
        link, and only what spills past the backward is exposed — the
        optimizer then consumes buckets as they land, so the exposed tail
        floors at the last bucket.

    Returns dict(exposed_s, overlapped_s, comm_s, n_buckets, per_bucket_s).
    """
    from repro.core.autotune import simulate_transfer_s

    def t_of(nbytes: float) -> float:
        return simulate_transfer_s(nbytes, link, streams=streams,
                                   chunk_bytes=chunk_bytes, pacing=pacing,
                                   algo=algo, world=world, compress=compress)

    m = max(1, int(microbatches))
    W = max(0.0, float(compute_window))
    t_all = t_of(payload_bytes)
    if bucket_bytes and bucket_bytes > 0:
        # successive buckets' chunks queue onto the SAME streams back to
        # back (streamed_psum keeps the channels fed across bucket
        # boundaries), so a bucket's wire time is its proportional share of
        # the whole transfer — plus one launch latency per bucket, the
        # per-bucket floor that stops "smaller is always better"
        n_buckets = max(1, math.ceil(payload_bytes / bucket_bytes))
        per_bucket = [t_all / n_buckets + link.latency_s] * n_buckets
    else:
        n_buckets = 1
        per_bucket = [t_all]

    # pipelined microbatches: sync under the next microbatch's compute
    exposed = (m - 1) * max(0.0, sum(per_bucket) - W)
    # final microbatch: buckets flush during its backward
    Wb = backward_frac * W
    end = 0.0
    for k, t_k in enumerate(per_bucket):
        ready = Wb * (k + 1) / n_buckets
        end = max(end, ready) + t_k
    exposed += max(0.0, end - Wb)
    comm = m * sum(per_bucket)
    return dict(exposed_s=exposed,
                overlapped_s=max(0.0, comm - exposed),
                comm_s=comm, n_buckets=n_buckets,
                per_bucket_s=per_bucket)
