"""Layer-bucketed gradient sync: partition the gradient tree into
``bucket_bytes``-sized buckets along the stacked ``layers`` dim and issue one
streamed cross-pod psum per bucket.

Why buckets: `accum_grads` hides the sync of microbatch i under microbatch
i+1's compute, but the *final* sync is exposed whole — and at
``microbatches=1`` (the common large-model config) there is no overlap at
all.  Buckets restore the paper's latency hiding at any microbatch count:

  * **backward flush** — the train step wraps each bucket's layer range in a
    ``custom_vjp`` hook (:func:`repro.core.overlap.flush_hook`), so bucket
    k's WAN transfer is issued the moment its backward slice is produced and
    overlaps the backward of earlier layers;
  * **tail interleave** — the optimizer consumes the sync bucket-by-bucket
    (:func:`repro.optim.adamw.adamw_update` with ``buckets=``): update(k)
    depends only on sync(k) plus the clip-norm scalar, so the exposed tail
    shrinks from the full tree to one bucket.

Bucket boundaries slice the *leading layers dim* of stacked params (the
scan-stacked ``blocks`` subtree), never a scatter/TP dim, so slicing costs
no collective.  A leaf is layer-bucketable only when it has a *stated*
scatter dim: leaves chunked along the dim-0 fallback would change their
blockwise-int8 quantization blocks under layer slicing, so they ride in the
rest bucket instead.  Within a bucket, each slice is chunked with the row
geometry of its *full* leaf (:func:`repro.core.streams.chunk_rows`), which
keeps bucketed transfers bit-identical to the unbucketed path for every
compression mode.

Bucket indices count from the output end of the stack (bucket 0 = the last
layers — the first gradients backprop produces); the rest bucket (top-level
leaves: embed/head/norms + any non-sliceable stacked leaf) comes last.
Telemetry lands under ``{key}/bkt{i}``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import streams as st
from repro.core.path import WidePath


@dataclass(frozen=True)
class Bucket:
    """One sync bucket: a layer range of the stacked subtree, or the rest
    bucket (``lo == hi == -1``) holding every non-layer-sliceable leaf."""
    index: int
    lo: int
    hi: int
    nbytes: int                   # payload bytes of this bucket's slices

    @property
    def is_rest(self) -> bool:
        return self.lo < 0


@dataclass(frozen=True)
class BucketPlan:
    n_layers: int
    layers_per_bucket: int
    buckets: tuple                # layer buckets (backward order) + rest
    stacked_bytes: int
    rest_bytes: int

    @property
    def layer_buckets(self) -> tuple:
        return tuple(b for b in self.buckets if not b.is_rest)

    @property
    def rest_bucket(self) -> Optional[Bucket]:
        for b in self.buckets:
            if b.is_rest:
                return b
        return None

    @property
    def layer_bounds(self) -> list:
        """[(lo, hi), ...] in forward (ascending-layer) order."""
        return sorted((b.lo, b.hi) for b in self.layer_buckets)


def bucketable_flags(leaves: list, stacked, dims=None) -> list[bool]:
    """Per-leaf layer-bucketability: marked stacked AND a stated scatter dim
    (>= 1, so the slice never crosses the dim chunking/quantization uses).

    `stacked` is a pytree of bools aligned with the tree the leaves came
    from (or a flat list); `dims` the raw scatter-dim tree/list (None leaves
    kept; negative dims follow numpy semantics, `d % ndim`, exactly like
    `streams.normalize_dims` — "no scatter dim" is spelled None, never -1).
    Leaves that fail the test ride in the rest bucket."""
    flag_list = (stacked if isinstance(stacked, list)
                 else jax.tree.leaves(stacked))
    if dims is None:
        dim_list: list = [None] * len(leaves)
    else:
        dim_list = (dims if isinstance(dims, list)
                    else jax.tree.leaves(dims, is_leaf=lambda x: x is None))
    out = []
    for x, f, d in zip(leaves, flag_list, dim_list):
        ok = bool(f) and d is not None and x.ndim >= 2
        if ok:
            dd = d if d >= 0 else d % x.ndim
            ok = dd != 0
        out.append(ok)
    return out


def plan_buckets(leaves: list, flags: list[bool], bucket_bytes: int
                 ) -> BucketPlan:
    """Tile the stacked leaves' leading layers dim into ~bucket_bytes ranges.

    Ranges are cut from the top of the stack (backward production order);
    the final (lowest-layer) bucket absorbs the remainder, so the ranges
    tile ``[0, n_layers)`` exactly — mirroring `plan_chunks`' remainder
    handling.  Works on concrete arrays or ShapeDtypeStructs."""
    stacked_leaves = [x for x, f in zip(leaves, flags) if f]
    rest_bytes = sum(st.leaf_bytes(x) for x, f in zip(leaves, flags) if not f)
    if not stacked_leaves or bucket_bytes <= 0:
        rest = (Bucket(0, -1, -1, rest_bytes),) if rest_bytes else ()
        return BucketPlan(0, 0, rest, 0, rest_bytes)
    n_layers = {x.shape[0] for x in stacked_leaves}
    if len(n_layers) != 1:
        raise ValueError(f"stacked leaves disagree on the layers dim: "
                         f"{sorted(n_layers)}")
    nL = n_layers.pop()
    stacked_bytes = sum(st.leaf_bytes(x) for x in stacked_leaves)
    per_layer = max(1, stacked_bytes // nL)
    lpb = max(1, int(bucket_bytes // per_layer))
    buckets: list[Bucket] = []
    hi = nL
    planned = 0
    while hi > 0:
        lo = max(0, hi - lpb)
        nb = sum((st.leaf_bytes(x) // nL) * (hi - lo) for x in stacked_leaves)
        if lo == 0:   # remainder bucket absorbs the byte-accounting tail too
            nb = stacked_bytes - planned
        buckets.append(Bucket(len(buckets), lo, hi, nb))
        planned += nb
        hi = lo
    if planned != stacked_bytes:
        raise RuntimeError(
            f"bucket plan covers {planned} bytes but the stacked leaves "
            f"hold {stacked_bytes} (n_layers={nL}, layers_per_bucket={lpb})")
    if rest_bytes:
        buckets.append(Bucket(len(buckets), -1, -1, rest_bytes))
    return BucketPlan(nL, lpb, tuple(buckets), stacked_bytes, rest_bytes)


def bucket_indices(flags: list[bool], bucket: Bucket) -> list[int]:
    """Flat-leaf indices participating in one bucket."""
    if bucket.is_rest:
        return [i for i, f in enumerate(flags) if not f]
    return [i for i, f in enumerate(flags) if f]


def slice_leaf(x, lo: int, hi: int):
    """Layer-range slice of a stacked leaf (abstract-shape aware)."""
    if isinstance(x, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((hi - lo,) + tuple(x.shape[1:]), x.dtype)
    return jax.lax.slice_in_dim(x, lo, hi, axis=0)


def bucket_payload(leaves: list, flags: list[bool], bucket: Bucket
                   ) -> tuple[list, list[int]]:
    """(payload leaves, their original flat indices) for one bucket."""
    idx = bucket_indices(flags, bucket)
    if bucket.is_rest:
        return [leaves[i] for i in idx], idx
    return [slice_leaf(leaves[i], bucket.lo, bucket.hi) for i in idx], idx


def aligned_chunks(full_leaves: list, payload: list, idx: list[int],
                   dim_list: list, chunk_bytes: int) -> list:
    """Chunk plan for a bucket payload using each FULL leaf's row geometry,
    so chunk boundaries along the scatter dim — and therefore blockwise-int8
    quantization blocks — match the unbucketed transfer exactly."""
    rows = [st.chunk_rows(full_leaves[i], dim_list[i], chunk_bytes)
            for i in idx]
    sub_dims = [dim_list[i] for i in idx]
    return st.plan_chunks(payload, sub_dims, chunk_bytes, rows=rows)


def bucketed_sync(tree, path: WidePath, *, stacked, dims=None,
                  site_groups=None, tel_prefix: Optional[str] = None,
                  bucket_bytes: Optional[int] = None):
    """Chunked/streamed cross-pod psum of a pytree, one transfer per bucket.

    `stacked` marks the leaves carrying a leading layers dim (pytree of
    bools or flat list); `dims` is the usual per-leaf scatter-dim tree.
    Numerically identical (bit-for-bit, every algo × compression) to
    ``streamed_psum(tree, ...)`` — buckets only re-partition which chunks
    travel together, and chunk geometry within a slice mirrors the full
    leaf's.  Per-bucket plans/timings land under ``{key}/bkt{i}``.
    """
    from repro.core.collectives import streamed_psum
    from repro.sharding import manual_axes_present
    bb = path.bucket_bytes if bucket_bytes is None else int(bucket_bytes)
    if bb <= 0:
        return streamed_psum(tree, path, dims=dims, site_groups=site_groups)
    if path.axis not in manual_axes_present(path.axis):
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    flags = bucketable_flags(leaves, stacked, dims)
    ndims = st.normalize_dims(leaves, dims)
    plan = plan_buckets(leaves, flags, bb)
    key = tel_prefix or path.key
    pieces: dict[int, list] = {i: [] for i in range(len(leaves))}
    out: list = list(leaves)
    for b in plan.buckets:
        payload, idx = bucket_payload(leaves, flags, b)
        if not payload:
            continue
        chunks = aligned_chunks(leaves, payload, idx, ndims, path.chunk_bytes)
        synced = streamed_psum(payload, path, dims=[ndims[i] for i in idx],
                               site_groups=site_groups,
                               tel_key=f"{key}/bkt{b.index}", chunks=chunks)
        for i, s in zip(idx, synced):
            if b.is_rest:
                out[i] = s
            else:
                pieces[i].append((b.lo, s))
    for i, ps in pieces.items():
        if ps:
            out[i] = jnp.concatenate([s for _, s in sorted(ps)], axis=0)
    return jax.tree.unflatten(treedef, out)


def note_bucket_plans(path: WidePath, leaves: list, dims, stacked,
                      bucket_bytes: Optional[int] = None,
                      key: Optional[str] = None,
                      world: int = 1,
                      flags: Optional[list] = None) -> Optional[BucketPlan]:
    """Record per-bucket traffic plans from abstract leaves (build time).

    Mirrors what `bucketed_sync` will note at trace time, so ``MPW.Report``
    shows the ``/bkt{i}`` breakdown even before the first step executes.
    `flags` overrides the bucketability test — the backward-flush path
    buckets *every* stacked leaf with its segment, so its notes pass the
    raw stacked flags.  Returns the plan (None when bucketing is off)."""
    from repro.core import telemetry as tel
    from repro.core.ring import wire_bytes_per_pod
    bb = path.bucket_bytes if bucket_bytes is None else int(bucket_bytes)
    if bb <= 0:
        return None
    if flags is None:
        flags = bucketable_flags(leaves, stacked, dims)
    ndims = st.normalize_dims(leaves, dims)
    plan = plan_buckets(leaves, flags, bb)
    key = key or path.key
    for b in plan.buckets:
        payload, idx = bucket_payload(leaves, flags, b)
        if not payload:
            continue
        chunks = aligned_chunks(leaves, payload, idx, ndims, path.chunk_bytes)
        buckets = st.assign_streams(chunks, path.streams)
        wire = wire_bytes_per_pod(sum(c.nbytes for c in chunks), world,
                                  algo=path.comm.algo,
                                  compress=path.comm.compress)
        tel.note_plan(f"{key}/bkt{b.index}", **st.plan_summary(
            chunks, buckets, path.streams, path.chunk_bytes,
            path.comm.pacing, algo=path.comm.algo, world=world,
            compress=path.comm.compress, wire_bytes=int(round(wire))))
    return plan
