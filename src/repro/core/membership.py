"""Elastic site membership: lease-based liveness, epochs, quorum.

MPWide's flagship runs (CosmoGrid: four supercomputers, two continents)
are long enough that a site *will* drop out mid-run.  PR 6's chaos layer
heals a dead link by re-routing, but the world itself stayed static: a
site that is gone for good kept its slot in every collective.  This
module makes the world elastic:

  * **Leases** — every site's liveness is a lease renewed by deterministic
    heartbeat probes, modeled over the existing :class:`~repro.core.
    topology.LinkProfile` hops on the chaos fault clock (steps — never
    wall time, mpwlint R5).  A probe that times out marks the site
    *suspect*; a fault that outlives ``lease_steps`` evicts it.
  * **Epochs** — the membership version.  Strictly monotonic: every
    *applied* join/leave/evict bumps it by exactly one; observers (the
    Trainer) compare epochs to know when to re-form their world.
  * **Quorum** — a configurable :class:`QuorumPolicy` over the *live*
    members only; evicted and departed sites can never satisfy it.
  * **Rejoin** — an evicted site whose links heal for ``rejoin_after``
    consecutive probes rejoins (catch-up from the replica is the
    Trainer's side — see ``runtime/train_loop.py``).

Probes retry per a :class:`~repro.core.retry.RetryPolicy` before a
failure is reported, so a single modeled blip does not start the lease
clock.  All transitions land in the :class:`~repro.core.chaos.
IncidentLog` (``evict`` / ``join`` / ``leave`` kinds), giving resize
scenarios the same golden-timeline determinism as link faults.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.autotune import simulate_hop_s
from repro.core.retry import PROBE_RETRY, RetryPolicy
from repro.core.topology import Topology

ACTIVE = "active"
SUSPECT = "suspect"      # lease clock running; still a member
EVICTED = "evicted"
LEFT = "left"            # graceful departure (drained, no fault)


@dataclass(frozen=True)
class QuorumPolicy:
    """Membership quorum: how many *live* sites a run needs to proceed.

    `required(total)` is ``max(min_sites, ceil(fraction * total))`` where
    `total` counts every site the membership has ever known — evicted and
    departed sites still raise the bar but can never help clear it.
    """
    min_sites: int = 1
    fraction: float = 0.5

    def __post_init__(self) -> None:
        if self.min_sites < 1:
            raise ValueError(
                f"QuorumPolicy.min_sites must be >= 1, got {self.min_sites}")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"QuorumPolicy.fraction must be in [0, 1], got {self.fraction}")

    def required(self, total: int) -> int:
        return max(self.min_sites, math.ceil(self.fraction * max(0, total)))

    def satisfied(self, live: int, total: int) -> bool:
        return live >= self.required(total)


class SiteMembership:
    """Lease-based liveness over a :class:`~repro.core.topology.Topology`.

    One designated `coordinator` site (the chief, in the workers/ps/chief
    sense) probes every other site once per step along the raw link graph
    — *raw* meaning fault schedules apply but administrative down-links do
    not, so a healed link on an evicted site is visible and drives rejoin.
    All state transitions are deterministic functions of (topology fault
    schedules, step, seed): a resize scenario replays bit-identically.

    The trainer-facing contract is the `epoch`: strictly monotonic,
    bumped by exactly one on every applied join/leave/evict.  Helpers
    (:meth:`member_pod_groups`, :meth:`member_gateways`) give the current
    epoch's collective subgroup in the shape the transfer engines take.
    """

    def __init__(self, topo: Topology, coordinator: str, *,
                 lease_steps: int = 4, rejoin_after: int = 3,
                 quorum: Optional[QuorumPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 probe_bytes: int = 1 << 20, timeout_s: float = 30.0,
                 seed: int = 0, log=None) -> None:
        if coordinator not in [s.name for s in topo.sites]:
            raise KeyError(f"unknown coordinator site {coordinator!r}")
        from repro.core.chaos import get_incident_log
        self.topo = topo
        self.coordinator = coordinator
        self.lease_steps = max(1, int(lease_steps))
        self.rejoin_after = max(1, int(rejoin_after))
        self.quorum = quorum or QuorumPolicy()
        self.retry = retry or PROBE_RETRY
        self.probe_bytes = int(probe_bytes)
        self.timeout_s = float(timeout_s)
        self.seed = int(seed)
        self.log = log or get_incident_log()
        self.epoch = 0
        self._names = [s.name for s in topo.sites]
        self._state = {n: ACTIVE for n in self._names}
        self._suspect_since: dict[str, int] = {}
        self._streak: dict[str, int] = {}       # healthy probes while evicted
        self._last_step: Optional[int] = None

    # -- queries -------------------------------------------------------------
    def state(self, name: str) -> str:
        if name not in self._state:
            raise KeyError(f"unknown site {name!r}")
        return self._state[name]

    def members(self) -> list:
        """Live members, in site order (active + suspect: a suspect site
        still holds its lease)."""
        return [n for n in self._names
                if self._state[n] in (ACTIVE, SUSPECT)]

    def is_member(self, name: str) -> bool:
        return self.state(name) in (ACTIVE, SUSPECT)

    def evicted(self) -> list:
        return [n for n in self._names if self._state[n] == EVICTED]

    def has_quorum(self) -> bool:
        return self.quorum.satisfied(len(self.members()), len(self._names))

    def member_pod_groups(self) -> list:
        """`Topology.pod_groups` restricted to live members — the
        intra-site groups of the current epoch's collective."""
        groups = self.topo.pod_groups()
        return [g for s, g in zip(self.topo.sites, groups)
                if self._state[s.name] in (ACTIVE, SUSPECT)]

    def member_gateways(self) -> list:
        """Gateway pod per live member — the WAN exchange subgroup."""
        return [s.gateway for s in self.topo.sites
                if self._state[s.name] in (ACTIVE, SUSPECT)]

    # -- the per-step liveness pass ------------------------------------------
    def on_step(self, step: int) -> None:
        """Run one probe round at `step` (idempotent per step: the Trainer
        and an attached ChaosMonitor may both drive it)."""
        if self._last_step is not None and step <= self._last_step:
            return
        self._last_step = step
        for name in self._names:
            if name == self.coordinator:
                continue
            st = self._state[name]
            if st == LEFT:
                continue
            alive = self.probe(name, step)
            if st == ACTIVE and not alive:
                self.suspect(name, step, reason="probe-timeout")
            elif st == SUSPECT:
                if alive:
                    self._reinstate(name)
                elif step - self._suspect_since[name] >= self.lease_steps:
                    self.evict(name, step, reason="lease-expired")
            elif st == EVICTED:
                if alive:
                    self._streak[name] = self._streak.get(name, 0) + 1
                    if self._streak[name] >= self.rejoin_after:
                        self.join(name, step)
                else:
                    self._streak[name] = 0

    def probe(self, name: str, step: int) -> bool:
        """One heartbeat: modeled transfer of `probe_bytes` along every hop
        of the raw coordinator->site path, retried per the RetryPolicy.
        True iff some attempt completes under the watchdog timeout."""
        profiles = self._probe_path(name)
        if not profiles:
            return False
        key = self._names.index(name)
        for attempt, _delay in enumerate(self.retry.schedule(key=key)):
            ok = True
            for h, prof in enumerate(profiles):
                secs = simulate_hop_s(
                    self.probe_bytes, prof, step, timeout_s=self.timeout_s,
                    seed=self.seed + 31 * key + 7 * h + 104729 * attempt)
                if secs >= self.timeout_s:
                    ok = False
                    break
            if ok:
                return True
        return False

    def _probe_path(self, name: str) -> list:
        """Hop profiles of the shortest raw-graph path coordinator->site.
        BFS over `Topology.neighbors` (which ignores administrative downs —
        only the fault schedules decide what a probe sees)."""
        if name not in self._state:
            raise KeyError(f"unknown site {name!r}")
        prev: dict[str, str] = {}
        queue = [self.coordinator]
        seen = {self.coordinator}
        while queue:
            u = queue.pop(0)
            if u == name:
                break
            for v in self.topo.neighbors(u):
                if v not in seen:
                    seen.add(v)
                    prev[v] = u
                    queue.append(v)
        if name not in prev:
            return []
        hops = [name]
        while hops[-1] != self.coordinator:
            hops.append(prev[hops[-1]])
        hops.reverse()
        return [self.topo.link(a, b) for a, b in zip(hops, hops[1:])]

    # -- transitions (each applied one bumps the epoch by exactly 1) ---------
    def suspect(self, name: str, step: int, reason: str = "") -> bool:
        """Start `name`'s lease clock (no epoch bump — the site is still a
        member until the lease expires).  Idempotent while suspect."""
        if self.state(name) != ACTIVE or name == self.coordinator:
            return False
        self._state[name] = SUSPECT
        self._suspect_since[name] = step
        self.log.add(step, "detect", name,
                     {"signal": "lease", "reason": reason,
                      "lease_steps": self.lease_steps})
        return True

    def _reinstate(self, name: str) -> None:
        # the lease renewed before expiry: back to active, no epoch change
        self._state[name] = ACTIVE
        self._suspect_since.pop(name, None)

    def evict(self, name: str, step: int, reason: str = "") -> bool:
        """Remove a site whose fault outlived its lease.  Fails its links
        in the topology so route planning and the trainer's world resize
        see the same picture."""
        if name == self.coordinator:
            raise ValueError(
                f"cannot evict the coordinator site {name!r}")
        if self.state(name) not in (ACTIVE, SUSPECT):
            return False
        self._state[name] = EVICTED
        self._suspect_since.pop(name, None)
        self._streak[name] = 0
        self.topo.fail_site(name)
        self.epoch += 1
        self.log.add(step, "evict", name,
                     {"epoch": self.epoch, "reason": reason,
                      "members": self.members()})
        return True

    def leave(self, name: str, step: int) -> bool:
        """Graceful departure: the site drained and said goodbye — same
        resize as an evict, but it will not be probed for rejoin."""
        if name == self.coordinator:
            raise ValueError(
                f"cannot remove the coordinator site {name!r}")
        if self.state(name) not in (ACTIVE, SUSPECT):
            return False
        self._state[name] = LEFT
        self._suspect_since.pop(name, None)
        self.topo.fail_site(name)
        self.epoch += 1
        self.log.add(step, "leave", name,
                     {"epoch": self.epoch, "members": self.members()})
        return True

    def join(self, name: str, step: int) -> bool:
        """A site (re)joins: restore its links, bump the epoch.  The
        trainer notices the epoch change and runs replica catch-up before
        folding the site into the next delta sync."""
        if self.state(name) in (ACTIVE, SUSPECT):
            return False
        self._state[name] = ACTIVE
        self._streak.pop(name, None)
        self.topo.restore_site(name)
        self.epoch += 1
        self.log.add(step, "join", name,
                     {"epoch": self.epoch, "members": self.members()})
        return True
