"""Local-SGD over the WAN: K site-local steps, one cross-site delta sync.

MPWide's CosmoGrid runs paid the WAN price every coupling step.  The
ROADMAP's "asynchronous multi-site training" item asks for the obvious
escape hatch: let every site take ``K`` fully local optimizer steps (the
per-step gradient sync stays inside the site — :func:`~repro.core.
collectives.local_site_allreduce`), then reconcile the sites by shipping
one **model delta** across the WAN:

    merged = anchor + mean_over_member_sites(params_site - anchor)

where `anchor` is the params snapshot at the previous reconciliation.
The delta — not the raw params — crosses the wire because deltas after a
few local steps are small and near-zero-centred, exactly what the int8
block codec quantizes best; and the exchange rides the *same* machinery
as a gradient sync (:func:`~repro.core.collectives.streamed_psum` over
the membership's gateway subgroup: ring/int8/chunking/streams/pacing all
apply).

Elasticity: the member set comes from :class:`~repro.core.membership.
SiteMembership` at the current epoch.  Non-member pods contribute zero
to — and take nothing from — the merge: an evicted site's params freeze
where they were, and :func:`catchup` later clones a survivor's state
onto it when it rejoins.

K = 1 is *defined* as the synchronous path: the Trainer dispatches to
the ordinary per-step hierarchical sync, so local-SGD at K=1 is
bit-identical to the pre-elastic behaviour by construction (no float
re-association to reason about).

Everything here is traced inside the runtime's shard_map (manual over
the DP axes); the numpy `reference_*` twins below are the executable
spec the property tests check the traced versions against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import streamed_psum
from repro.core.path import WidePath
from repro.sharding import manual_axes_present


class LocalSGDController:
    """The K-step cadence: which steps are sync steps.

    Steps are 0-based; with ``k=4`` the sync lands on steps 3, 7, 11, ...
    — i.e. *after* every K-th local step, so a run of N = m*K steps does
    exactly m reconciliations.  ``k <= 1`` means every step syncs (the
    synchronous path; the Trainer never builds a delta-sync for it).
    """

    def __init__(self, k: int = 1) -> None:
        self.k = max(1, int(k))

    @property
    def enabled(self) -> bool:
        return self.k > 1

    def is_sync_step(self, step: int) -> bool:
        return self.k <= 1 or (step + 1) % self.k == 0


def delta_sync(params, anchor, path: WidePath, *, dims=None,
               site_groups=None, member_pods=None, member_gateways=None):
    """Traced body of one cross-site reconciliation (call inside shard_map).

    `member_pods` / `member_gateways` / `site_groups` are trace-time
    constants from the membership at the current epoch (the Trainer
    re-traces on every epoch change).  Stages:

      1. per-pod f32 delta against the anchor, masked so only *member
         gateways* contribute (each member site's pods are bit-identical
         after K local steps, so the gateway's delta is the site's);
      2. :func:`streamed_psum` of the masked deltas with
         ``subgroup=member_gateways`` — the WAN exchange, on the path's
         ring/int8/chunk/stream knobs, accounted under ``{key}/delta``;
      3. re-mask to member gateways (ring lanes outside the subgroup come
         back holding garbage) and an intra-site grouped psum broadcasts
         each site's gateway value to its pods;
      4. merge ``anchor + sum/n_members`` on member pods only — evicted
         and departed pods keep their local params untouched.
    """
    if path.axis not in manual_axes_present(path.axis):
        return params
    groups = [list(g) for g in site_groups]
    gw = [int(g) for g in member_gateways]
    n = len(gw)
    idx = jax.lax.axis_index(path.axis)
    is_m = jnp.any(idx == jnp.asarray(sorted(member_pods), jnp.int32))
    is_gw = jnp.any(idx == jnp.asarray(gw, jnp.int32))

    def to_delta(p, a):
        d = p.astype(jnp.float32) - a.astype(jnp.float32)
        return jnp.where(is_gw, d, jnp.zeros_like(d))

    masked = jax.tree.map(to_delta, params, anchor)
    exchanged = streamed_psum(masked, path, dims=dims,
                              subgroup=gw, tel_key=f"{path.key}/delta")
    gw_only = jax.tree.map(lambda d: jnp.where(is_gw, d, jnp.zeros_like(d)),
                           exchanged)
    summed = jax.tree.map(
        lambda d: jax.lax.psum(d, path.axis, axis_index_groups=groups),
        gw_only)

    def merge(p, a, s):
        m = a.astype(jnp.float32) + s / n
        return jnp.where(is_m, m, p.astype(jnp.float32)).astype(p.dtype)

    return jax.tree.map(merge, params, anchor, summed)


def catchup(params, path: WidePath, *, source_pod: int, target_pods,
            site_groups=None):
    """Clone a survivor's params onto rejoining pods (call inside shard_map).

    The rejoined site missed every reconciliation while evicted; before it
    can contribute a delta it must share the survivors' anchor.  On a real
    deployment this is the replica checkpoint restore (``failover_to_
    replica``); inside the emulated mesh it is a broadcast: mask params to
    `source_pod` (a surviving gateway), psum over the pod axis, and adopt
    the result on `target_pods` only.  Survivors' params pass through
    bit-untouched.
    """
    if path.axis not in manual_axes_present(path.axis):
        return params
    del site_groups  # broadcast is axis-wide; kept for signature symmetry
    idx = jax.lax.axis_index(path.axis)
    is_src = idx == jnp.int32(source_pod)
    is_tgt = jnp.any(idx == jnp.asarray(sorted(target_pods), jnp.int32))

    def clone(p):
        src = jnp.where(is_src, p.astype(jnp.float32), jnp.zeros_like(p, jnp.float32))
        bcast = jax.lax.psum(src, path.axis)
        return jnp.where(is_tgt, bcast, p.astype(jnp.float32)).astype(p.dtype)

    return jax.tree.map(clone, params)


# ---------------------------------------------------------------------------
# numpy reference twins (the property-test spec)
# ---------------------------------------------------------------------------

def reference_delta_merge(anchor, site_params, members):
    """What one reconciliation does, per site, in plain numpy.

    `site_params` maps site name -> params array; `members` is the live
    member list.  Returns the post-sync params per site: members get
    ``anchor + mean(member deltas)``, non-members keep their own.
    """
    deltas = [np.asarray(site_params[m], np.float32) - np.asarray(anchor, np.float32)
              for m in members]
    merged = np.asarray(anchor, np.float32) + np.mean(deltas, axis=0)
    return {s: (merged if s in members else np.asarray(p))
            for s, p in site_params.items()}


def reference_wan_bytes(n_params: int, steps: int, k: int, n_sites: int,
                        bytes_per_el: int = 4) -> int:
    """Modeled cross-site WAN bytes of a run: one gateway-subgroup
    exchange of the full model every K steps (ring: ~2 passes of the
    payload per member), versus every step when k=1."""
    syncs = steps // max(1, k)
    per_sync = 2 * (n_sites - 1) / max(1, n_sites) * n_params * bytes_per_el
    return int(syncs * per_sync)
