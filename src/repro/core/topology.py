"""Multi-site WAN topology: sites, heterogeneous links, route planning, and
the store-and-forward Forwarder (the paper's mechanism for connecting
supercomputers *without direct connectivity* — the CosmoGrid runs spanned up
to four machines on two continents by relaying through intermediate hosts).

Mapping onto the mesh: each *site* owns one or more coordinates on the "pod"
mesh axis (its pods); links connect sites with per-hop :class:`LinkProfile`s
(distinct alpha/beta/window *and* distinct comm knobs — the paper tunes each
leg separately: >=32 streams on the WAN leg, 1 on the LAN leg of the same
route).  A :class:`Route` is a site sequence with per-hop profiles; the
:class:`Forwarder` compiles it into a multi-hop :class:`~repro.core.path.WidePath`
whose transfers store-and-forward hop by hop (`repro.core.cycle.forward`).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

from repro.configs.base import CommConfig
from repro.core.path import Hop, LinkSpec, WidePath


@dataclass(frozen=True)
class Fault:
    """One scheduled fault on a link.  Everything is derived from the
    schedule fields plus `seed`, so a fault run replays bit-identically:
    the chaos suite's scenarios are scripts, not dice rolls.

    Kinds:
      * ``"drop"``      — the link is dead for steps in [start, stop).
      * ``"degrade"``   — bandwidth is multiplied by `factor` and a
                          deterministic `error_rate` fraction of chunks is
                          corrupted for steps in [start, stop).
      * ``"partition"`` — the link is dead *and* `site` is declared
                          unreachable (whole-site loss: the failover case).
    """
    kind: str
    start: int = 0                 # first step the fault is active
    stop: Optional[int] = None     # first healed step (None: never heals)
    factor: float = 1.0            # degrade: bandwidth multiplier in (0, 1]
    error_rate: float = 0.0        # degrade: fraction of chunks corrupted
    site: Optional[str] = None     # partition: the site cut off
    seed: int = 0                  # drives which chunks corrupt

    def active(self, step: int) -> bool:
        return step >= self.start and (self.stop is None or step < self.stop)


@dataclass(frozen=True)
class LinkHealth:
    """A link's effective condition at one step: the fold of every active
    :class:`Fault` on its profile."""
    alive: bool = True
    bandwidth_factor: float = 1.0
    error_rate: float = 0.0
    partitioned: tuple = ()        # sites the active faults cut off
    seed: int = 0

    @property
    def faulty(self) -> bool:
        return (not self.alive or self.bandwidth_factor < 1.0
                or self.error_rate > 0.0 or bool(self.partitioned))


@dataclass(frozen=True)
class LinkProfile:
    """One heterogeneous WAN hop: the alpha-beta/window link model plus the
    comm knobs (streams / chunk / pacing) transfers over this hop should run
    with.  `LinkSpec` is the bare physics; the profile adds the tuning."""
    name: str
    latency_s: float              # alpha: one-way latency
    bandwidth_Bps: float          # beta^-1: attainable path capacity
    window: Optional[float] = None  # per-stream in-flight cap (TCP window)
    streams: int = 32
    chunk_mb: float = 8.0
    pacing: float = 1.0
    faults: tuple = field(default=())   # tuple[Fault, ...], step-scheduled

    @property
    def spec(self) -> LinkSpec:
        return LinkSpec(self.name, self.latency_s, self.bandwidth_Bps,
                        self.window)

    def comm(self, base: Optional[CommConfig] = None) -> CommConfig:
        base = base or CommConfig()
        return replace(base, streams=self.streams, chunk_mb=self.chunk_mb,
                       pacing=self.pacing)

    # -- fault schedule ------------------------------------------------------
    def with_fault(self, fault: Fault) -> "LinkProfile":
        return replace(self, faults=self.faults + (fault,))

    def drop(self, at_step: int, until: Optional[int] = None,
             seed: int = 0) -> "LinkProfile":
        """Schedule the link to die at `at_step` (heal at `until`, if set)."""
        return self.with_fault(Fault("drop", start=at_step, stop=until,
                                     seed=seed))

    def degrade(self, factor: float, window: tuple,
                error_rate: float = 0.0, seed: int = 0) -> "LinkProfile":
        """Scale bandwidth by `factor` over steps [window[0], window[1]),
        corrupting a deterministic `error_rate` fraction of chunks."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"degrade factor must be in (0, 1], got {factor}")
        return self.with_fault(Fault("degrade", start=window[0],
                                     stop=window[1], factor=factor,
                                     error_rate=error_rate, seed=seed))

    def partition(self, site: str, at_step: int = 0,
                  until: Optional[int] = None, seed: int = 0) -> "LinkProfile":
        """Schedule a partition: the link dies and `site` is declared lost
        (distinguishes re-routable hop death from whole-site failover)."""
        return self.with_fault(Fault("partition", start=at_step, stop=until,
                                     site=site, seed=seed))

    def health(self, step: int) -> LinkHealth:
        """Fold every fault active at `step` into one :class:`LinkHealth`."""
        alive, factor, err = True, 1.0, 0.0
        parts: list = []
        seed = 0
        for f in self.faults:
            if not f.active(step):
                continue
            seed = (seed * 1000003) ^ (f.seed + 77 * f.start + hash(f.kind))
            if f.kind == "drop":
                alive = False
            elif f.kind == "partition":
                alive = False
                if f.site:
                    parts.append(f.site)
            elif f.kind == "degrade":
                factor = min(factor, f.factor)
                err = max(err, f.error_rate)
            else:
                raise ValueError(f"unknown fault kind {f.kind!r}")
        return LinkHealth(alive, factor, err, tuple(parts), seed & 0x7FFFFFFF)

    def transfer_s(self, nbytes: float, step: Optional[int] = None) -> float:
        """Modeled seconds to move `nbytes` over this hop (stream-aware:
        window-capped links deliver streams * window/RTT up to capacity).
        With `step`, the fault schedule applies: a dead link models as
        ``inf``; a degraded one as proportionally less capacity."""
        bw_factor = 1.0
        if step is not None:
            h = self.health(step)
            if not h.alive:
                return math.inf
            bw_factor = h.bandwidth_factor
        if self.window:
            per_stream = self.window / (2 * self.latency_s)
            bw = min(self.bandwidth_Bps, max(1, self.streams) * per_stream)
        else:
            bw = self.bandwidth_Bps
        return self.latency_s + nbytes / max(1.0, bw * bw_factor)


# intra-site fabric: pods at one site talk over the local interconnect
LAN = LinkProfile("lan", 50e-6, 6.25e9, streams=1, chunk_mb=64.0)


@dataclass(frozen=True)
class Site:
    """A named site owning contiguous coordinates on the pod axis."""
    name: str
    pods: tuple = (0,)

    @property
    def gateway(self) -> int:
        """The pod that fronts this site's WAN traffic (paper: the Forwarder
        host / the one machine with external connectivity)."""
        return self.pods[0]


@dataclass(frozen=True)
class Route:
    """A planned path through the topology: the site sequence, the profile of
    each hop, and the pod-axis shift each hop executes as."""
    sites: tuple                    # tuple[str, ...], len n+1
    profiles: tuple                 # tuple[LinkProfile, ...], len n
    shifts: tuple                   # tuple[int, ...], len n

    @property
    def n_hops(self) -> int:
        return len(self.profiles)

    @property
    def bottleneck(self) -> int:
        """Index of the slowest hop (lowest bandwidth, then highest alpha)."""
        return min(range(self.n_hops),
                   key=lambda i: (self.profiles[i].bandwidth_Bps,
                                  -self.profiles[i].latency_s))

    def as_hops(self, base_comm: Optional[CommConfig] = None,
                bottleneck_comm: Optional[CommConfig] = None) -> tuple:
        """Compile to :class:`~repro.core.path.Hop`s.  Each hop takes its
        profile's comm knobs; `bottleneck_comm` (e.g. the RunConfig's tuned
        comm) overrides the slow hop — the slot the autotuner drives."""
        hops = []
        for i, (prof, shift) in enumerate(zip(self.profiles, self.shifts)):
            comm = prof.comm(base_comm)
            if bottleneck_comm is not None and i == self.bottleneck:
                comm = bottleneck_comm
            hops.append(Hop(name=f"{self.sites[i]}->{self.sites[i + 1]}",
                            link=prof.spec, comm=comm, shift=shift))
        return tuple(hops)

    def modeled_s(self, nbytes: float, store_and_forward: bool = True) -> float:
        """Seconds to relay `nbytes` end to end.  Store-and-forward: each
        relay holds the full message before sending (serial hops — the
        paper's Forwarder semantics); else the pipeline bound (bottleneck
        bandwidth + per-hop latencies)."""
        if store_and_forward:
            return sum(p.transfer_s(nbytes) for p in self.profiles)
        alphas = sum(p.latency_s for p in self.profiles)
        return alphas + self.profiles[self.bottleneck].transfer_s(nbytes) \
            - self.profiles[self.bottleneck].latency_s

    def describe(self) -> str:
        legs = [self.sites[0]]
        for s, p in zip(self.sites[1:], self.profiles):
            legs.append(f"--[{p.name}]--> {s}")
        return " ".join(legs)


class Topology:
    """A graph of sites and heterogeneous links with route planning.

    Routing metrics:
      * ``"hops"``    — fewest hops (BFS).
      * ``"latency"`` — minimum summed one-way latency (Dijkstra on alpha).
      * ``"width"``   — widest path: maximize the bottleneck bandwidth
                        (Dijkstra on -min(bandwidth)); what a bulk DataGather
                        mirror wants.
    """

    def __init__(self) -> None:
        self._sites: dict[str, Site] = {}
        self._links: dict[tuple, LinkProfile] = {}
        self._down: set[tuple] = set()       # directed (a, b) pairs taken out
        self._next_pod = 0

    # -- construction --------------------------------------------------------
    def add_site(self, name: str, pods: Optional[Sequence[int]] = None,
                 n_pods: int = 1) -> Site:
        if name in self._sites:
            raise ValueError(f"duplicate site {name!r}")
        if pods is None:
            pods = tuple(range(self._next_pod, self._next_pod + n_pods))
        site = Site(name, tuple(pods))
        taken = {p for s in self._sites.values() for p in s.pods}
        if taken & set(site.pods):
            raise ValueError(f"pods {taken & set(site.pods)} already assigned")
        self._sites[name] = site
        self._next_pod = max([self._next_pod, *[p + 1 for p in site.pods]])
        return site

    def connect(self, a: str, b: str, profile: LinkProfile,
                bidirectional: bool = True) -> None:
        for n in (a, b):
            if n not in self._sites:
                raise KeyError(f"unknown site {n!r}")
        self._links[(a, b)] = profile
        if bidirectional:
            self._links[(b, a)] = profile

    # -- link liveness (the chaos layer drives these) ------------------------
    def fail_link(self, a: str, b: str, bidirectional: bool = True) -> None:
        """Take the a->b link out of route planning (the detector's response
        to a dead hop).  The profile stays registered for later restore."""
        if (a, b) not in self._links:
            raise KeyError(f"no link {a!r} -> {b!r}")
        self._down.add((a, b))
        if bidirectional and (b, a) in self._links:
            self._down.add((b, a))

    def restore_link(self, a: str, b: str, bidirectional: bool = True) -> None:
        self._down.discard((a, b))
        if bidirectional:
            self._down.discard((b, a))

    def fail_site(self, name: str) -> list:
        """Whole-site loss: every link touching `name` goes down.  Returns
        the directed pairs taken out."""
        if name not in self._sites:
            raise KeyError(f"unknown site {name!r}")
        hit = [(a, b) for (a, b) in self._links if name in (a, b)]
        self._down.update(hit)
        return hit

    def restore_site(self, name: str) -> list:
        """Undo :meth:`fail_site`: links touching `name` come back, except
        those whose *other* endpoint is itself still failed (all of that
        site's links down) — a rejoining site must not silently resurrect a
        still-dead peer.  Returns the directed pairs restored."""
        if name not in self._sites:
            raise KeyError(f"unknown site {name!r}")

        def site_dead(s: str) -> bool:
            touching = [(a, b) for (a, b) in self._links if s in (a, b)]
            return bool(touching) and all(p in self._down for p in touching)

        dead_peers = {s for s in self._sites
                      if s != name and site_dead(s)}
        hit = [(a, b) for (a, b) in self._down
               if name in (a, b) and not ({a, b} & dead_peers)]
        self._down.difference_update(hit)
        return hit

    def is_down(self, a: str, b: str) -> bool:
        return (a, b) in self._down

    def down_links(self) -> frozenset:
        return frozenset(self._down)

    # -- accessors -----------------------------------------------------------
    def site(self, name: str) -> Site:
        return self._sites[name]

    @property
    def sites(self) -> list:
        return list(self._sites.values())

    @property
    def n_pods(self) -> int:
        return 1 + max(p for s in self._sites.values() for p in s.pods)

    def link(self, a: str, b: str) -> Optional[LinkProfile]:
        return self._links.get((a, b))

    def neighbors(self, name: str) -> list:
        return [b for (a, b) in self._links if a == name]

    def pod_groups(self) -> list:
        """Site pod groups covering every pod — `axis_index_groups` for the
        intra-site reduction stage of the hierarchical collective."""
        groups = [list(s.pods) for s in self._sites.values()]
        covered = sorted(p for g in groups for p in g)
        if covered != list(range(len(covered))):
            raise ValueError(f"site pods must tile the pod axis, got {covered}")
        return groups

    def gateways(self) -> list:
        return [s.gateway for s in self._sites.values()]

    def site_of_pod(self, pod: int) -> Site:
        for s in self._sites.values():
            if pod in s.pods:
                return s
        raise KeyError(f"pod {pod} belongs to no site")

    # -- route planning ------------------------------------------------------
    def route(self, src: str, dst: str, metric: str = "latency",
              avoid: frozenset = frozenset()) -> Route:
        """Plan a route src -> dst; raises KeyError when disconnected.

        ``avoid`` holds extra directed ``(a, b)`` pairs treated as down for
        this search only — callers (e.g. the serving tier's reroute path)
        can steer around a faulted hop without mutating the topology.
        """
        if metric not in ("hops", "latency", "width"):
            raise ValueError(f"unknown metric {metric!r}")
        for n in (src, dst):
            if n not in self._sites:
                raise KeyError(f"unknown site {n!r}")
        if src == dst:
            # a 0-hop Route would silently degrade (WidePath.hops=() means
            # "implicit single hop", i.e. a real ring shift, not a no-op)
            raise ValueError(f"route {src} -> {dst}: src and dst coincide")
        prev = self._search(src, dst, metric, avoid)
        if dst not in prev:
            raise KeyError(f"no route {src} -> {dst}")
        names = [dst]
        while names[-1] != src:
            names.append(prev[names[-1]])
        names.reverse()
        profiles, shifts = [], []
        for a, b in zip(names, names[1:]):
            profiles.append(self._links[(a, b)])
            shifts.append(self._sites[b].gateway - self._sites[a].gateway)
        return Route(tuple(names), tuple(profiles), tuple(shifts))

    def _search(self, src: str, dst: str, metric: str,
                avoid: frozenset = frozenset()) -> dict:
        # Dijkstra over (cost, site); "hops" degenerates to BFS via unit cost
        def edge_cost(prof: LinkProfile) -> float:
            if metric == "hops":
                return 1.0
            if metric == "latency":
                return prof.latency_s
            return 0.0                      # width handled via bottleneck key

        def merge(acc: float, prof: LinkProfile) -> float:
            if metric == "width":           # cost = -bottleneck bandwidth
                return max(acc, -prof.bandwidth_Bps)
            return acc + edge_cost(prof)

        start_cost = -float("inf") if metric == "width" else 0.0
        best = {src: start_cost}
        prev: dict[str, str] = {}
        q: list = [(start_cost, src)]
        while q:
            cost, u = heapq.heappop(q)
            if cost > best.get(u, float("inf")):
                continue
            if u == dst:
                break
            for (a, b), prof in self._links.items():
                if a != u or (a, b) in self._down or (a, b) in avoid:
                    continue
                c = merge(cost, prof)
                if c < best.get(b, float("inf")):
                    best[b] = c
                    prev[b] = u
                    heapq.heappush(q, (c, b))
        return prev


class Forwarder:
    """The paper's Forwarder: relays traffic between sites with no direct
    connectivity by composing per-hop :class:`~repro.core.path.WidePath`
    transfers with store-and-forward semantics.

    Holds the planned :class:`Route` and the compiled multi-hop ``path``;
    calling the forwarder inside the manual-DP shard_map relays a pytree
    end to end (each hop re-chunks with its own knobs — a relay site holds
    the full message before sending it on, as the real Forwarder process
    does with its receive/send buffer pair).
    """

    def __init__(self, topo: Topology, src: str, dst: str, *,
                 metric: str = "latency", axis: str = "pod",
                 comm: Optional[CommConfig] = None,
                 name: Optional[str] = None) -> None:
        self.topo = topo
        self.src, self.dst = src, dst
        self.route = topo.route(src, dst, metric)
        base = WidePath(axis=axis, comm=comm or CommConfig(),
                        name=name or f"fwd-{src}-{dst}")
        self.path = base.with_hops(self.route.as_hops(base_comm=comm))

    def __call__(self, tree, dims=None):
        # note: `from repro.core import cycle` would resolve to the cycle()
        # *function* the package re-exports, not the module
        from repro.core.cycle import forward
        return forward(tree, self.path, dims=dims)

    def modeled_s(self, nbytes: float) -> float:
        return self.route.modeled_s(nbytes)

    def describe(self) -> str:
        return self.route.describe()


def cosmogrid_topology(pods_per_site: int = 1,
                       backup_links: bool = False) -> Topology:
    """The 4-site CosmoGrid-style testbed (arXiv:1101.0605): a star around
    Amsterdam — the 10 Gbps light path to Tokyo, and regular internet to
    Espoo and Edinburgh.  Tokyo<->Espoo has *no* direct link: reaching it is
    the paper's Forwarder scenario (2 hops via Amsterdam).

    `backup_links=True` adds a slow commodity-internet Tokyo<->Edinburgh
    link (the chaos scenarios' detour): when the Amsterdam-Tokyo light path
    dies, routing can heal around it instead of declaring Tokyo lost."""
    t = Topology()
    for name in ("amsterdam", "tokyo", "espoo", "edinburgh"):
        t.add_site(name, n_pods=pods_per_site)
    t.connect("amsterdam", "tokyo",
              LinkProfile("ams-tokyo-lightpath", 135e-3, 1.25e9,
                          window=4 << 20, streams=16, chunk_mb=16.0))
    t.connect("amsterdam", "espoo",
              LinkProfile("ams-espoo", 22e-3, 115e6, window=64 << 10,
                          streams=64, chunk_mb=8.0))
    t.connect("amsterdam", "edinburgh",
              LinkProfile("ams-edinburgh", 14e-3, 90e6, window=64 << 10,
                          streams=64, chunk_mb=8.0))
    if backup_links:
        t.connect("tokyo", "edinburgh",
                  LinkProfile("tokyo-edinburgh-backup", 160e-3, 60e6,
                              window=64 << 10, streams=64, chunk_mb=4.0))
    return t
