"""Paper-faithful MPW_* API facade (Table 2 of the paper).

MPWide exposes a tiny C-style API; higher-level services are asked to
integrate it as a module.  This facade offers the same verbs over mesh-axis
paths so coupled-application code (examples/couple_apps.py) reads like an
MPWide program.  All calls are jit-compatible and must run inside the
manual-DP shard_map context the runtime establishes.

Differences from the C++ API, by necessity of the platform:
  * buffers are pytrees of fixed-shape arrays, not char*: XLA requires
    static shapes.  MPW_DSendRecv ("unknown size using caching") keeps the
    paper's *interface* by carrying (max-size buffer, length) pairs — the
    cache is the compiled executable for each max size.
  * non-blocking sends return a token; MPW_Wait orders against it via
    optimization_barrier (the scheduler overlaps in between, which is
    exactly what the paper's ISendRecv achieves with threads).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig
from repro.core import cycle as cy
from repro.core.autotune import autotune_path
from repro.core.collectives import streamed_psum
from repro.core.path import INTERPOD, WidePath


@dataclass
class _PathState:
    path: WidePath


@dataclass
class MPW:
    """One MPWide session (MPW_Init .. MPW_Finalize)."""
    paths: dict[int, _PathState] = field(default_factory=dict)
    _next: int = 0

    # -- lifecycle ---------------------------------------------------------
    @staticmethod
    def Init() -> "MPW":
        return MPW()

    def Finalize(self) -> None:
        self.paths.clear()

    # -- path management ----------------------------------------------------
    def CreatePath(self, axis: str = "pod", nstreams: int = 32,
                   link=INTERPOD, comm: Optional[CommConfig] = None) -> int:
        comm = comm or CommConfig(streams=nstreams)
        pid = self._next
        self._next += 1
        self.paths[pid] = _PathState(WidePath(axis=axis, comm=comm, link=link))
        return pid

    def DestroyPath(self, pid: int) -> None:
        del self.paths[pid]

    def path(self, pid: int) -> WidePath:
        return self.paths[pid].path

    # -- tuning knobs (paper names) ------------------------------------------
    def setChunkSize(self, pid: int, nbytes: int) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(chunk_mb=nbytes / (1 << 20))

    def setPacingRate(self, pid: int, rate: float) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(pacing=rate)

    def setWin(self, pid: int, nbytes: int) -> None:
        # TCP window -> chunk payload sizing against the link BDP
        self.setChunkSize(pid, nbytes)

    def setAutoTuning(self, pid: int, enabled: bool,
                      payload_bytes: Optional[int] = None) -> None:
        p = self.paths[pid].path.with_(autotune=enabled)
        if enabled and payload_bytes:
            p = autotune_path(p, payload_bytes)
        self.paths[pid].path = p

    # -- data movement ------------------------------------------------------
    def Send(self, pid: int, tree, shift: int = 1):
        """Send to the ring neighbour; returns what the neighbour sent us
        (SPMD sends are symmetric — this is MPW_SendRecv's send half)."""
        return cy.pod_shift(tree, self.path(pid), shift)

    def Recv(self, pid: int, tree, shift: int = 1):
        return cy.pod_shift(tree, self.path(pid), -shift)

    def SendRecv(self, pid: int, tree, shift: int = 1):
        return cy.sendrecv(tree, self.path(pid), shift)

    def DSendRecv(self, pid: int, tree, length: jax.Array, max_len: int,
                  shift: int = 1):
        """Unknown-size exchange: ships (buffer, length); receiver masks."""
        payload = {"buf": tree, "len": jnp.asarray(length, jnp.int32)}
        out = cy.sendrecv(payload, self.path(pid), shift)
        return out["buf"], out["len"]

    def ISendRecv(self, pid: int, tree, shift: int = 1):
        """Non-blocking exchange: returns (result, token). The result must
        not be consumed before MPW_Wait(token) orders it."""
        out = cy.sendrecv(tree, self.path(pid), shift)
        token = jax.tree.leaves(out)[0].reshape(-1)[0].astype(jnp.float32)
        return out, token

    def Has_NBE_Finished(self, token) -> bool:
        # SPMD collectives complete within the step; the token exists to
        # order consumers (paper semantics: poll -> always true by Wait time)
        return True

    def Wait(self, value, token):
        out, _ = jax.lax.optimization_barrier((value, token))
        return out

    def AllReduce(self, pid: int, tree, dims=None):
        """Not in the C API (MPWide users hand-roll it); provided because
        gradient sync is the dominant use in this framework."""
        return streamed_psum(tree, self.path(pid), dims=dims)

    def Cycle(self, recv_pid: int, send_pid: int, tree):
        return cy.cycle(self.path(recv_pid), self.path(send_pid), tree)

    def Relay(self, pid: int, tree, hops: int = 1):
        return cy.relay(tree, self.path(pid), hops)

    def Barrier(self):
        return cy.barrier()

    @staticmethod
    def DNSResolve(host: str) -> str:
        """Mesh-axis 'addressing': pods are coordinates, not hostnames."""
        return host
