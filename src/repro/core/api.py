"""Paper-faithful MPW_* API facade (Table 2 of the paper).

MPWide exposes a tiny C-style API; higher-level services are asked to
integrate it as a module.  This facade offers the same verbs over mesh-axis
paths so coupled-application code (examples/couple_apps.py) reads like an
MPWide program.  Message-passing calls are jit-compatible and must run
inside the manual-DP shard_map context the runtime establishes; the file
verbs (FileSend/FileRecv/FileCopy/DataGather — the paper's mpw-cp tool and
DataGather service) are host-side and run anywhere.

Differences from the C++ API, by necessity of the platform:
  * buffers are pytrees of fixed-shape arrays, not char*: XLA requires
    static shapes.  MPW_DSendRecv ("unknown size using caching") keeps the
    paper's *interface* by carrying (max-size buffer, length) pairs — the
    cache is the compiled executable for each max size.
  * non-blocking sends return a token; MPW_Wait orders against it via
    optimization_barrier (the scheduler overlaps in between, which is
    exactly what the paper's ISendRecv achieves with threads).
"""
from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig
from repro.core import cycle as cy
from repro.core.autotune import OnlineTuner, RouteTuner, autotune_path
from repro.core.collectives import streamed_psum
from repro.core.path import INTERPOD, Hop, WidePath
from repro.core.telemetry import get_telemetry


@dataclass
class _PathState:
    path: WidePath
    tuner: Optional[OnlineTuner] = None        # single-link paths
    route_tuner: Optional[RouteTuner] = None   # multi-hop paths (per hop)
    batcher: Optional[object] = None           # ContinuousBatcher, via Serve()


# process-wide path ids: telemetry keys ("mpw{pid}:{link}") must stay unique
# across MPW sessions, or a new session's stats would merge into an old
# session's registry slot
_PATH_IDS = itertools.count()


@dataclass
class MPW:
    """One MPWide session (MPW_Init .. MPW_Finalize)."""
    paths: dict[int, _PathState] = field(default_factory=dict)
    membership: Optional[object] = None   # SiteMembership, via Membership()

    # -- lifecycle ---------------------------------------------------------
    @staticmethod
    def Init() -> "MPW":
        return MPW()

    def Finalize(self) -> None:
        self.paths.clear()

    # -- path management ----------------------------------------------------
    def CreatePath(self, axis: str = "pod", nstreams: int = 32,
                   link=INTERPOD, comm: Optional[CommConfig] = None) -> int:
        comm = comm or CommConfig(streams=nstreams)
        pid = next(_PATH_IDS)
        self.paths[pid] = _PathState(
            WidePath(axis=axis, comm=comm, link=link, name=f"mpw{pid}"))
        return pid

    def CreatePathVariadic(self, axis: str = "pod",
                           streams_per_hop=(32,), links=None,
                           comm: Optional[CommConfig] = None) -> int:
        """MPW_CreatePathVariadicStreams: a path whose legs each get their
        own stream count (paper: per-leg tuning of a Forwarder route).

        `links` is an optional per-hop sequence of LinkSpecs (or topology
        LinkProfiles via `.spec`); hops default to consecutive +1 ring
        shifts.  A single-entry `streams_per_hop` degrades to CreatePath.
        """
        comm = comm or CommConfig()
        links = list(links) if links is not None else [INTERPOD] * len(streams_per_hop)
        if len(links) != len(streams_per_hop):
            raise ValueError(
                f"CreatePathVariadic: streams_per_hop has "
                f"{len(streams_per_hop)} entr{'y' if len(streams_per_hop) == 1 else 'ies'} "
                f"but links has {len(links)} — they must align per hop")
        pid = next(_PATH_IDS)
        hops = tuple(
            Hop(name=f"hop{i}-{lk.name}", link=lk,
                comm=replace(comm, streams=int(s)), shift=1)
            for i, (s, lk) in enumerate(zip(streams_per_hop, links)))
        base = WidePath(axis=axis, comm=comm, name=f"mpw{pid}")
        self.paths[pid] = _PathState(base.with_hops(hops))
        return pid

    def CreateForwarder(self, topo, src: str, dst: str, *,
                        metric: str = "latency",
                        comm: Optional[CommConfig] = None) -> int:
        """Set up the paper's Forwarder: plan a route src -> dst through the
        topology (relaying across intermediate sites when there is no direct
        link) and register it as a multi-hop path.  `Relay`/`Forward` then
        store-and-forward along it; `PathStats` reports every hop."""
        from repro.core.topology import Forwarder
        pid = next(_PATH_IDS)
        fwd = Forwarder(topo, src, dst, metric=metric, comm=comm,
                        name=f"mpw{pid}-{src}-{dst}")
        self.paths[pid] = _PathState(fwd.path)
        return pid

    def Forward(self, pid: int, tree, dims=None, reverse: bool = False):
        """Relay a payload along the path's route, store-and-forward (the
        Forwarder data plane; single-link paths degrade to one shift)."""
        return cy.forward(tree, self.path(pid), dims=dims, reverse=reverse)

    def Route(self, pid: int) -> list:
        """Hop descriptions of a path's route (name, link, shift, knobs)."""
        return [{"hop": i, "name": h.name, "link": h.link.name,
                 "shift": h.shift, "streams": h.streams,
                 "chunk_mb": h.comm.chunk_mb, "pacing": h.comm.pacing}
                for i, h in enumerate(self.path(pid).route)]

    def DestroyPath(self, pid: int) -> None:
        del self.paths[pid]

    def path(self, pid: int) -> WidePath:
        return self.paths[pid].path

    # -- tuning knobs (paper names) ------------------------------------------
    def setChunkSize(self, pid: int, nbytes: int) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(chunk_mb=nbytes / (1 << 20))

    def setPacingRate(self, pid: int, rate: float) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(pacing=rate)

    def setAlgorithm(self, pid: int, algo: str) -> None:
        """Select the cross-pod all-reduce algorithm (beyond the C API):
        "psum" (one collective per chunk; gather-based when compressed),
        "ring" / "ring2" (bandwidth-optimal ppermute rings — see
        repro/core/ring.py)."""
        from repro.core.ring import ALGOS
        if algo not in ALGOS:
            raise ValueError(f"unknown algo {algo!r}; have {ALGOS}")
        self.paths[pid].path = self.paths[pid].path.with_(algo=algo)

    def setBucketSize(self, pid: int, nbytes: int) -> None:
        """Select the gradient-sync bucket size (beyond the C API): > 0
        splits all-reduce payloads into ~nbytes buckets along the stacked
        `layers` dim so transfers flush during backprop and the exposed
        tail is consumed bucket-by-bucket (repro/core/buckets.py); 0
        restores one whole-tree sync."""
        if nbytes < 0:
            raise ValueError(f"bucket size must be >= 0, got {nbytes}")
        self.paths[pid].path = self.paths[pid].path.with_(
            bucket_mb=nbytes / (1 << 20))

    def setWin(self, pid: int, nbytes: int) -> None:
        # TCP window -> chunk payload sizing against the link BDP
        self.setChunkSize(pid, nbytes)

    def setLocalSteps(self, pid: int, k: int) -> None:
        """Select the local-SGD cadence (beyond the C API): K > 1 keeps
        each step's gradient sync inside the site and ships a model delta
        across the WAN only every K-th step (repro/core/localsgd.py); 1
        restores the fully synchronous sync.  A Trainer built from this
        path's CommConfig picks the cadence up at build time."""
        if k < 1:
            raise ValueError(f"local steps must be >= 1, got {k}")
        self.paths[pid].path = self.paths[pid].path.with_(local_steps=int(k))

    def Membership(self, topo, coordinator: str, **kw):
        """Attach elastic site membership (beyond the C API): lease-based
        liveness probed over `topo`'s links from the `coordinator` site,
        monotonic epochs, quorum, evict/rejoin — see
        repro/core/membership.py.  Keyword args pass through to
        :class:`~repro.core.membership.SiteMembership` (lease_steps,
        rejoin_after, quorum, retry, seed, ...).  The session keeps the
        instance (``self.membership``) so a Trainer and a ChaosMonitor can
        share it; calling again replaces it."""
        from repro.core.membership import SiteMembership
        self.membership = SiteMembership(topo, coordinator, **kw)
        return self.membership

    # -- serving (beyond the C API; the paper's client-server claim) ---------
    def Serve(self, pid: int, *, max_slots: int, queue_limit: int = 64,
              prefill_steps=1, step_s: float = 1e-2, kv_bytes=0,
              ship_steps=None, deadline_steps=None, shed: bool = True,
              topo=None, prefill_site: Optional[str] = None,
              decode_site: Optional[str] = None, membership=None,
              retry=None, max_reships: int = 2,
              ship_timeout_s: float = 0.5, log=None):
        """Attach a continuous-batching serving scheduler to a path.

        The path is the WAN leg prefilled KV caches cross in a
        disaggregated deployment: `kv_bytes` (an int, or a callable of the
        :class:`~repro.core.serving.Request` — e.g. proportional to
        prompt_len via :func:`~repro.core.kvship.kv_cache_bytes`) converts
        into per-request ship steps through the path's deterministic link
        model; `ship_steps` (int or callable) overrides the model outright.
        Returns the :class:`~repro.core.serving.ContinuousBatcher`;
        calling again replaces it.  The runtime engine
        (`repro.runtime.serving.ServingEngine`) drives the same scheduler
        with real prefill/ship/decode work.

        Fault tolerance: `deadline_steps` (+ `shed`) turns on per-request
        SLOs with load shedding.  With `topo` + `prefill_site` +
        `decode_site`, KV ships run through a
        :class:`~repro.core.serving.FaultAwareShipper` — the topology's
        `LinkProfile` fault schedules apply, failed ships retry through
        `retry` (:data:`~repro.core.retry.KVSHIP_RETRY` by default) and
        reroute after `max_reships` — and a `membership` (defaults to the
        session's, from :meth:`Membership`) fails the serving roles over
        off evicted sites.  Incidents land in `log` (defaults to the
        session incident log, so they show in :meth:`Report`)."""
        from repro.core.chaos import get_incident_log
        from repro.core.serving import (ContinuousBatcher, FaultAwareShipper,
                                        modeled_ship_steps)
        st = self.paths[pid]
        path = st.path
        if log is None:
            log = get_incident_log()
        if membership is None and topo is not None:
            membership = self.membership
        shipper = None
        if topo is not None:
            if not (prefill_site and decode_site):
                raise ValueError(
                    f"Serve with topo needs prefill_site and decode_site, "
                    f"got prefill_site={prefill_site!r} "
                    f"decode_site={decode_site!r}")
            shipper = FaultAwareShipper(
                topo, prefill_site, decode_site, kv_bytes=kv_bytes,
                step_s=step_s, retry=retry, max_reships=max_reships,
                timeout_s=ship_timeout_s, log=log, name=path.key)
        if ship_steps is not None:
            ship = ship_steps
        elif callable(kv_bytes):
            ship = lambda r: modeled_ship_steps(int(kv_bytes(r)), path, step_s)
        elif kv_bytes:
            ship = modeled_ship_steps(int(kv_bytes), path, step_s)
        else:
            ship = 0
        st.batcher = ContinuousBatcher(
            max_slots, queue_limit, prefill_steps=prefill_steps,
            ship_steps=ship, step_s=step_s, name=path.key,
            deadline_steps=deadline_steps, shed=shed, shipper=shipper,
            log=log, membership=membership, prefill_site=prefill_site,
            decode_site=decode_site)
        return st.batcher

    def Admit(self, pid: int, prompt_len: int, max_new: int,
              deadline_steps: Optional[int] = None) -> Optional[int]:
        """Admission control: submit one request to the path's serving
        scheduler.  Returns the request id, or None when the request is
        rejected (queue full) or shed (its modeled completion under
        current link health already blows `deadline_steps`)."""
        st = self.paths[pid]
        if st.batcher is None:
            raise ValueError(f"path {pid} has no serving scheduler — call "
                             f"Serve(pid={pid}, ...) first")
        return st.batcher.submit(prompt_len, max_new,
                                 deadline_steps=deadline_steps)

    def ServeStats(self, pid: int, drain: bool = True) -> dict:
        """Serving stats for a path's scheduler: completion/rejection/
        timeout/shed counts, reship/reroute/failover counters and the
        `degraded` flag, SLO attainment, latency and TTFT percentiles,
        goodput (modeled seconds), plus the deterministic event
        `timeline`.  `drain=True` first steps the virtual clock until
        every admitted request is terminal."""
        st = self.paths[pid]
        if st.batcher is None:
            raise ValueError(f"path {pid} has no serving scheduler — call "
                             f"Serve(pid={pid}, ...) first")
        if drain:
            st.batcher.drain()
        out = st.batcher.stats()
        out["timeline"] = st.batcher.timeline()
        return out

    def setAutoTuning(self, pid: int, enabled: bool,
                      payload_bytes: Optional[int] = None, *,
                      online: bool = True, window: int = 5) -> None:
        """MPW_setAutoTuning (paper: on by default).

        With `payload_bytes` the path gets the model-based warm start
        (alpha-beta optimum for that payload).  With `online` (beyond the C
        API) an :class:`OnlineTuner` is attached: feed measured seconds via
        :meth:`Observe` and the path re-tunes itself every `window` samples.
        Multi-hop paths get a :class:`RouteTuner` — one controller per hop,
        because the legs of a Forwarder route have different optima (the
        paper: >=32 streams WAN, 1 LAN on the same route).
        """
        st = self.paths[pid]
        p = st.path.with_(autotune=enabled)
        if enabled and payload_bytes:
            p = autotune_path(p, payload_bytes)
        st.path = p
        st.tuner = st.route_tuner = None
        if enabled and online:
            if p.hops:
                st.route_tuner = RouteTuner(p, window=window)
            else:
                st.tuner = OnlineTuner(streams=p.streams,
                                       chunk_mb=p.comm.chunk_mb,
                                       pacing=p.comm.pacing,
                                       algo=p.comm.algo,
                                       bucket_mb=p.comm.bucket_mb,
                                       window=window)

    def Observe(self, pid: int, seconds: float,
                nbytes: Optional[int] = None,
                hop: Optional[int] = None) -> bool:
        """Feed one measured transfer/step time for a path (beyond the C
        API; the paper's library measures inside its own send loop — here
        transfers execute inside jitted steps, so the host reports times).

        Records the sample in telemetry and, when autotuning is on, advances
        the online controller.  On a multi-hop path, `hop` attributes the
        sample to one leg; without it the end-to-end time is split across
        hops by modeled share and every hop's controller advances.  Returns
        True when any hop was re-tuned — callers holding compiled
        executables should rebuild on True.
        """
        st = self.paths[pid]
        tel = get_telemetry()
        if hop is not None:
            if not 0 <= hop < st.path.n_hops:
                raise ValueError(f"hop {hop} out of range for a "
                                 f"{st.path.n_hops}-hop path")
            if not st.path.hops:
                hop = None   # single-link: the path IS the hop
        if hop is not None:
            tel.record(st.path.hop_key(hop), seconds, nbytes=nbytes)
            if st.route_tuner is None:
                return False
            cfg = st.route_tuner.observe(hop, seconds)
            if cfg is None:
                return False
            st.path = st.path.with_hop(hop, **cfg)
            tel.path(st.path.hop_key(hop)).note_retune(None, cfg)
            return True
        tel.record(st.path.key, seconds, nbytes=nbytes)
        if st.route_tuner is not None:
            plan = tel.path(st.path.key).plan
            payload = nbytes if nbytes is not None else (
                plan.payload_bytes if plan else 0)
            retunes = st.route_tuner.observe_total(seconds, payload)
            for i, cfg in retunes.items():
                st.path = st.path.with_hop(i, **cfg)
                tel.path(st.path.hop_key(i)).note_retune(None, cfg)
            return bool(retunes)
        if st.tuner is None:
            return False
        cfg = st.tuner.observe(seconds)
        if cfg is None:
            return False
        st.path = st.path.with_(**cfg)
        get_telemetry().path(st.path.key).note_retune(None, cfg)
        return True

    # -- telemetry (beyond the C API; the paper's mpwtest diagnostics) -------
    def PathStats(self, pid: int) -> dict:
        """Per-path stats: plan shape, transfer counts, achieved GB/s.
        Multi-hop paths add a `hops` list with one summary per leg."""
        p = self.paths[pid].path
        out = get_telemetry().path(p.key).summary()
        if p.hops:
            out["hops"] = [get_telemetry().path(k).summary()
                           for k in p.hop_keys()]
        return out

    def Report(self, formatted: bool = False):
        """All per-path stats recorded in this process (facade paths and the
        runtime loops' train/serve paths alike).  The formatted report
        appends the incident timeline whenever the chaos layer recorded one
        (fault injected -> detected -> action -> recovery latency), so one
        artifact carries both the throughput story and the root cause."""
        t = get_telemetry()
        if not formatted:
            return t.report()
        out = t.format_report()
        from repro.core.chaos import get_incident_log
        log = get_incident_log()
        if log.events():
            out += "\n\n**Incidents**\n\n" + log.format_timeline()
        return out

    def Incidents(self, clear: bool = False):
        """The chaos incident timeline as JSON-friendly rows ({step, event,
        subject, detail}): every injected fault and every automatic
        response — detect, replan, retune, requeue, failover, recover (with
        `latency_steps`).  `clear=True` drains the log after reading."""
        from repro.core.chaos import get_incident_log
        log = get_incident_log()
        rows = log.timeline()
        if clear:
            log.clear()
        return rows

    # -- data movement ------------------------------------------------------
    def Send(self, pid: int, tree, shift: int = 1, dims=None):
        """Send to the ring neighbour; returns what the neighbour sent us
        (SPMD sends are symmetric — this is MPW_SendRecv's send half)."""
        return cy.pod_shift(tree, self.path(pid), shift, dims=dims)

    def Recv(self, pid: int, tree, shift: int = 1, dims=None):
        return cy.pod_shift(tree, self.path(pid), -shift, dims=dims)

    def SendRecv(self, pid: int, tree, shift: int = 1, dims=None):
        return cy.sendrecv(tree, self.path(pid), shift, dims=dims)

    def DSendRecv(self, pid: int, tree, length: jax.Array, max_len: int,
                  shift: int = 1):
        """Unknown-size exchange: ships (buffer, length); receiver masks."""
        payload = {"buf": tree, "len": jnp.asarray(length, jnp.int32)}
        out = cy.sendrecv(payload, self.path(pid), shift)
        return out["buf"], out["len"]

    def ISendRecv(self, pid: int, tree, shift: int = 1):
        """Non-blocking exchange: returns (result, token). The result must
        not be consumed before MPW_Wait(token) orders it."""
        out = cy.sendrecv(tree, self.path(pid), shift)
        token = jax.tree.leaves(out)[0].reshape(-1)[0].astype(jnp.float32)
        return out, token

    def Has_NBE_Finished(self, token) -> bool:
        # SPMD collectives complete within the step; the token exists to
        # order consumers (paper semantics: poll -> always true by Wait time)
        return True

    def Wait(self, value, token):
        out, _ = jax.lax.optimization_barrier((value, token))
        return out

    def AllReduce(self, pid: int, tree, dims=None, site_groups=None):
        """Not in the C API (MPWide users hand-roll it); provided because
        gradient sync is the dominant use in this framework.  `site_groups`
        (Topology.pod_groups) reduces intra-site before the slow hop."""
        return streamed_psum(tree, self.path(pid), dims=dims,
                             site_groups=site_groups)

    def Cycle(self, recv_pid: int, send_pid: int, tree, dims=None):
        return cy.cycle(self.path(recv_pid), self.path(send_pid), tree,
                        dims=dims)

    def Relay(self, pid: int, tree, hops: int = 1, dims=None):
        return cy.relay(tree, self.path(pid), hops, dims=dims)

    def Barrier(self):
        return cy.barrier()

    @staticmethod
    def DNSResolve(host: str) -> str:
        """Mesh-axis 'addressing': pods are coordinates, not hostnames."""
        return host

    # -- file transfer (mpw-cp / DataGather; paper §"moving files") ----------
    def _file_engine(self, pid: int):
        # a fresh engine per call reads the path's *current* knobs, so
        # setChunkSize / Observe-driven retunes apply to the next transfer.
        # File timings carry no signal about the collective algorithm or
        # the gradient-sync bucket size, so a path that ships files stops
        # probing those knobs (its other knobs — streams/chunk/pacing —
        # stay shared with collectives).
        from repro.core.filetransfer import FileTransfer
        st = self.paths[pid]
        if st.tuner is not None:
            st.tuner.pin_algo()
            st.tuner.pin_bucket()
            # pinning reverts the *tuner's* state; if a probe was already
            # applied to the path it must be reverted there too — future
            # configs exclude the pinned knob, so nothing else would undo it
            incumbent = st.tuner.grids["algo"][st.tuner.best_idx["algo"]]
            if st.path.comm.algo != incumbent:
                st.path = st.path.with_(algo=incumbent)
            bucket = st.tuner.grids["bucket_mb"][st.tuner.best_idx["bucket_mb"]]
            if st.path.comm.bucket_mb != bucket:
                st.path = st.path.with_(bucket_mb=bucket)
        return FileTransfer(self.path(pid))

    def FileSend(self, pid: int, src: str, dst: str, *, resume: bool = True):
        """mpw-cp's send half: ship one local file along the path's route
        (multi-hop routes store-and-forward with per-hop telemetry).
        Chunked over the path's streams, per-chunk checksums, lossless
        per-chunk compression when the path's `compress` knob is on, and
        resumable via the `<dst>.mpwcp.json` sidecar.  Returns the
        :class:`~repro.core.filetransfer.FileResult`."""
        res = self._file_engine(pid).copy(src, dst, resume=resume,
                                          record_total=False)
        self.Observe(pid, res.modeled_s, nbytes=res.wire_bytes)
        return res

    def FileRecv(self, pid: int, src: str, dst: str, *, resume: bool = True):
        """mpw-cp's receive half: pull a file along the *reverse* route
        (the return direction of a bidirectional Forwarder path)."""
        res = self._file_engine(pid).copy(src, dst, resume=resume,
                                          reverse=True, record_total=False)
        self.Observe(pid, res.modeled_s, nbytes=res.wire_bytes)
        return res

    def FileCopy(self, pid: int, src: str, dst: str, *, resume: bool = True):
        """mpw-cp: copy a file *or a directory tree* over the path.  A
        directory becomes a manifest walk — one FileJob per file.  Returns
        one FileResult, or the list of per-file results for a tree."""
        eng = self._file_engine(pid)
        if os.path.isdir(src):
            results = eng.copy_tree(src, dst, resume=resume,
                                    record_total=False)
            self.Observe(pid, sum(r.modeled_s for r in results),
                         nbytes=sum(r.wire_bytes for r in results))
            return results
        res = eng.copy(src, dst, resume=resume, record_total=False)
        self.Observe(pid, res.modeled_s, nbytes=res.wire_bytes)
        return res

    def DataGather(self, pid: int, src_dir: str, dst_dir: str, *,
                   interval_s: float = 2.0, start: bool = True):
        """The paper's DataGather service: continuously mirror `src_dir` to
        `dst_dir`, shipping stale files over this path (manifest diff ->
        FileJobs).  Returns the :class:`~repro.checkpoint.replicate.
        DataGather` thread handle (running when `start`; call ``.stop()``
        to drain and join)."""
        from repro.checkpoint.replicate import DataGather as _DG
        eng = self._file_engine(pid)
        # the mirror discards FileResults: skip the finalize sha256 re-read
        # (per-chunk CRCs already verify every byte)
        eng.digest = False
        g = _DG(src_dir, dst_dir, interval_s=interval_s, transfer=eng)
        return g.start() if start else g
