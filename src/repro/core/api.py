"""Paper-faithful MPW_* API facade (Table 2 of the paper).

MPWide exposes a tiny C-style API; higher-level services are asked to
integrate it as a module.  This facade offers the same verbs over mesh-axis
paths so coupled-application code (examples/couple_apps.py) reads like an
MPWide program.  All calls are jit-compatible and must run inside the
manual-DP shard_map context the runtime establishes.

Differences from the C++ API, by necessity of the platform:
  * buffers are pytrees of fixed-shape arrays, not char*: XLA requires
    static shapes.  MPW_DSendRecv ("unknown size using caching") keeps the
    paper's *interface* by carrying (max-size buffer, length) pairs — the
    cache is the compiled executable for each max size.
  * non-blocking sends return a token; MPW_Wait orders against it via
    optimization_barrier (the scheduler overlaps in between, which is
    exactly what the paper's ISendRecv achieves with threads).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CommConfig
from repro.core import cycle as cy
from repro.core.autotune import OnlineTuner, autotune_path
from repro.core.collectives import streamed_psum
from repro.core.path import INTERPOD, WidePath
from repro.core.telemetry import get_telemetry


@dataclass
class _PathState:
    path: WidePath
    tuner: Optional[OnlineTuner] = None


# process-wide path ids: telemetry keys ("mpw{pid}:{link}") must stay unique
# across MPW sessions, or a new session's stats would merge into an old
# session's registry slot
_PATH_IDS = itertools.count()


@dataclass
class MPW:
    """One MPWide session (MPW_Init .. MPW_Finalize)."""
    paths: dict[int, _PathState] = field(default_factory=dict)

    # -- lifecycle ---------------------------------------------------------
    @staticmethod
    def Init() -> "MPW":
        return MPW()

    def Finalize(self) -> None:
        self.paths.clear()

    # -- path management ----------------------------------------------------
    def CreatePath(self, axis: str = "pod", nstreams: int = 32,
                   link=INTERPOD, comm: Optional[CommConfig] = None) -> int:
        comm = comm or CommConfig(streams=nstreams)
        pid = next(_PATH_IDS)
        self.paths[pid] = _PathState(
            WidePath(axis=axis, comm=comm, link=link, name=f"mpw{pid}"))
        return pid

    def DestroyPath(self, pid: int) -> None:
        del self.paths[pid]

    def path(self, pid: int) -> WidePath:
        return self.paths[pid].path

    # -- tuning knobs (paper names) ------------------------------------------
    def setChunkSize(self, pid: int, nbytes: int) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(chunk_mb=nbytes / (1 << 20))

    def setPacingRate(self, pid: int, rate: float) -> None:
        self.paths[pid].path = self.paths[pid].path.with_(pacing=rate)

    def setWin(self, pid: int, nbytes: int) -> None:
        # TCP window -> chunk payload sizing against the link BDP
        self.setChunkSize(pid, nbytes)

    def setAutoTuning(self, pid: int, enabled: bool,
                      payload_bytes: Optional[int] = None, *,
                      online: bool = True, window: int = 5) -> None:
        """MPW_setAutoTuning (paper: on by default).

        With `payload_bytes` the path gets the model-based warm start
        (alpha-beta optimum for that payload).  With `online` (beyond the C
        API) an :class:`OnlineTuner` is attached: feed measured seconds via
        :meth:`Observe` and the path re-tunes itself every `window` samples.
        """
        st = self.paths[pid]
        p = st.path.with_(autotune=enabled)
        if enabled and payload_bytes:
            p = autotune_path(p, payload_bytes)
        st.path = p
        if enabled and online:
            st.tuner = OnlineTuner(streams=p.streams,
                                   chunk_mb=p.comm.chunk_mb,
                                   pacing=p.comm.pacing, window=window)
        else:
            st.tuner = None

    def Observe(self, pid: int, seconds: float,
                nbytes: Optional[int] = None) -> bool:
        """Feed one measured transfer/step time for a path (beyond the C
        API; the paper's library measures inside its own send loop — here
        transfers execute inside jitted steps, so the host reports times).

        Records the sample in telemetry and, when autotuning is on, advances
        the online controller.  Returns True when the path was re-tuned —
        callers holding compiled executables should rebuild on True.
        """
        st = self.paths[pid]
        get_telemetry().record(st.path.key, seconds, nbytes=nbytes)
        if st.tuner is None:
            return False
        cfg = st.tuner.observe(seconds)
        if cfg is None:
            return False
        st.path = st.path.with_(**cfg)
        get_telemetry().path(st.path.key).note_retune(None, cfg)
        return True

    # -- telemetry (beyond the C API; the paper's mpwtest diagnostics) -------
    def PathStats(self, pid: int) -> dict:
        """Per-path stats: plan shape, transfer counts, achieved GB/s."""
        return get_telemetry().path(self.paths[pid].path.key).summary()

    def Report(self, formatted: bool = False):
        """All per-path stats recorded in this process (facade paths and the
        runtime loops' train/serve paths alike)."""
        t = get_telemetry()
        return t.format_report() if formatted else t.report()

    # -- data movement ------------------------------------------------------
    def Send(self, pid: int, tree, shift: int = 1):
        """Send to the ring neighbour; returns what the neighbour sent us
        (SPMD sends are symmetric — this is MPW_SendRecv's send half)."""
        return cy.pod_shift(tree, self.path(pid), shift)

    def Recv(self, pid: int, tree, shift: int = 1):
        return cy.pod_shift(tree, self.path(pid), -shift)

    def SendRecv(self, pid: int, tree, shift: int = 1):
        return cy.sendrecv(tree, self.path(pid), shift)

    def DSendRecv(self, pid: int, tree, length: jax.Array, max_len: int,
                  shift: int = 1):
        """Unknown-size exchange: ships (buffer, length); receiver masks."""
        payload = {"buf": tree, "len": jnp.asarray(length, jnp.int32)}
        out = cy.sendrecv(payload, self.path(pid), shift)
        return out["buf"], out["len"]

    def ISendRecv(self, pid: int, tree, shift: int = 1):
        """Non-blocking exchange: returns (result, token). The result must
        not be consumed before MPW_Wait(token) orders it."""
        out = cy.sendrecv(tree, self.path(pid), shift)
        token = jax.tree.leaves(out)[0].reshape(-1)[0].astype(jnp.float32)
        return out, token

    def Has_NBE_Finished(self, token) -> bool:
        # SPMD collectives complete within the step; the token exists to
        # order consumers (paper semantics: poll -> always true by Wait time)
        return True

    def Wait(self, value, token):
        out, _ = jax.lax.optimization_barrier((value, token))
        return out

    def AllReduce(self, pid: int, tree, dims=None):
        """Not in the C API (MPWide users hand-roll it); provided because
        gradient sync is the dominant use in this framework."""
        return streamed_psum(tree, self.path(pid), dims=dims)

    def Cycle(self, recv_pid: int, send_pid: int, tree):
        return cy.cycle(self.path(recv_pid), self.path(send_pid), tree)

    def Relay(self, pid: int, tree, hops: int = 1):
        return cy.relay(tree, self.path(pid), hops)

    def Barrier(self):
        return cy.barrier()

    @staticmethod
    def DNSResolve(host: str) -> str:
        """Mesh-axis 'addressing': pods are coordinates, not hostnames."""
        return host
