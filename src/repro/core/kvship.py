"""KV-cache shipping over a WidePath (disaggregated prefill/decode).

Prefill runs on one site, decode on another; the prefilled KV cache crosses
the WAN as just another payload for the MPWide machinery: the chunk planner
cuts each KV leaf along its stacked ``layers`` dim, chunks are LPT-balanced
over the path's streams, multi-hop routes store-and-forward with per-hop
knobs, and the optional wire codec (``bf16`` / ``int8``) reduces wire bytes
exactly like the gradient wire does.

Following MPI Advance's persistent-collective argument (PAPERS.md), the
transfer *plan* is frozen once per cache geometry (:func:`plan_kv_ship`) and
reused for every request — per-request work is slicing, encoding, and
telemetry.  pMR's zero-copy motivation keeps the per-request hot path free
of re-planning.

Telemetry: each shipped request records under ``serve/req{rid}/kv`` (end to
end) and ``serve/req{rid}/kv/hop{i}:{leg}`` (per hop), with *exact* encoded
wire bytes — the byte-accounting acceptance test compares these against the
plan bit-for-bit.  Transfer seconds are deterministic modeled seconds
(`simulate_transfer_s`), never wall clock (mpwlint R5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import telemetry as tel
from repro.core.autotune import simulate_hop_s, simulate_transfer_s
from repro.core.path import WidePath
from repro.core.retry import KVSHIP_RETRY
from repro.core.streams import Chunk, assign_streams, leaf_bytes, plan_chunks

QBLOCK = 256   # int8 wire blocking (matches repro.core.compress)

# cap on fault responses within one ship — a schedule that keeps cutting
# every attempt raises ShipError instead of spinning
_MAX_SHIP_FAULTS = 64


class ShipError(RuntimeError):
    """A KV ship exhausted its reships and found no surviving route."""


def kv_cache_bytes(n_layers: int, kv_heads: int, head_dim: int,
                   prompt_len: int, *, itemsize: int = 2,
                   leaves: int = 2) -> int:
    """Logical bytes of one request's prefilled KV cache (k + v leaves)."""
    return leaves * n_layers * prompt_len * kv_heads * head_dim * itemsize


def _encoded_nbytes(n_elems: int, itemsize: int, compress: str) -> int:
    """Exact wire bytes of one encoded chunk."""
    if compress == "none":
        return n_elems * itemsize
    if compress == "bf16":
        return n_elems * 2
    if compress == "int8":
        pad = (-n_elems) % QBLOCK
        n = n_elems + pad
        return n + (n // QBLOCK) * 4          # int8 payload + f32 scales
    raise ValueError(f"unknown KV wire codec {compress!r}; "
                     f"have none|bf16|int8")


@dataclass(frozen=True)
class KVShipPlan:
    """Frozen per-session transfer plan for one cache geometry."""
    path: WidePath
    leaf_names: tuple          # cache dict keys, sorted ("k", "v", ...)
    shapes: tuple              # per-leaf single-request KV shape
    dtype: str
    chunks: tuple              # tuple[Chunk, ...] over the flat leaves
    streams_used: int
    load_balance: float
    payload_bytes: int         # logical bytes (pre-codec)
    wire_bytes_hop: int        # exact encoded bytes per hop

    @property
    def n_hops(self) -> int:
        return self.path.n_hops

    @property
    def wire_bytes_total(self) -> int:
        """Wire bytes summed over every hop of the route."""
        return self.wire_bytes_hop * self.n_hops


@dataclass(frozen=True)
class KVShipResult:
    rid: int
    wire_bytes_hop: int
    wire_bytes_total: int
    modeled_s: float           # end-to-end (store-and-forward sum, incl.
    per_hop_s: tuple           # watchdog timeouts + retry backoffs)
    n_chunks: int
    reships: int = 0           # failed-hop retries this ship needed
    reroutes: int = 0          # route replans this ship needed
    route: tuple = ()          # site names traversed (when routed)


def plan_kv_ship(kv_template: dict, path: WidePath) -> KVShipPlan:
    """Plan the KV transfer once for a cache geometry.

    `kv_template`: one request's KV leaves (arrays or ShapeDtypeStructs),
    e.g. ``{"k": (nL, S_p, KH, Dh), "v": ...}`` with the batch dim already
    squeezed out.  Chunks are cut along dim 0 (the stacked layers dim — the
    dim that is never TP-sharded in a cache), so a chunk is a contiguous
    run of whole layers."""
    names = tuple(sorted(kv_template))
    if not names:
        raise ValueError(f"kv_template must hold at least one KV leaf, "
                         f"got keys {names}")
    leaves = [kv_template[n] for n in names]
    dt = jnp.dtype(leaves[0].dtype)
    for n, x in zip(names, leaves):
        if jnp.dtype(x.dtype) != dt:
            raise ValueError(f"KV leaves must share one dtype, got "
                             f"{x.dtype} for {n!r} vs {dt}")
        if x.ndim < 2:
            raise ValueError(f"KV leaf {n!r} must be at least 2-D "
                             f"(layers leading), got shape {tuple(x.shape)}")
    chunks = plan_chunks(leaves, [0] * len(leaves), path.chunk_bytes)
    buckets = assign_streams(chunks, path.streams)
    loads = [sum(c.nbytes for c in b) for b in buckets]
    mean = sum(loads) / len(loads) if loads else 0.0
    itemsize = dt.itemsize
    wire_hop = sum(_encoded_nbytes(c.nbytes // itemsize, itemsize,
                                   path.comm.compress)
                   for c in chunks)
    return KVShipPlan(
        path=path, leaf_names=names,
        shapes=tuple(tuple(x.shape) for x in leaves), dtype=str(dt),
        chunks=tuple(chunks), streams_used=len(buckets),
        load_balance=(max(loads) / mean) if mean > 0 else 1.0,
        payload_bytes=sum(leaf_bytes(x) for x in leaves),
        wire_bytes_hop=int(wire_hop))


def _encode_decode(arr: np.ndarray, compress: str) -> tuple:
    """One chunk through the wire codec: returns (decoded array, wire bytes).

    ``none`` is byte-identical; ``bf16``/``int8`` round-trip through the
    wire dtype (int8 flattens to 1-D and pads to the quantization block, so
    padding waste never exceeds QBLOCK-1 elements per chunk)."""
    if compress == "none":
        return arr, arr.nbytes
    if compress == "bf16":
        out = np.asarray(jnp.asarray(arr).astype(jnp.bfloat16)
                         .astype(arr.dtype))
        return out.reshape(arr.shape), 2 * arr.size
    from repro.kernels import ops
    flat = jnp.asarray(arr).astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, s = ops.quant_int8(flat, block=QBLOCK)
    wire = int(np.asarray(q).nbytes + np.asarray(s).nbytes)
    y = ops.dequant_int8(q, s, block=QBLOCK, dtype=jnp.float32)
    y = y[:arr.size].reshape(arr.shape).astype(arr.dtype)
    return np.asarray(y), wire


def _corrupts(health, rid: int, hop: int, attempt: int) -> bool:
    """Deterministic per-attempt corruption draw against the hop's active
    ``error_rate`` (seeded by the fault schedule — replays bit-identically,
    like the file-transfer checksum path)."""
    if health.error_rate <= 0.0:
        return False
    x = ((health.seed * 1000003) ^ (rid * 8191 + hop * 131 + attempt * 7))
    x &= 0x7FFFFFFF
    return (x % 10000) / 10000.0 < health.error_rate


def ship_kv(kv: dict, plan: KVShipPlan, rid: int, *,
            step=None, route=None, retry=None, max_reships: int = 2,
            topo=None, log=None,
            timeout_s: float = 30.0) -> tuple[dict, KVShipResult]:
    """Ship one request's KV leaves along the plan's path.

    Store-and-forward over the route: each hop re-encodes every chunk with
    the path's wire codec (``none`` arrives bit-identical — the parity test
    depends on it), records its exact encoded bytes and modeled seconds
    under the request's telemetry keys, and hands the decoded payload to
    the next hop.  Returns (reconstructed KV dict, :class:`KVShipResult`).

    With ``route`` (the :class:`~repro.core.topology.Route` the path was
    compiled from — its `LinkProfile` fault schedules) and ``step``, the
    fault clock applies per hop: a dead hop, or one whose ``error_rate``
    corrupts this attempt (a deterministic seeded draw, counted as a
    checksum error), burns the ``timeout_s`` watchdog and retries after a
    seeded ``retry`` backoff (:data:`~repro.core.retry.KVSHIP_RETRY` by
    default), logging a ``reship`` incident to ``log``; after
    ``max_reships`` failures the remaining hops replan over ``topo``'s
    surviving links (``reroute``).  With no route left, :class:`ShipError`
    is raised — the batcher's cue to degrade to collocated serving.
    """
    path = plan.path
    if max_reships < 0:
        raise ValueError(f"max_reships must be >= 0, got {max_reships}")
    if route is not None and len(route.profiles) != path.n_hops:
        raise ValueError(f"route has {len(route.profiles)} hops but the "
                         f"plan's path has {path.n_hops} — re-plan after "
                         f"a topology change")
    arrs = []
    for name, shape in zip(plan.leaf_names, plan.shapes):
        if name not in kv:
            raise ValueError(f"kv is missing leaf {name!r} the plan was "
                             f"built for (have {sorted(kv)})")
        a = np.asarray(kv[name])
        if tuple(a.shape) != shape:
            raise ValueError(f"kv leaf {name!r} has shape {tuple(a.shape)} "
                             f"but the plan was frozen for {shape} — "
                             f"re-plan on cache-geometry change")
        arrs.append(a)
    key = f"serve/req{rid}/kv"
    tel.note_plan(key, payload_bytes=plan.payload_bytes,
                  n_chunks=len(plan.chunks),
                  streams_used=plan.streams_used,
                  streams_configured=path.streams,
                  chunk_bytes=path.chunk_bytes, pacing=path.comm.pacing,
                  load_balance=plan.load_balance, algo="shift",
                  wire_bytes=plan.wire_bytes_hop)
    pol = KVSHIP_RETRY if retry is None else retry
    hops = list(path.route)
    profs = list(route.profiles) if route is not None else [None] * len(hops)
    sites = list(route.sites) if route is not None else []
    avoid: set = set()
    per_hop_s = []
    total_s = 0.0
    reships = reroutes = faults = 0
    i = 0
    while i < len(hops):
        hop = hops[i]
        prof = profs[i]
        # fault gate: a dead hop or a corrupted attempt burns the watchdog
        # and retries; exhausted retries replan the remaining hops
        attempt = 0
        while prof is not None and step is not None:
            if faults > _MAX_SHIP_FAULTS:
                raise ShipError(f"req{rid}: ship exceeded {_MAX_SHIP_FAULTS} "
                                f"fault responses at hop {i} ({hop.name})")
            health = prof.health(int(step) + attempt)
            corrupt = health.alive and _corrupts(health, rid, i, attempt)
            if health.alive and not corrupt:
                break
            faults += 1
            total_s += float(timeout_s)
            if corrupt:
                tel.note_checksum_error(f"{key}/hop{i}:{hop.name}")
            if attempt < max_reships:
                backoff = pol.delay_s(attempt, key=rid * 31 + i)
                total_s += backoff
                reships += 1
                attempt += 1
                if log is not None:
                    log.add(int(step) + attempt, "reship", hop.name,
                            {"rid": rid,
                             "reason": "corrupt" if corrupt else "dead",
                             "attempt": attempt,
                             "backoff_s": round(backoff, 6)})
                continue
            # reships exhausted: replan from the stranded site
            if topo is None:
                raise ShipError(
                    f"req{rid}: hop {i} ({hop.name}) still faulty after "
                    f"{max_reships} reship(s) and no topology to replan on")
            avoid.add((sites[i], sites[i + 1]))
            avoid.add((sites[i + 1], sites[i]))
            try:
                nr = topo.route(sites[i], sites[-1],
                                avoid=frozenset(avoid))
            except (KeyError, ValueError):
                raise ShipError(
                    f"req{rid}: no surviving route {sites[i]} -> "
                    f"{sites[-1]} after {reships} reship(s)")
            reroutes += 1
            if log is not None:
                log.add(int(step) + attempt, "reroute", hop.name,
                        {"rid": rid, "route": list(nr.sites)})
            hops = hops[:i] + list(nr.as_hops(base_comm=path.comm))
            profs = profs[:i] + list(nr.profiles)
            sites = sites[:i] + list(nr.sites)
            hop = hops[i]
            prof = profs[i]
            attempt = 0
        hop_bytes = 0
        out = [None] * len(arrs)
        for c in plan.chunks:
            piece = arrs[c.leaf][c.start:c.start + c.size]
            decoded, wire = _encode_decode(piece, hop.comm.compress)
            hop_bytes += wire
            if out[c.leaf] is None:
                out[c.leaf] = []
            out[c.leaf].append((c.start, decoded))
        if hop_bytes != plan.wire_bytes_hop and hop.comm.compress == path.comm.compress:
            raise RuntimeError(
                f"hop {i} encoded {hop_bytes} wire bytes but the plan "
                f"promised {plan.wire_bytes_hop} — plan and codec disagree")
        arrs = [np.concatenate([p for _, p in sorted(pieces, key=lambda t: t[0])],
                               axis=0)
                for pieces in out]
        if prof is not None and step is not None:
            hop_s = simulate_hop_s(
                hop_bytes, prof, int(step) + attempt, streams=hop.streams,
                chunk_bytes=hop.chunk_bytes, pacing=hop.comm.pacing,
                timeout_s=timeout_s)
        else:
            hop_s = simulate_transfer_s(
                hop_bytes, hop.link, streams=hop.streams,
                chunk_bytes=hop.chunk_bytes, pacing=hop.comm.pacing)
        per_hop_s.append(hop_s)
        total_s += hop_s
        tel.record(f"{key}/hop{i}:{hop.name}", hop_s, nbytes=hop_bytes,
                   step=step)
        i += 1
    n_hops = len(per_hop_s)
    tel.record(key, total_s, nbytes=plan.wire_bytes_hop * n_hops,
               step=step)
    if reships or reroutes:
        tel.note_ship_retry(key, reships=reships, reroutes=reroutes)
    return (
        {n: a for n, a in zip(plan.leaf_names, arrs)},
        KVShipResult(rid=rid, wire_bytes_hop=plan.wire_bytes_hop,
                     wire_bytes_total=plan.wire_bytes_hop * n_hops,
                     modeled_s=total_s, per_hop_s=tuple(per_hop_s),
                     n_chunks=len(plan.chunks), reships=reships,
                     reroutes=reroutes, route=tuple(sites)))
