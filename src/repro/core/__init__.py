"""MPWide core: paths, streamed collectives, autotuner, telemetry, relay,
MPW_* API."""
from repro.core.api import MPW  # noqa: F401
from repro.core.autotune import (  # noqa: F401
    OnlineTuner,
    Tuning,
    autotune_path,
    simulate_transfer_s,
    tune,
)
from repro.core.collectives import (  # noqa: F401
    flat_allreduce,
    gateway_allreduce,
    hierarchical_allreduce,
    streamed_psum,
    wide_allreduce,
)
from repro.core.cycle import barrier, cycle, pod_shift, relay, sendrecv  # noqa: F401
from repro.core.overlap import accum_grads  # noqa: F401
from repro.core.path import ICI, INTERPOD, LinkSpec, WidePath, local_path  # noqa: F401
from repro.core.telemetry import PathTelemetry, Telemetry, get_telemetry  # noqa: F401
