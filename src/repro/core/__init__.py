"""MPWide core: paths, streamed collectives, ring collectives, autotuner,
telemetry, relay, multi-site topology/Forwarder, file transfer (mpw-cp),
MPW_* API."""
from repro.core.api import MPW  # noqa: F401
from repro.core.autotune import (  # noqa: F401
    OnlineTuner,
    RouteTuner,
    Tuning,
    autotune_path,
    simulate_transfer_s,
    tune,
)
from repro.core.chaos import (  # noqa: F401
    ChaosDetector,
    ChaosMonitor,
    IncidentLog,
    get_incident_log,
    healing_transfer,
    link_fault_hook,
)
from repro.core.buckets import (  # noqa: F401
    Bucket,
    BucketPlan,
    bucketed_sync,
    plan_buckets,
)
from repro.core.collectives import (  # noqa: F401
    flat_allreduce,
    gateway_allreduce,
    hierarchical_allreduce,
    local_site_allreduce,
    site_allreduce,
    streamed_psum,
    wide_allreduce,
)
from repro.core.cycle import (  # noqa: F401
    barrier,
    cycle,
    forward,
    pod_shift,
    relay,
    sendrecv,
)
from repro.core.filetransfer import (  # noqa: F401
    FileJob,
    FileResult,
    FileTransfer,
    file_sha256,
    local_transfer,
    plan_file_chunks,
)
from repro.core.kvship import (  # noqa: F401
    KVShipPlan,
    KVShipResult,
    kv_cache_bytes,
    plan_kv_ship,
    ship_kv,
)
from repro.core.localsgd import LocalSGDController  # noqa: F401
from repro.core.membership import QuorumPolicy, SiteMembership  # noqa: F401
from repro.core.overlap import accum_grads  # noqa: F401
from repro.core.path import (  # noqa: F401
    ICI,
    INTERPOD,
    Hop,
    LinkSpec,
    WidePath,
    local_path,
)
from repro.core.retry import PROBE_RETRY, RetryPolicy, RetryState  # noqa: F401
from repro.core.serving import (  # noqa: F401
    ContinuousBatcher,
    FixedBatchScheduler,
    Request,
    modeled_ship_steps,
)
from repro.core.ring import (  # noqa: F401
    ring_all_gather,
    ring_allreduce,
    ring_reduce_scatter,
    wire_bytes_per_pod,
)
from repro.core.telemetry import PathTelemetry, Telemetry, get_telemetry  # noqa: F401
from repro.core.topology import (  # noqa: F401
    LAN,
    Fault,
    Forwarder,
    LinkHealth,
    LinkProfile,
    Route,
    Site,
    Topology,
    cosmogrid_topology,
)
