"""Chunk planning: split a payload into chunk descriptors and balance them
over streams (MPW_Send "splitted evenly over the channels").

Assignment is greedy longest-processing-time (LPT), not round-robin: chunks
in descending size order each go to the currently least-loaded stream, so
mixed-size payloads (many small leaves plus a few huge ones — or a file's
equal chunks plus its remainder tail) keep the per-stream byte loads even;
`plan_summary.load_balance` reports max/mean bucket load.

For *array* payloads, chunks are cut along each leaf's scatter dim (the dim
that is not TP-sharded — the same dim ZeRO shards over "data"), so slicing
never crosses a GSPMD-sharded dimension and costs no collective.  File
transfers reuse the same :class:`Chunk` descriptor for byte ranges
(`repro.core.filetransfer.plan_file_chunks`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Chunk:
    leaf: int                 # index into the flat leaf list
    dim: int                  # dim being sliced
    start: int
    size: int
    nbytes: int               # approximate payload bytes


def leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


def chunk_rows(x, dim: Optional[int], chunk_bytes: int) -> Optional[int]:
    """Rows-per-chunk the planner would pick for this leaf (None: unchunked).

    Exposed so bucketed transfers (`repro.core.buckets`) can chunk a *slice*
    of a leaf with the row geometry of the full leaf: identical chunk
    boundaries along the scatter dim keep blockwise int8 quantization
    bit-identical to the unbucketed transfer."""
    nb = leaf_bytes(x)
    if dim is None or nb <= chunk_bytes or x.ndim == 0 or x.shape[dim] <= 1:
        return None
    return max(1, chunk_bytes // max(nb // x.shape[dim], 1))


def plan_chunks(leaves: list, dims: list[Optional[int]], chunk_bytes: int,
                rows: Optional[list] = None) -> list[Chunk]:
    """Split each leaf into chunks of <= chunk_bytes along its scatter dim.

    `rows` (per-leaf rows-per-chunk override, None entries = default
    behaviour) forces a leaf's chunk geometry — see :func:`chunk_rows`."""
    chunks: list[Chunk] = []
    for i, (x, dim) in enumerate(zip(leaves, dims)):
        nb = leaf_bytes(x)
        forced = rows[i] if rows is not None else None
        if forced is None and (dim is None or nb <= chunk_bytes
                               or x.shape[dim] <= 1):
            chunks.append(Chunk(i, dim if dim is not None else 0, 0,
                                x.shape[dim] if dim is not None and x.ndim else 0, nb))
            continue
        n = x.shape[dim]
        bytes_per_row = nb // n
        rows_i = (forced if forced is not None
                  else max(1, chunk_bytes // max(bytes_per_row, 1)))
        start = 0
        planned = 0
        while start < n:
            size = min(rows_i, n - start)
            # the last chunk absorbs the truncation remainder of nb // n, so
            # summed chunk nbytes (plan_summary.payload_bytes, telemetry GB/s)
            # exactly equals the leaf's bytes
            cb = nb - planned if start + size >= n else size * bytes_per_row
            chunks.append(Chunk(i, dim, start, size, cb))
            planned += cb
            start += size
        if planned != nb:
            raise RuntimeError(
                f"chunk plan covers {planned} bytes but leaf {i} (shape "
                f"{tuple(x.shape)}, dim {dim}, rows {rows_i}) holds {nb}")
    return chunks


def normalize_dims(leaves: list, dims=None) -> list[Optional[int]]:
    """Per-leaf scatter dims with the unsharded dim-0 fallback.

    `dims` may be None (fallback everywhere), a flat list, or a pytree whose
    leaves align with `leaves` (None leaves kept via is_leaf).  A leaf with no
    stated scatter dim is sliced along dim 0 — only safe when dim 0 is not
    TP-sharded, which holds for the replicated fallback leaves this covers.

    Negative dims follow numpy semantics (``d % ndim``: -1 is the *last*
    dim).  They must not be remapped to dim 0 — a stated scatter dim is a
    promise about which dim is safe to slice, and dim 0 of the same leaf may
    be TP-sharded.  Out-of-range dims (d >= ndim) are passed through so the
    chunk planner fails loudly at trace time, not silently wrapped.
    """
    if dims is None:
        return [0 if l.ndim else None for l in leaves]
    dim_list = (dims if isinstance(dims, list)
                else jax.tree.leaves(dims, is_leaf=lambda x: x is None))
    out: list[Optional[int]] = []
    for l, d in zip(leaves, dim_list):
        if d is None:
            out.append(0 if l.ndim else None)
        elif l.ndim == 0:
            out.append(None)
        else:
            out.append(d if d >= 0 else d % l.ndim)
    return out


def assign_streams(chunks: list[Chunk], streams: int) -> list[list[Chunk]]:
    """Greedy longest-processing-time balancing: chunks in descending size
    order each go to the currently least-loaded stream."""
    streams = max(1, min(streams, max(1, len(chunks))))
    buckets: list[list[Chunk]] = [[] for _ in range(streams)]
    loads = [0] * streams
    for c in sorted(chunks, key=lambda c: -c.nbytes):
        s = int(np.argmin(loads))
        buckets[s].append(c)
        loads[s] += c.nbytes
    return [b for b in buckets if b]


def plan_summary(chunks: list[Chunk], buckets: list[list[Chunk]],
                 streams_configured: int, chunk_bytes: int,
                 pacing: float = 1.0, *, algo: str = "psum", world: int = 1,
                 compress: str = "none",
                 wire_bytes: Optional[int] = None) -> dict:
    """Static traffic shape of a (chunks, buckets) plan, in the kwargs
    telemetry.note_plan expects.  Works on abstract leaves (shapes only), so
    the runtime can record plans at build time without devices.

    `algo`/`world`/`compress` feed the modeled per-pod wire-byte count
    (:func:`repro.core.ring.wire_bytes_per_pod`); pass `wire_bytes` to
    override the model (e.g. gateway-subgroup accounting averaged over the
    whole axis)."""
    from repro.core.ring import wire_bytes_per_pod
    loads = [sum(c.nbytes for c in b) for b in buckets]
    mean = (sum(loads) / len(loads)) if loads else 0.0
    payload = sum(c.nbytes for c in chunks)
    if wire_bytes is None:
        wire_bytes = int(round(wire_bytes_per_pod(
            payload, int(world), algo=algo, compress=compress)))
    return dict(
        payload_bytes=payload,
        n_chunks=len(chunks),
        streams_used=len(buckets),
        streams_configured=max(1, int(streams_configured)),
        chunk_bytes=int(chunk_bytes),
        pacing=float(pacing),
        load_balance=(max(loads) / mean) if mean > 0 else 1.0,
        algo=str(algo),
        wire_bytes=int(wire_bytes),
    )


def slice_chunk(x: jax.Array, c: Chunk) -> jax.Array:
    if c.size == 0 or c.size == x.shape[c.dim]:
        return x
    return jax.lax.slice_in_dim(x, c.start, c.start + c.size, axis=c.dim)


def stitch_leaf(x_template: jax.Array, pieces: list[tuple[Chunk, jax.Array]]
                ) -> jax.Array:
    """Reassemble a leaf from its processed chunks."""
    if len(pieces) == 1 and (pieces[0][0].size == 0
                             or pieces[0][0].size == x_template.shape[pieces[0][0].dim]):
        return pieces[0][1]
    pieces = sorted(pieces, key=lambda p: p[0].start)
    return jnp.concatenate([p[1] for p in pieces], axis=pieces[0][0].dim)
