"""RetryPolicy: one seeded backoff schedule for every retry loop.

Before this module, each subsystem retried its own way: the file engine
re-queued a failed chunk immediately (hammering a degraded link with the
exact traffic that just failed), the healing transfer replanned with no
pause between reroutes, and there was no liveness probing at all.  The
paper's guidance is the opposite — back off a misbehaving path and let
the autotuner re-fit — so all retry behavior now routes through one
policy object: seeded exponential backoff with deterministic jitter, a
modeled-seconds deadline, and a max attempt count.

Determinism: delays are *modeled* seconds derived from the LCG in
``repro.core.autotune`` — nothing here reads a wall clock (mpwlint R5),
so a chaos run replays the same schedule twice.  Callers that sleep for
real (none in ``core/``) convert the modeled delay themselves.

mpwlint rule **R6** enforces adoption: a literal ``while``-retry in
``src/`` (a ``continue`` inside an ``except`` handler, or a
``time.sleep`` next to a ``try`` in the loop body) must reference a
``RetryPolicy`` in its enclosing function.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

from repro.core.autotune import _lcg01


@dataclass(frozen=True)
class RetryPolicy:
    """Seeded exponential backoff + jitter + deadline + attempt cap.

    `max_attempts` counts *tries*, not retries: 1 means "try once, never
    retry".  The delay before retry k (the k+1-th try, k >= 1) is
    ``base_s * multiplier**(k-1)`` clamped to `max_s`, scaled by a
    deterministic jitter factor in ``[1-jitter/2, 1+jitter/2)`` drawn
    from the LCG on ``(seed, key, k)`` — two runs with the same seed see
    the same schedule, two chunks (different `key`) see decorrelated
    ones.  `deadline_s` caps the *cumulative modeled delay*: a schedule
    stops yielding once the next delay would exceed it.
    """
    max_attempts: int = 4
    base_s: float = 0.05
    multiplier: float = 2.0
    max_s: float = 5.0
    jitter: float = 0.5
    deadline_s: float = float("inf")
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(
                f"RetryPolicy.max_attempts must be >= 1, got {self.max_attempts}")
        if self.multiplier < 1.0:
            raise ValueError(
                f"RetryPolicy.multiplier must be >= 1, got {self.multiplier}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(
                f"RetryPolicy.jitter must be in [0, 1], got {self.jitter}")

    def delay_s(self, attempt: int, key: int = 0) -> float:
        """Modeled backoff before try `attempt` (0-based; try 0 is free)."""
        if attempt <= 0:
            return 0.0
        raw = min(self.max_s, self.base_s * self.multiplier ** (attempt - 1))
        u = _lcg01(self.seed * 1000003 + key * 8191 + attempt)
        return raw * (1.0 + self.jitter * (u - 0.5))

    def schedule(self, key: int = 0) -> Iterator[float]:
        """Yield the modeled delay before each try: 0.0, d1, d2, ...

        Stops after `max_attempts` tries or when cumulative delay would
        blow `deadline_s` — ``for delay in policy.schedule(key): ...``
        is the canonical retry loop (and what R6 looks for).
        """
        spent = 0.0
        for attempt in range(self.max_attempts):
            d = self.delay_s(attempt, key)
            if spent + d > self.deadline_s:
                return
            spent += d
            yield d

    def total_budget_s(self, key: int = 0) -> float:
        """Cumulative modeled delay of a full schedule (for lease math)."""
        return sum(self.schedule(key))

    def with_(self, **kw) -> "RetryPolicy":
        from dataclasses import replace
        return replace(self, **kw)


class RetryState:
    """Mutable cursor over one policy schedule (for loops that cannot be
    written as a ``for``: the Trainer's fault-recovery loop interleaves
    successful steps between retries, so exhaustion is judged per
    *incident streak*, not per loop entry)."""

    def __init__(self, policy: RetryPolicy, key: int = 0) -> None:
        self.policy = policy
        self.key = key
        self.attempt = 0
        self.spent_s = 0.0

    def next_delay_s(self) -> Optional[float]:
        """Modeled delay before the next retry, or None when exhausted."""
        nxt = self.attempt + 1
        if nxt >= self.policy.max_attempts:
            return None
        d = self.policy.delay_s(nxt, self.key)
        if self.spent_s + d > self.policy.deadline_s:
            return None
        self.attempt = nxt
        self.spent_s += d
        return d

    def reset(self) -> None:
        """A success ends the incident streak: the next fault starts the
        schedule over."""
        self.attempt = 0
        self.spent_s = 0.0


# membership liveness probes share one conservative default: a couple of
# quick re-probes (a transient blip should not cost a lease) before the
# monitor lets the lease clock run out
PROBE_RETRY = RetryPolicy(max_attempts=3, base_s=0.1, multiplier=2.0,
                          max_s=2.0, jitter=0.5)

# KV-cache ships race a request deadline, so the backoff ladder is shorter
# and tighter than the probe default: fail fast toward the reroute path
# (`max_reships` in core/serving.py) instead of waiting out a dead link
KVSHIP_RETRY = RetryPolicy(max_attempts=4, base_s=0.05, multiplier=2.0,
                           max_s=1.0, jitter=0.5)
