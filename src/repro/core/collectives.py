"""Wide-area collectives: the paper's transfer engine mapped onto mesh axes.

All functions here run inside a shard_map body where the DP axes ("pod",
"data") are *manual*; the TP axis stays under GSPMD.  The cross-pod stage is
the WAN analogue and is where streams / chunking / pacing / compression
apply.

Modes (CommConfig.mode):
  flat          one big psum over (data+pod) per leaf — the single-stream
                scp/naive baseline.
  hierarchical  in-pod reduce-scatter -> streamed/chunked cross-pod psum on
                1/D-size shards -> in-pod all-gather.  The firewall-level
                forwarding hierarchy of the paper; default.
  gateway       in-pod all-reduce, cross-pod exchange performed only by the
                data-rank-0 "front-end" group, in-pod broadcast.  The
                user-space Forwarder, faithfully including its inefficiency.

Within the cross-pod stage, each chunk's all-reduce lowers to the algorithm
`CommConfig.algo` selects (dispatch in :func:`_reduce_one`): "psum" is one
collective per chunk (gather-based when compressed — per-pod wire bytes
grow linearly in pod count), "ring"/"ring2" are the bandwidth-optimal
ppermute rings of `repro.core.ring` (int8 requantized per hop; ring2
bidirectional).  With `site_groups` the stage goes topology-aware
(:func:`site_allreduce`): intra-site reduction first, then only site
gateways cross the slow hop — rings exchange over the gateway subgroup
only, psum reduces gateway-masked values over the full axis.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import compress as comp
from repro.core import ring as rg
from repro.core import streams as st
from repro.core import telemetry as tel
from repro.core.path import WidePath
from repro.sharding import manual_axes_present


def _chain(dep: jax.Array, x: jax.Array) -> jax.Array:
    """Order x after dep without touching values (stream sequencing)."""
    x, _ = jax.lax.optimization_barrier((x, dep))
    return x


def _reduce_one(x: jax.Array, dim: int, axis: str, compress: str,
                algo: str = "psum", subgroup=None) -> jax.Array:
    """All-reduce one chunk with the selected algorithm.

    `subgroup` (site-gateway pod indices) is only *executed* by the ring
    algorithms (the permute names only subgroup members); the psum fallback
    reduces over the full axis and relies on the caller having masked
    non-member contributions to zero.
    """
    if algo in ("ring", "ring2"):
        return rg.ring_allreduce(x, dim, axis, compress=compress,
                                 bidirectional=(algo == "ring2"),
                                 subgroup=subgroup)
    if compress == "int8":
        return comp.compressed_psum(x, dim, axis)
    if compress == "bf16":
        return comp.bf16_psum(x, axis)
    return jax.lax.psum(x, axis)


def streamed_psum(tree, path: WidePath, dims=None, site_groups=None,
                  tel_key=None, subgroup=None, chunks=None):
    """Chunked, streamed, paced psum of a pytree over path.axis.

    This is MPW_Send/Recv semantics for an all-reduce payload: the payload is
    split into chunks (MPW_setChunkSize), chunks are round-robined over
    `streams` independent channels, chunks within a channel are ordered, and
    pacing serializes channel groups (MPW_setPacingRate).

    With `site_groups` (a partition of the pod-axis indices into sites, from
    :meth:`Topology.pod_groups`) the reduction goes hierarchical: reduce
    intra-site over the fast links first, then only one gateway pod per site
    carries the site-sum across the slow hop — see :func:`site_allreduce`.

    A multi-hop `path` (Forwarder route) executes with the bottleneck hop's
    knobs — the slow hop is where chunking/streams matter — but records a
    traffic plan for *every* hop, so `MPW.Report()` shows per-hop stats.

    The algorithm each chunk lowers to is `path.comm.algo`: "psum" (one
    collective per chunk; gather-based when compressed) or "ring"/"ring2"
    (bandwidth-optimal ppermute rings, int8-requantized per hop).  `subgroup`
    restricts the exchange to a subset of pod indices (the site-gateway
    exchange — see :func:`site_allreduce`); wire-byte accounting is averaged
    over the whole axis since only members carry WAN traffic.  `chunks` (a
    precomputed ``streams.Chunk`` list over this tree's flattened leaves)
    overrides the planner — bucketed transfers use it to keep a slice's
    chunk geometry identical to the full leaf's (int8 block alignment).
    """
    algo = path.comm.algo
    if algo not in rg.ALGOS:
        raise ValueError(f"unknown comm algo {algo!r}; have {rg.ALGOS}")
    if path.axis not in manual_axes_present(path.axis):
        return tree  # axis absent (single-pod): nothing to cross
    if site_groups is not None:
        return site_allreduce(tree, path, site_groups, dims=dims,
                              chunks=chunks, tel_key=tel_key)
    leaves, treedef = jax.tree.flatten(tree)
    dim_list = st.normalize_dims(leaves, dims)
    if chunks is None:
        chunks = st.plan_chunks(leaves, dim_list, path.chunk_bytes)
    buckets = st.assign_streams(chunks, path.streams)
    # trace-time: the plan is static per executable; record its shape once
    world = jax.lax.axis_size(path.axis)
    eff_world = len(subgroup) if subgroup else world
    wire = rg.wire_bytes_per_pod(sum(c.nbytes for c in chunks), eff_world,
                                 algo=algo, compress=path.comm.compress)
    if subgroup:   # only members carry WAN traffic: average over the axis
        wire *= eff_world / world
    tel.note_plan(tel_key or path.key, **st.plan_summary(
        chunks, buckets, path.streams, path.chunk_bytes, path.comm.pacing,
        algo=algo, world=eff_world, compress=path.comm.compress,
        wire_bytes=int(round(wire))))
    if path.hops:
        _note_hop_plans(path, leaves, dim_list)

    # pacing: only ceil(streams * pacing) streams in flight per wave
    pace = max(0.0, min(1.0, float(path.comm.pacing)))
    per_wave = max(1, int(round(len(buckets) * pace))) if buckets else 1

    done: dict[int, list] = {i: [] for i in range(len(leaves))}
    wave_token = jnp.zeros((), jnp.float32)
    for w0 in range(0, len(buckets), per_wave):
        wave = buckets[w0:w0 + per_wave]
        wave_results = []
        for bucket in wave:
            dep = wave_token
            for c in bucket:
                x = st.slice_chunk(leaves[c.leaf], c)
                x = _chain(dep, x)
                # only chunk *starts* are ordered within a stream, so the
                # 2(P-1) ring steps of successive chunks pipeline: chunk
                # k+1's first hop may run while chunk k's later hops drain
                r = _reduce_one(x, c.dim, path.axis, path.comm.compress,
                                algo, subgroup)
                done[c.leaf].append((c, r))
                dep = r.reshape(-1)[0].astype(jnp.float32)  # order within stream
            wave_results.append(dep)
        if w0 + per_wave < len(buckets):  # pace next wave after this one
            wave_token = sum(wave_results) * 0.0

    out_leaves = []
    for i, leaf in enumerate(leaves):
        pieces = done[i]
        if not pieces:
            out_leaves.append(leaf)
        else:
            out_leaves.append(st.stitch_leaf(leaf, pieces))
    return jax.tree.unflatten(treedef, out_leaves)


def site_allreduce(tree, path: WidePath, site_groups, dims=None, chunks=None,
                   tel_key=None):
    """Topology-aware hierarchical psum over the pod axis: reduce intra-site
    before crossing the slow hop.

    `site_groups` partitions the pod-axis indices into sites (from
    :meth:`Topology.pod_groups`).  Three stages:

      1. **intra-site reduce** — psum with `axis_index_groups`, over the fast
         LAN links (cheap; every pod at a site ends with the site-sum);
      2. **gateway mask** — only the first pod of each site keeps its value
         (the paper's Forwarder host: the one machine with WAN connectivity);
      3. **cross-site exchange** — over the gateway subgroup.  With
         `algo="ring"`/`"ring2"` the exchange is a chunked/streamed ring
         *among the S gateways only* (non-gateways neither send nor
         receive), followed by an intra-site broadcast.  With `algo="psum"`
         it is a chunked/streamed full-axis psum of gateway-masked values,
         which doubles as the in-site broadcast — on the TPU emulation the
         masked zeros do occupy fabric links, an artifact the ring variant
         avoids even there.

    Slow-hop bytes: only S site-sums cross the WAN instead of P
    pod-contributions — the reduction a flat psum cannot express.  Per-stage
    traffic plans land under `{path.key}/intra` and `{path.key}/wan` (or the
    route's per-hop keys when the path is multi-hop).  The `/wan` plan
    accounts gateway-subgroup bytes (averaged over the axis) for *both*
    algorithms: wire bytes model the WAN deployment, where non-gateway
    hosts have no WAN connectivity at all (the paper's Forwarder never
    opens WAN sockets on them), so `MPW.Report()` throughput reflects what
    the slow links carry rather than the emulation's masked-zero traffic.
    """
    # `chunks` (precomputed chunk plan) applies to the WAN stage only — the
    # intra-site stage is unchunked psum either way
    groups = [list(g) for g in site_groups]
    if len({len(g) for g in groups}) > 1:
        # TPU psum lowering requires equal-size axis_index_groups; fail the
        # same way everywhere (and before the axis guard) rather than only
        # on the production platform
        raise ValueError(
            f"site_allreduce needs equal pods per site, got sizes "
            f"{[len(g) for g in groups]}; give every site the same n_pods "
            f"(routing/forwarding has no such constraint)")
    if path.axis not in manual_axes_present(path.axis):
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    dim_list = st.normalize_dims(leaves, dims)

    # stage 1: intra-site reduction (fast links; unchunked — LAN alpha is
    # negligible, the paper uses 1 stream locally)
    reduced = [jax.lax.psum(l, path.axis, axis_index_groups=groups)
               for l in leaves]
    intra = st.plan_chunks(leaves, dim_list, path.chunk_bytes)
    tel.note_plan(f"{tel_key or path.key}/intra", **st.plan_summary(
        intra, st.assign_streams(intra, 1), 1, path.chunk_bytes, 1.0,
        world=len(groups[0])))
    if len(groups) == 1:
        return jax.tree.unflatten(treedef, reduced)  # one site: no WAN hop

    gateways = [g[0] for g in groups]
    idx = jax.lax.axis_index(path.axis)
    is_gw = jnp.any(idx == jnp.asarray(gateways, jnp.int32))
    wan_key = None if path.hops else f"{tel_key or path.key}/wan"

    if path.comm.algo in ("ring", "ring2"):
        # stage 2'/3': ring among the gateways only — no gateway mask
        # needed (the permute never touches non-gateways), but non-gateway
        # lanes come back holding garbage, so mask before the broadcast
        exchanged = streamed_psum(jax.tree.unflatten(treedef, reduced), path,
                                  dims=dim_list, tel_key=wan_key,
                                  subgroup=gateways, chunks=chunks)
        gw_only = [jnp.where(is_gw, l, jnp.zeros_like(l))
                   for l in jax.tree.leaves(exchanged)]
        bcast = [jax.lax.psum(l, path.axis, axis_index_groups=groups)
                 for l in gw_only]
        return jax.tree.unflatten(treedef, bcast)

    # stage 2: gateway mask — non-gateway pods contribute zero to the WAN
    masked = [jnp.where(is_gw, l, jnp.zeros_like(l)) for l in reduced]

    # stage 3: cross-site exchange over the WAN path knobs; the psum of
    # gateway-only site-sums is the global sum, delivered everywhere.
    # `subgroup` here only scopes the wire-byte accounting to the gateways.
    return streamed_psum(jax.tree.unflatten(treedef, masked), path,
                         dims=dim_list, tel_key=wan_key, subgroup=gateways,
                         chunks=chunks)


def _note_hop_plans(path: WidePath, leaves, dim_list) -> None:
    """Record a per-hop traffic plan for a multi-hop path: the same payload
    crosses every hop, but each hop chunks it with its own knobs."""
    for i, hop in enumerate(path.route):
        chunks = st.plan_chunks(leaves, dim_list, hop.chunk_bytes)
        buckets = st.assign_streams(chunks, hop.streams)
        tel.note_plan(path.hop_key(i), **st.plan_summary(
            chunks, buckets, hop.streams, hop.chunk_bytes, hop.comm.pacing,
            algo="shift"))


def flat_allreduce(tree, axes: Sequence[str]):
    axes = manual_axes_present(*axes)
    if not axes:
        return tree
    return jax.tree.map(lambda g: jax.lax.psum(g, axes), tree)


def hierarchical_allreduce(tree, path: WidePath, data_axes: Sequence[str],
                           dims, keep_scattered: bool = False,
                           site_groups=None):
    """RS(data) -> streamed cross-pod psum -> AG(data).

    `dims` is the per-leaf scatter-dim tree (from param.tree_fsdp_dims).
    With keep_scattered the final AG is skipped (ZeRO: the optimizer updates
    shards).  Leaves with dim None fall back to psum over data.
    """
    data_axes = manual_axes_present(*data_axes)
    leaves, treedef = jax.tree.flatten(tree)
    dim_list = jax.tree.leaves(dims, is_leaf=lambda x: x is None)

    def rs(g, d):
        if not data_axes:
            return g
        if d is None or g.ndim == 0 or g.shape[d] % _axes_size(data_axes) != 0:
            return jax.lax.psum(g, data_axes)
        return _psum_scatter_nd(g, d, data_axes)

    scat = [rs(g, d) for g, d in zip(leaves, dim_list)]
    scat_tree = jax.tree.unflatten(treedef, scat)
    synced = streamed_psum(scat_tree, path, dims=dim_list,
                           site_groups=site_groups)
    if keep_scattered:
        return synced

    def ag(g, g0, d):
        if not data_axes or d is None or g.shape == g0.shape:
            return g
        return _all_gather_nd(g, d, data_axes)

    out = [ag(g, g0, d) for g, g0, d in zip(jax.tree.leaves(synced), leaves, dim_list)]
    return jax.tree.unflatten(treedef, out)


def local_site_allreduce(tree, path: WidePath, data_axes: Sequence[str],
                         dims, keep_scattered: bool = False,
                         site_groups=None):
    """The local-SGD step sync: RS(data) -> *intra-site* pod psum -> AG(data).

    Identical to :func:`hierarchical_allreduce` except the cross-pod stage
    never leaves the site: with `site_groups` the pod psum is grouped per
    site (fast LAN links only), so pods within one site stay bit-identical
    while sites diverge until the next K-step delta sync merges them (see
    ``repro/core/localsgd.py``).  Without `site_groups` the whole pod axis
    is one site and this degenerates to a full sync.  No WAN bytes, no
    chunking — there is nothing to stream over a LAN-only reduction.
    """
    data_axes = manual_axes_present(*data_axes)
    leaves, treedef = jax.tree.flatten(tree)
    dim_list = jax.tree.leaves(dims, is_leaf=lambda x: x is None)

    def rs(g, d):
        if not data_axes:
            return g
        if d is None or g.ndim == 0 or g.shape[d] % _axes_size(data_axes) != 0:
            return jax.lax.psum(g, data_axes)
        return _psum_scatter_nd(g, d, data_axes)

    scat = [rs(g, d) for g, d in zip(leaves, dim_list)]
    if path.axis in manual_axes_present(path.axis):
        groups = ([list(g) for g in site_groups] if site_groups is not None
                  else None)
        if groups is not None and len({len(g) for g in groups}) > 1:
            raise ValueError(
                f"local_site_allreduce needs equal pods per site, got sizes "
                f"{[len(g) for g in groups]}")
        scat = [jax.lax.psum(g, path.axis, axis_index_groups=groups)
                for g in scat]
    if keep_scattered:
        return jax.tree.unflatten(treedef, scat)

    def ag(g, g0, d):
        if not data_axes or d is None or g.shape == g0.shape:
            return g
        return _all_gather_nd(g, d, data_axes)

    out = [ag(g, g0, d) for g, g0, d in zip(scat, leaves, dim_list)]
    return jax.tree.unflatten(treedef, out)


def gateway_allreduce(tree, path: WidePath, data_axes: Sequence[str]):
    """The user-space Forwarder: front-end group relays all WAN traffic."""
    data_axes = manual_axes_present(*data_axes)
    if data_axes:
        tree = jax.tree.map(lambda g: jax.lax.psum(g, data_axes), tree)
    if path.axis not in manual_axes_present(path.axis):
        return tree
    if not data_axes:
        return streamed_psum(tree, path)
    rank = jax.lax.axis_index(data_axes[0])
    for ax in data_axes[1:]:
        rank = rank * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    is_gw = (rank == 0)

    masked = jax.tree.map(lambda g: jnp.where(is_gw, g, jnp.zeros_like(g)), tree)
    crossed = streamed_psum(masked, path)
    # broadcast from the gateway within the pod (psum of gateway-only values);
    # non-gateway ranks hold the pre-cross in-pod sum, which must be dropped.
    gw_only = jax.tree.map(lambda g: jnp.where(is_gw, g, jnp.zeros_like(g)), crossed)
    return jax.tree.map(lambda g: jax.lax.psum(g, data_axes), gw_only)


def wide_allreduce(tree, path: WidePath, *, data_axes: Sequence[str] = ("data",),
                   dims=None, keep_scattered: bool = False, site_groups=None):
    """Dispatch on CommConfig.mode. The one entry point the runtime uses.

    `site_groups` (Topology.pod_groups) makes the hierarchical mode's
    cross-pod stage site-aware: intra-site reduction over fast links before
    the slow hop is crossed (see :func:`site_allreduce`)."""
    mode = path.comm.mode
    if mode == "flat":
        return flat_allreduce(tree, tuple(data_axes) + (path.axis,))
    if mode == "gateway":
        return gateway_allreduce(tree, path, data_axes)
    if mode == "hierarchical":
        return hierarchical_allreduce(tree, path, data_axes, dims,
                                      keep_scattered=keep_scattered,
                                      site_groups=site_groups)
    raise ValueError(f"unknown comm mode {mode!r}")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _axes_size(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def _psum_scatter_nd(g: jax.Array, dim: int, axes: Sequence[str]) -> jax.Array:
    for a in axes:
        g = jax.lax.psum_scatter(g, a, scatter_dimension=dim, tiled=True)
    return g


def _all_gather_nd(g: jax.Array, dim: int, axes: Sequence[str]) -> jax.Array:
    for a in reversed(axes):
        g = jax.lax.all_gather(g, a, axis=dim, tiled=True)
    return g
