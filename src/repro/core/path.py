"""WidePath: the MPWide communication-path abstraction, adapted to TPU.

A path in the paper is (endpoint pair, S tcp streams, chunk size, pacing,
window).  Here a path is (mesh axis, S chunk-streams, chunk bytes, pacing,
compression): every transfer over the path is split into chunks, chunks are
round-robined onto S *streams*, chunks within one stream are ordered (like
bytes on one TCP connection) while distinct streams are independent HLO ops
the XLA latency-hiding scheduler may run concurrently and overlap with
compute.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.configs.base import CommConfig


from typing import Optional as _Optional


@dataclass(frozen=True)
class LinkSpec:
    """alpha-beta link model (per device).

    `window`: per-stream in-flight byte cap (TCP congestion window) — the
    mechanism behind the paper's ">=32 streams on WANs": one stream moves at
    most window/RTT.  None for links without per-channel caps (TPU fabrics).
    """
    name: str
    latency_s: float          # alpha: per-op launch + one-way latency
    bandwidth_Bps: float      # beta^-1: per-device link bandwidth
    window: _Optional[float] = None

    def transfer_s(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps


# hardware constants (assignment): TPU v5e
ICI = LinkSpec("ici", 1e-6, 50e9)               # intra-pod, per link
INTERPOD = LinkSpec("interpod", 50e-6, 6.25e9)  # cross-pod DCN-class link
# WAN regimes from the paper's experiments (for the table-1 benchmark);
# windows ~64KB reproduce the paper's observed single-stream (scp) rates
WAN_LONDON_POZNAN = LinkSpec("lon-poz", 12e-3, 125e6, window=64 << 10)
WAN_POZNAN_GDANSK = LinkSpec("poz-gda", 5e-3, 156e6, window=64 << 10)
WAN_POZNAN_AMS = LinkSpec("poz-ams", 9e-3, 70e6, window=64 << 10)
WAN_UCL_HECTOR = LinkSpec("ucl-hector", 5.5e-3, 120e6, window=64 << 10)


@dataclass(frozen=True)
class WidePath:
    """A configured communication path over one mesh axis."""
    axis: str = "pod"
    comm: CommConfig = CommConfig()
    link: LinkSpec = INTERPOD
    name: Optional[str] = None    # telemetry label (defaults to the axis)

    @property
    def streams(self) -> int:
        return max(1, int(self.comm.streams))

    @property
    def chunk_bytes(self) -> int:
        return max(1 << 16, int(self.comm.chunk_mb * (1 << 20)))

    @property
    def key(self) -> str:
        """Registry key for this path's telemetry slot."""
        return f"{self.name or self.axis}:{self.link.name}"

    def with_(self, **kw) -> "WidePath":
        comm_kw = {k: v for k, v in kw.items() if hasattr(self.comm, k)}
        path_kw = {k: v for k, v in kw.items() if k in ("axis", "link", "name")}
        comm = replace(self.comm, **comm_kw) if comm_kw else self.comm
        return replace(self, comm=comm, **path_kw)


def local_path(comm: Optional[CommConfig] = None) -> WidePath:
    """Single-stream path over the intra-pod fabric (paper: 1 stream local)."""
    comm = comm or CommConfig(streams=1, chunk_mb=64.0, compress="none")
    return WidePath(axis="data", comm=comm, link=ICI)
