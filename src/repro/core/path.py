"""WidePath: the MPWide communication-path abstraction, adapted to TPU.

A path in the paper is (endpoint pair, S tcp streams, chunk size, pacing,
window).  Here a path is (mesh axis, S chunk-streams, chunk bytes, pacing,
compression): every transfer over the path is split into chunks, chunks are
round-robined onto S *streams*, chunks within one stream are ordered (like
bytes on one TCP connection) while distinct streams are independent HLO ops
the XLA latency-hiding scheduler may run concurrently and overlap with
compute.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.configs.base import CommConfig


from typing import Optional as _Optional


@dataclass(frozen=True)
class LinkSpec:
    """alpha-beta link model (per device).

    `window`: per-stream in-flight byte cap (TCP congestion window) — the
    mechanism behind the paper's ">=32 streams on WANs": one stream moves at
    most window/RTT.  None for links without per-channel caps (TPU fabrics).
    """
    name: str
    latency_s: float          # alpha: per-op launch + one-way latency
    bandwidth_Bps: float      # beta^-1: per-device link bandwidth
    window: _Optional[float] = None

    def transfer_s(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps


# hardware constants (assignment): TPU v5e
ICI = LinkSpec("ici", 1e-6, 50e9)               # intra-pod, per link
INTERPOD = LinkSpec("interpod", 50e-6, 6.25e9)  # cross-pod DCN-class link
# WAN regimes from the paper's experiments (for the table-1 benchmark);
# windows ~64KB reproduce the paper's observed single-stream (scp) rates
WAN_LONDON_POZNAN = LinkSpec("lon-poz", 12e-3, 125e6, window=64 << 10)
WAN_POZNAN_GDANSK = LinkSpec("poz-gda", 5e-3, 156e6, window=64 << 10)
WAN_POZNAN_AMS = LinkSpec("poz-ams", 9e-3, 70e6, window=64 << 10)
WAN_UCL_HECTOR = LinkSpec("ucl-hector", 5.5e-3, 120e6, window=64 << 10)


@dataclass(frozen=True)
class Hop:
    """One leg of a multi-hop route: the link it traverses, the comm knobs
    that leg runs with, and the pod-axis shift that executes it.

    A Forwarder route (site A -> relay -> site B) is a tuple of Hops; each
    hop is an independent transfer with its own chunking/streams/pacing —
    the paper tunes every path leg separately (32 streams on the WAN leg,
    1 on the LAN leg of the same route).
    """
    name: str                     # label, e.g. "ams->tokyo"
    link: LinkSpec = INTERPOD
    comm: CommConfig = CommConfig()
    shift: int = 1                # pod-ring delta this hop traverses

    @property
    def streams(self) -> int:
        return max(1, int(self.comm.streams))

    @property
    def chunk_bytes(self) -> int:
        return max(1 << 16, int(self.comm.chunk_mb * (1 << 20)))

    def with_(self, **kw) -> "Hop":
        comm_kw = {k: v for k, v in kw.items() if hasattr(self.comm, k)}
        hop_kw = {k: v for k, v in kw.items()
                  if k in ("name", "link", "shift")}
        comm = replace(self.comm, **comm_kw) if comm_kw else self.comm
        return replace(self, comm=comm, **hop_kw)


@dataclass(frozen=True)
class WidePath:
    """A configured communication path over one mesh axis.

    With `hops` set, the path is a multi-hop route (a Forwarder chain):
    transfers store-and-forward through each hop with that hop's own comm
    knobs, and the path-level knob properties (`streams`, `chunk_bytes`)
    read from — and `with_` writes to — the *bottleneck* hop, so existing
    single-link tuning code (Trainer retune, setChunkSize) transparently
    tunes the hop that dominates.
    """
    axis: str = "pod"
    comm: CommConfig = CommConfig()
    link: LinkSpec = INTERPOD
    name: Optional[str] = None    # telemetry label (defaults to the axis)
    hops: tuple = ()              # tuple[Hop, ...]; empty = single-link path

    @property
    def route(self) -> tuple:
        """The hop sequence: explicit hops, or the implicit single hop."""
        if self.hops:
            return self.hops
        return (Hop(name=self.link.name, link=self.link, comm=self.comm,
                    shift=1),)

    @property
    def n_hops(self) -> int:
        return len(self.route)

    @property
    def bottleneck(self) -> int:
        """Index of the slowest hop (lowest bandwidth, then highest alpha)."""
        r = self.route
        return min(range(len(r)),
                   key=lambda i: (r[i].link.bandwidth_Bps,
                                  -r[i].link.latency_s))

    def hop_key(self, i: int) -> str:
        """Telemetry key for hop i (sorts under the path's own key)."""
        return f"{self.key}/hop{i}:{self.route[i].name}"

    def hop_keys(self) -> list:
        return [self.hop_key(i) for i in range(self.n_hops)]

    @property
    def streams(self) -> int:
        if self.hops:
            return self.route[self.bottleneck].streams
        return max(1, int(self.comm.streams))

    @property
    def chunk_bytes(self) -> int:
        if self.hops:
            return self.route[self.bottleneck].chunk_bytes
        return max(1 << 16, int(self.comm.chunk_mb * (1 << 20)))

    @property
    def bucket_bytes(self) -> int:
        """Gradient-sync bucket size in bytes; 0 = bucketing disabled."""
        return max(0, int(self.comm.bucket_mb * (1 << 20)))

    @property
    def key(self) -> str:
        """Registry key for this path's telemetry slot."""
        return f"{self.name or self.axis}:{self.link.name}"

    def with_(self, **kw) -> "WidePath":
        comm_kw = {k: v for k, v in kw.items() if hasattr(self.comm, k)}
        path_kw = {k: v for k, v in kw.items()
                   if k in ("axis", "link", "name", "hops")}
        comm = replace(self.comm, **comm_kw) if comm_kw else self.comm
        out = replace(self, comm=comm, **path_kw)
        if out.hops and comm_kw and "hops" not in path_kw:
            # knob writes target the bottleneck hop (see class docstring)
            out = out.with_hop(out.bottleneck, **comm_kw)
        return out

    def with_hop(self, i: int, **kw) -> "WidePath":
        """Replace knobs of hop i (comm fields, link, name, shift)."""
        r = list(self.route)
        r[i] = r[i].with_(**kw)
        return replace(self, hops=tuple(r))

    def with_hops(self, hops) -> "WidePath":
        """Attach an explicit hop route; `link` becomes the bottleneck's
        link so `key` and alpha-beta warm starts describe the slow hop."""
        p = replace(self, hops=tuple(hops))
        return replace(p, link=p.route[p.bottleneck].link)


def local_path(comm: Optional[CommConfig] = None) -> WidePath:
    """Single-stream path over the intra-pod fabric (paper: 1 stream local)."""
    comm = comm or CommConfig(streams=1, chunk_mb=64.0, compress="none")
    return WidePath(axis="data", comm=comm, link=ICI)
