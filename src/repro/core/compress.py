"""Cross-pod payload compression (beyond-paper distributed-optimization
stage; MPWide itself ships raw bytes, but on a bandwidth-bound inter-pod link
bytes ARE the roofline, so the path optionally quantizes per chunk).

int8 mode: blockwise absmax int8 via the Pallas quant kernel; summation is
performed on the *gathered* dequantized values (quantize-then-reduce), which
is the standard compressed-allreduce formulation.  bf16 mode simply casts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

QBLOCK = 256


def _to_last(x: jax.Array, dim: int):
    if x.ndim == 0:
        y = x.reshape(1, 1)
        return y, y.shape, 1
    y = jnp.moveaxis(x, dim, -1)
    return y, y.shape, y.shape[-1]


def quant_chunk(x: jax.Array, dim: int):
    """Quantize a chunk along `dim` (its scatter dim). Returns (q, scales, meta)."""
    y, shape, n = _to_last(x, dim)
    pad = (-n) % QBLOCK
    if pad:
        y = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
    q, s = ops.quant_int8(y, block=QBLOCK)
    return q, s, (x.shape, x.dtype, dim, n, pad)


def dequant_chunk(q: jax.Array, s: jax.Array, meta) -> jax.Array:
    shape, dtype, dim, n, pad = meta
    y = ops.dequant_int8(q, s, block=QBLOCK, dtype=jnp.float32)
    if pad:
        y = y[..., :n]
    if len(shape) == 0:
        return y.reshape(()).astype(dtype)
    return jnp.moveaxis(y, -1, dim).astype(dtype)


def dequant_sum(qg: jax.Array, sg: jax.Array, meta) -> jax.Array:
    """Dequantize a gathered (P, ...) int8 batch in one shot and sum over
    the shard axis.

    One dequant subgraph regardless of P: the naive per-shard Python loop
    unrolls into P dequant subgraphs, blowing up compile time linearly in
    pod count.  Summation is in f32 (then cast back), which also tightens
    the reduction numerics vs. accumulating in a bf16 leaf dtype.
    """
    shape, dtype, dim, n, pad = meta
    y = ops.dequant_int8(qg, sg, block=QBLOCK, dtype=jnp.float32)  # (P, ..., n+pad)
    out = jnp.sum(y, axis=0)
    if pad:
        out = out[..., :n]
    if len(shape) == 0:
        return out.reshape(()).astype(dtype)
    return jnp.moveaxis(out, -1, dim).astype(dtype)


def compressed_psum(x: jax.Array, dim: int, axis: str) -> jax.Array:
    """Quantize-then-reduce all-reduce over a (manual) mesh axis.

    all_gather the int8 payload + scales over `axis`, dequantize the whole
    (P, ...) batch at once, sum over shards.  Link bytes: n/4 vs n (f32) or
    n/2 (bf16) per direction — but per-pod traffic is (P-1)*n/4 (gather);
    see :mod:`repro.core.ring` for the bandwidth-optimal ring variant.
    """
    q, s, meta = quant_chunk(x, dim)
    qg = jax.lax.all_gather(q, axis)          # (P, ...) int8
    sg = jax.lax.all_gather(s, axis)
    return dequant_sum(qg, sg, meta).astype(x.dtype)


def bf16_psum(x: jax.Array, axis: str) -> jax.Array:
    """bf16-on-the-wire all-reduce, gather-based.

    Gather-based (like int8) rather than native bf16 psum for two reasons:
    (1) it is the general compressed-allreduce formulation, (2) XLA-CPU's
    AllReducePromotion pass CHECK-fails on bf16 all-reduce inside a
    partial-manual shard_map (unused auto axis present) — a compiler bug this
    container hits; all_gather(bf16) lowers fine and moves the same bytes.
    """
    g = jax.lax.all_gather(x.astype(jnp.bfloat16), axis)
    return jnp.sum(g.astype(jnp.float32), axis=0).astype(x.dtype)
