"""Bandwidth-optimal ring collectives over a (manual) mesh axis.

The gather-based compressed all-reduce (`compress.compressed_psum`) ships
every pod's full shard to every other pod: per-pod wire traffic grows as
``(P-1) * n_wire`` — linear in pod count.  A ring reduce-scatter +
all-gather moves only ``2 * (P-1)/P * n_wire`` per pod — the bandwidth
lower bound for an all-reduce — and decomposes into 2(P-1) small
`ppermute` steps the XLA latency-hiding scheduler can pipeline across
chunks/streams (chunk k's step t runs while chunk k+1 executes step t-1),
where a monolithic `psum`/gather is one unsplittable op.

Compression is applied *per ring step*: the reduce-scatter requantizes the
running partial sum before every hop, so int8 (not f32) is what crosses
the wire at every hop; the all-gather quantizes each finished segment once
at its owner and forwards the identical int8 payload hop by hop
(store-and-forward — no re-quantization error compounds in that phase, and
every pod dequantizes bit-identical bytes).

Two algorithms:
  ring   unidirectional: one chain of 2(P-1) steps.
  ring2  bidirectional: the payload is halved and the halves circulate in
         opposite directions concurrently — two independent chains of
         (P-1) steps each, halving the serial latency-step depth.

`subgroup` restricts the ring to a subset of pod indices (the
site-gateway exchange): the permute only names subgroup members, so
non-members neither send nor receive WAN traffic (they compute garbage a
caller masks off before the intra-site broadcast).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import compress as comp
from repro.kernels import ops

QBLOCK = comp.QBLOCK

ALGOS = ("psum", "ring", "ring2")

# bytes per f32 element that actually cross the wire, per compress mode.
# int8 additionally ships one f32 scale per QBLOCK elements (+4/QBLOCK =
# +1.6% — a sideband the model below deliberately excludes, like headers).
WIRE_FACTOR = {"none": 1.0, "bf16": 0.5, "int8": 0.25}


def wire_bytes_per_pod(payload_bytes: float, world: int, *,
                       algo: str = "psum", compress: str = "none") -> float:
    """Modeled per-pod link bytes to all-reduce `payload_bytes` (f32 bytes)
    over `world` pods.

      ring/ring2   2*(world-1)/world * wire   (bandwidth-optimal)
      psum+none    2*(world-1)/world * wire   (XLA lowers its own ring)
      psum+bf16/int8   (world-1) * wire       (gather-based: every pod
                                               receives world-1 remote
                                               shards — linear in P)
      shift        wire                       (one ppermute send/recv)
    """
    wire = float(payload_bytes) * WIRE_FACTOR.get(compress, 1.0)
    if algo == "shift":
        return wire
    if world <= 1:
        return 0.0
    if algo in ("ring", "ring2") or compress == "none":
        return 2.0 * (world - 1) / world * wire
    return (world - 1.0) * wire


# ---------------------------------------------------------------------------
# wire codecs: what one ring step actually ships
# ---------------------------------------------------------------------------

def _wire_block(m: int) -> int:
    """Quantization block for a segment-axis extent of m elements.

    min(QBLOCK, m): short segment rows become their own block instead of
    being zero-padded to QBLOCK (padding would inflate real wire bytes by
    up to QBLOCK/m per row — unmodeled traffic).  The block depends only on
    the segment extent along the scatter dim, which layer-bucket slicing
    never changes, so the choice preserves bucketing bit-identity."""
    return max(1, min(QBLOCK, int(m)))


def _q_wire(seg: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize a segment to the int8 wire format.

    Blocks run along the segment axis (dim 0 — the slice of the leaf's
    scatter dim this rank owns), one block row per coordinate of the other
    dims: quantization never mixes values across non-scatter dims.  That
    keeps the wire format *invariant under layer-bucket slicing* (a bucket
    slices the stacked `layers` dim — see repro/core/buckets.py), so a
    bucketed ring transfer is bit-identical to the whole-tree one; it also
    scopes each scale to one (row, block) instead of the flattened payload.
    """
    y = jnp.moveaxis(seg, 0, -1) if seg.ndim > 1 else seg
    block = _wire_block(seg.shape[0])
    pad = (-y.shape[-1]) % block
    if pad:
        y = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
    return ops.quant_int8(y, block=block)


def _dq_wire(q: jax.Array, s: jax.Array, like: jax.Array) -> jax.Array:
    y = ops.dequant_int8(q, s, block=_wire_block(like.shape[0]),
                         dtype=jnp.float32)
    n = like.shape[0]
    if like.ndim > 1:
        return jnp.moveaxis(y[..., :n], -1, 0)
    return y[:n]


def _hop(seg: jax.Array, axis: str, perm, compress: str) -> jax.Array:
    """One ring step: encode to the wire dtype, permute, decode to f32.
    With int8 this is the per-step requantization of the partial sum."""
    if compress == "int8":
        q, s = _q_wire(seg)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        return _dq_wire(q, s, seg)
    if compress == "bf16":
        return jax.lax.ppermute(seg.astype(jnp.bfloat16), axis,
                                perm).astype(jnp.float32)
    return jax.lax.ppermute(seg, axis, perm)


# ---------------------------------------------------------------------------
# ring mechanics
# ---------------------------------------------------------------------------

def _ring_setup(axis: str, subgroup: Optional[Sequence[int]]):
    """(world, my ring position, member pod indices).  With a subgroup,
    non-members get position 0 and compute garbage the caller masks."""
    if subgroup is None:
        world = jax.lax.axis_size(axis)
        return world, jax.lax.axis_index(axis), list(range(world))
    members = [int(g) for g in subgroup]
    idx = jax.lax.axis_index(axis)
    pos = jnp.argmax((idx == jnp.asarray(members, jnp.int32)).astype(jnp.int32))
    return len(members), pos, members


def _perm(members: list, s: int) -> list:
    """Ring permutation in position space: position i sends to i+s."""
    w = len(members)
    return [(members[i], members[(i + s) % w]) for i in range(w)]


def _take(y: jax.Array, i) -> jax.Array:
    return jax.lax.dynamic_index_in_dim(y, i, axis=0, keepdims=False)


def _put(y: jax.Array, seg: jax.Array, i) -> jax.Array:
    return jax.lax.dynamic_update_index_in_dim(y, seg, i, axis=0)


def _rs_chain(y: jax.Array, axis: str, members: list, pos, s: int,
              compress: str) -> jax.Array:
    """Reduce-scatter on stacked segments y: (world, m, ...).  Returns the
    fully-reduced segment this rank owns (= segment index `pos`): at step t
    each rank forwards its running partial (requantized on the wire) and
    folds in its own contribution to the next segment."""
    world = len(members)
    perm = _perm(members, s)
    seg = _take(y, jnp.mod(pos - s, world))
    for t in range(world - 1):
        seg = _hop(seg, axis, perm, compress)
        seg = seg + _take(y, jnp.mod(pos - s * (t + 2), world))
    return seg


def _ag_chain(seg: jax.Array, out: jax.Array, axis: str, members: list, pos,
              s: int, compress: str) -> jax.Array:
    """All-gather of per-rank owned segments into `out` (world, m, ...).
    Each segment is encoded once at its owner and the identical wire bytes
    are forwarded hop by hop, so every rank decodes the same values."""
    world = len(members)
    perm = _perm(members, s)
    if compress == "int8":
        q, sc = _q_wire(seg)
        out = _put(out, _dq_wire(q, sc, seg), jnp.mod(pos, world))
        for t in range(world - 1):
            q = jax.lax.ppermute(q, axis, perm)
            sc = jax.lax.ppermute(sc, axis, perm)
            out = _put(out, _dq_wire(q, sc, seg),
                       jnp.mod(pos - s * (t + 1), world))
        return out
    wire = seg.astype(jnp.bfloat16) if compress == "bf16" else seg
    out = _put(out, wire.astype(jnp.float32), jnp.mod(pos, world))
    for t in range(world - 1):
        wire = jax.lax.ppermute(wire, axis, perm)
        out = _put(out, wire.astype(jnp.float32),
                   jnp.mod(pos - s * (t + 1), world))
    return out


def _allreduce_1d(y: jax.Array, axis: str, members: list, pos, s: int,
                  compress: str) -> jax.Array:
    """Ring all-reduce of y along dim 0 (any extent: padded to a multiple
    of world, sliced back).  f32 accumulation; returns f32."""
    world = len(members)
    n = y.shape[0]
    pad = (-n) % world
    if pad:
        y = jnp.pad(y, [(0, pad)] + [(0, 0)] * (y.ndim - 1))
    y = y.astype(jnp.float32).reshape((world, (n + pad) // world) + y.shape[1:])
    seg = _rs_chain(y, axis, members, pos, s, compress)
    out = _ag_chain(seg, jnp.zeros_like(y), axis, members, pos, s, compress)
    return out.reshape((-1,) + out.shape[2:])[:n]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def ring_allreduce(x: jax.Array, dim: int, axis: str, *,
                   compress: str = "none", bidirectional: bool = False,
                   subgroup: Optional[Sequence[int]] = None) -> jax.Array:
    """Bandwidth-optimal all-reduce of `x` over `axis`, segmented along
    `dim` (the leaf's scatter dim — never a TP-sharded dim).

    bidirectional (the "ring2" algorithm) halves the payload and runs the
    halves around the ring in opposite directions concurrently, halving the
    serial latency-step depth.  Works for any world size >= 2 (odd rings
    included; extents are padded to a multiple of the world size).
    """
    world, pos, members = _ring_setup(axis, subgroup)
    if world <= 1:
        return x
    if x.ndim == 0:
        # scalars have no dim to segment and nothing to save: psum them
        # (masked to the subgroup so non-members contribute nothing)
        if subgroup is None:
            return jax.lax.psum(x, axis)
        keep = jnp.any(jax.lax.axis_index(axis)
                       == jnp.asarray(members, jnp.int32))
        return jax.lax.psum(jnp.where(keep, x, jnp.zeros_like(x)), axis)
    y = jnp.moveaxis(x, dim % x.ndim, 0)
    n = y.shape[0]
    if bidirectional and n >= 2:
        half = n // 2
        z = jnp.concatenate(
            [_allreduce_1d(y[:half], axis, members, pos, +1, compress),
             _allreduce_1d(y[half:], axis, members, pos, -1, compress)],
            axis=0)
    else:
        z = _allreduce_1d(y, axis, members, pos, +1, compress)
    return jnp.moveaxis(z, 0, dim % x.ndim).astype(x.dtype)


def ring_reduce_scatter(x: jax.Array, dim: int, axis: str, *,
                        compress: str = "none") -> jax.Array:
    """Ring reduce-scatter: `jax.lax.psum_scatter(..., tiled=True)` built
    from ppermute steps (rank r keeps tile r of the reduced payload).
    Requires `x.shape[dim] % world == 0`."""
    world, pos, members = _ring_setup(axis, None)
    if world <= 1:
        return x
    d = dim % x.ndim
    if x.shape[d] % world:
        raise ValueError(f"reduce_scatter dim {d} extent {x.shape[d]} not "
                         f"divisible by world {world}")
    y = jnp.moveaxis(x, d, 0)
    y = y.astype(jnp.float32).reshape((world, y.shape[0] // world)
                                      + y.shape[1:])
    seg = _rs_chain(y, axis, members, pos, +1, compress)
    return jnp.moveaxis(seg, 0, d).astype(x.dtype)


def ring_all_gather(x: jax.Array, dim: int, axis: str) -> jax.Array:
    """Ring all-gather: `jax.lax.all_gather(..., tiled=True)` built from
    ppermute steps (tiles land in rank order along `dim`)."""
    world, pos, members = _ring_setup(axis, None)
    if world <= 1:
        return x
    d = dim % x.ndim
    y = jnp.moveaxis(x, d, 0)
    out = jnp.zeros((world,) + y.shape, y.dtype)
    out = _ag_chain(y.astype(jnp.float32), out.astype(jnp.float32), axis,
                    members, pos, +1, "none").astype(x.dtype)
    out = out.reshape((world * y.shape[0],) + y.shape[1:])
    return jnp.moveaxis(out, 0, d)
