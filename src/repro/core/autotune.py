"""MPWide autotuner, alpha-beta edition.

The paper's autotuner (on by default) picks chunk size / window / pacing for
"fairly good performance with minimal effort".  Without TCP, the objective
becomes: minimize modeled *exposed* link time for a payload of `nbytes` over
a link, given a `compute_window` of overlappable work.

Model (per device, ring all-reduce factor folded into eff_bytes):
  per-chunk cost     t(c) = alpha + c / bw
  serial link time   T    = n_chunks * alpha + B / bw
  exposure           E    = max(0, T - W) + tail,  tail = c / bw
The optimum chunk count n* = sqrt(B / (alpha * bw)) balances launch overhead
against tail granularity — the WAN regime (large alpha*bw product) drives
n* up, reproducing the paper's ">=32 streams long-haul, 1 stream local"
guidance.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.path import LinkSpec, WidePath


@dataclass(frozen=True)
class Tuning:
    streams: int
    chunk_bytes: int
    modeled_link_s: float
    modeled_exposed_s: float


def allreduce_bytes(nbytes: int, world: int, algo: str = "ring") -> float:
    """Per-device link bytes for an all-reduce of `nbytes`."""
    if world <= 1:
        return 0.0
    if algo == "ring":
        return 2.0 * (world - 1) / world * nbytes
    return float(nbytes)  # gather-based


def model_transfer(nbytes: float, link: LinkSpec, n_chunks: int,
                   compute_window: float = 0.0) -> tuple[float, float]:
    """(total link seconds, exposed seconds after overlapping with window)."""
    n_chunks = max(1, n_chunks)
    total = n_chunks * link.latency_s + nbytes / link.bandwidth_Bps
    tail = (nbytes / n_chunks) / link.bandwidth_Bps
    exposed = max(0.0, total - compute_window) + tail
    return total, exposed


def tune(nbytes: int, link: LinkSpec, *, world: int = 2,
         compute_window: float = 0.0, max_streams: int = 256) -> Tuning:
    """Pick (streams, chunk) minimizing modeled exposure.

    streams: on window-capped links (WANs), enough parallel windows to fill
    the bandwidth-delay product — the paper's ">=32 streams long-haul";
    window-free fabrics keep a small concurrency for latency hiding.
    chunk: alpha-beta optimum (scanned exactly; the closed form is
    sqrt(B/(alpha*bw)))."""
    eff = allreduce_bytes(nbytes, world)
    if eff == 0.0:
        return Tuning(1, max(nbytes, 1), 0.0, 0.0)
    best = None
    for n in _chunk_candidates(eff, link, max_streams):
        total, exposed = model_transfer(eff, link, n, compute_window)
        key = (exposed, total, n)
        if best is None or key < best[0]:
            best = (key, n, total, exposed)
    _, n, total, exposed = best
    if link.window:
        bdp = link.bandwidth_Bps * 2 * link.latency_s
        streams = int(min(max_streams, max(1, math.ceil(bdp / link.window))))
    else:
        streams = int(min(n, 32))
    return Tuning(streams=streams,
                  chunk_bytes=max(1 << 16, int(math.ceil(eff / n))),
                  modeled_link_s=total, modeled_exposed_s=exposed)


def _chunk_candidates(eff: float, link: LinkSpec, max_streams: int):
    n_star = math.sqrt(eff / (link.latency_s * link.bandwidth_Bps))
    cands = {1, 2, 4, 8, 16, 32, 64, 128, 256,
             max(1, int(n_star)), max(1, int(n_star * 2)),
             max(1, int(n_star / 2))}
    return sorted(c for c in cands if c <= max(max_streams, 1) * 64)


def autotune_path(path: WidePath, nbytes: int, *, world: int = 2,
                  compute_window: float = 0.0) -> WidePath:
    """Return a path re-tuned for a payload size (MPW_setAutoTuning)."""
    if not path.comm.autotune:
        return path
    t = tune(nbytes, path.link, world=world, compute_window=compute_window)
    return path.with_(streams=t.streams,
                      chunk_mb=max(t.chunk_bytes / (1 << 20), 0.0625))
