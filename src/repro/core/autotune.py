"""MPWide autotuner, alpha-beta edition.

The paper's autotuner (on by default) picks chunk size / window / pacing for
"fairly good performance with minimal effort".  Without TCP, the objective
becomes: minimize modeled *exposed* link time for a payload of `nbytes` over
a link, given a `compute_window` of overlappable work.

Model (per device, ring all-reduce factor folded into eff_bytes):
  per-chunk cost     t(c) = alpha + c / bw
  serial link time   T    = n_chunks * alpha + B / bw
  exposure           E    = max(0, T - W) + tail,  tail = c / bw
The optimum chunk count n* = sqrt(B / (alpha * bw)) balances launch overhead
against tail granularity — the WAN regime (large alpha*bw product) drives
n* up, reproducing the paper's ">=32 streams long-haul, 1 stream local"
guidance.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from statistics import median
from typing import Optional

from repro.core.path import LinkSpec, WidePath


@dataclass(frozen=True)
class Tuning:
    streams: int
    chunk_bytes: int
    modeled_link_s: float
    modeled_exposed_s: float


def allreduce_bytes(nbytes: int, world: int, algo: str = "ring") -> float:
    """Per-device link bytes for an all-reduce of `nbytes`."""
    if world <= 1:
        return 0.0
    if algo == "ring":
        return 2.0 * (world - 1) / world * nbytes
    return float(nbytes)  # gather-based


def model_transfer(nbytes: float, link: LinkSpec, n_chunks: int,
                   compute_window: float = 0.0) -> tuple[float, float]:
    """(total link seconds, exposed seconds after overlapping with window)."""
    n_chunks = max(1, n_chunks)
    total = n_chunks * link.latency_s + nbytes / link.bandwidth_Bps
    tail = (nbytes / n_chunks) / link.bandwidth_Bps
    exposed = max(0.0, total - compute_window) + tail
    return total, exposed


def tune(nbytes: int, link: LinkSpec, *, world: int = 2,
         compute_window: float = 0.0, max_streams: int = 256) -> Tuning:
    """Pick (streams, chunk) minimizing modeled exposure.

    streams: on window-capped links (WANs), enough parallel windows to fill
    the bandwidth-delay product — the paper's ">=32 streams long-haul";
    window-free fabrics keep a small concurrency for latency hiding.
    chunk: alpha-beta optimum (scanned exactly; the closed form is
    sqrt(B/(alpha*bw)))."""
    eff = allreduce_bytes(nbytes, world)
    if eff == 0.0:
        return Tuning(1, max(nbytes, 1), 0.0, 0.0)
    best = None
    for n in _chunk_candidates(eff, link, max_streams):
        total, exposed = model_transfer(eff, link, n, compute_window)
        key = (exposed, total, n)
        if best is None or key < best[0]:
            best = (key, n, total, exposed)
    _, n, total, exposed = best
    if link.window:
        bdp = link.bandwidth_Bps * 2 * link.latency_s
        streams = int(min(max_streams, max(1, math.ceil(bdp / link.window))))
    else:
        streams = int(min(n, 32))
    return Tuning(streams=streams,
                  chunk_bytes=max(1 << 16, int(math.ceil(eff / n))),
                  modeled_link_s=total, modeled_exposed_s=exposed)


def _chunk_candidates(eff: float, link: LinkSpec, max_streams: int):
    n_star = math.sqrt(eff / (link.latency_s * link.bandwidth_Bps))
    cands = {1, 2, 4, 8, 16, 32, 64, 128, 256,
             max(1, int(n_star)), max(1, int(n_star * 2)),
             max(1, int(n_star / 2))}
    return sorted(c for c in cands if c <= max(max_streams, 1) * 64)


def autotune_path(path: WidePath, nbytes: int, *, world: int = 2,
                  compute_window: float = 0.0) -> WidePath:
    """Return a path re-tuned for a payload size (MPW_setAutoTuning)."""
    if not path.comm.autotune:
        return path
    t = tune(nbytes, path.link, world=world, compute_window=compute_window)
    return path.with_(streams=t.streams,
                      chunk_mb=max(t.chunk_bytes / (1 << 20), 0.0625))


# ---------------------------------------------------------------------------
# online autotuner: measurement-driven hill climb over the path knobs
# ---------------------------------------------------------------------------

STREAM_GRID: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256)
CHUNK_GRID_MB: tuple[float, ...] = (0.0625, 0.25, 1.0, 2.0, 4.0, 8.0,
                                    16.0, 32.0, 64.0)
PACING_GRID: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
ALGO_GRID: tuple[str, ...] = ("psum", "ring", "ring2")
# gradient-sync bucket size (0 = bucketing off, one whole-tree sync); the
# grid spans "one bucket per layer block" up to "a handful of buckets for
# the largest trees" — see repro/core/buckets.py
BUCKET_GRID_MB: tuple[float, ...] = (0.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def _seed(grid: list, value) -> int:
    """Index of `value` in `grid`, inserting it (sorted) when absent.

    The warm start may sit off-grid (e.g. 23 streams / 5.7 MiB chunks from
    the alpha-beta model); snapping it to a neighbour would make the tuner's
    incumbent a config that was never measured.  Keeping the exact value as
    a grid point means the first window's cost is booked against the config
    actually running."""
    if value not in grid:
        grid.append(value)
        grid.sort()
    return grid.index(value)


class OnlineTuner:
    """First-improvement hill climb over (streams, chunk_mb, pacing) driven
    by *measured* cost (wall seconds per step/transfer).

    The model-based :func:`tune` gives a warm start; this controller closes
    the loop the paper's autotuner closes over live TCP measurements.  The
    caller feeds one cost sample per executed step via :meth:`observe`; every
    `window` samples the tuner takes the median (robust to the recompile
    spike after a knob change and to stragglers), compares it to the best
    config seen, and either keeps climbing or reverts.

    Moves are +-1 grid step per knob, plus the two (streams, chunk) diagonals
    — streams and chunk size are coupled (a payload cut into fewer chunks
    than streams cannot feed them), and the diagonal is the only improving
    direction out of configs like (1 stream, one huge chunk).

    The collective *algorithm* (`psum | ring | ring2`, CommConfig.algo) is a
    fourth knob: which algorithm wins is payload-, pod-count- and
    compression-dependent (rings win on bandwidth; the monolithic psum can
    win tiny latency-bound payloads), so it is probed from measurements like
    any other knob.  `tune_algo=False` pins it (per-hop RouteTuner legs are
    ppermute shifts, where the all-reduce algorithm does not apply).

    The sync *bucket size* (`CommConfig.bucket_mb`, `repro.core.buckets`) is
    a fifth knob: smaller buckets hide more of the transfer behind backprop
    and the optimizer but pay more per-transfer overhead, and the optimum
    depends on the measured compute/comm balance — exactly the trade the
    hill climb is built for.  Probing up from the 0.0 grid point is how a
    path *discovers* that bucketed overlap pays.  `tune_bucket=False` pins
    it (file transfers and per-hop shift legs carry no bucket signal).

    `observe` returns the new knob dict to apply when the tuner wants a
    config change, else None.  The tuner never raises mid-training: any cost
    signal is accepted, convergence just stops proposing moves.
    """

    KNOBS = ("streams", "chunk_mb", "pacing", "algo", "bucket_mb")

    def __init__(self, streams: int = 32, chunk_mb: float = 8.0,
                 pacing: float = 1.0, *, algo: str = "psum",
                 bucket_mb: float = 0.0,
                 window: int = 5, warmup: int = 1,
                 rel_improvement: float = 0.02,
                 tune_pacing: bool = True, tune_algo: bool = True,
                 tune_bucket: bool = True) -> None:
        self.grids = {"streams": list(STREAM_GRID),
                      "chunk_mb": list(CHUNK_GRID_MB),
                      "pacing": list(PACING_GRID),
                      "algo": list(ALGO_GRID),
                      "bucket_mb": list(BUCKET_GRID_MB)}
        # seeds stay exact for any value the transfer engine itself accepts
        # (streams floor at 1, chunks at the 64 KiB engine floor, pacing
        # clamps into [0,1], buckets floor at 0=off — all mirroring
        # WidePath/streamed_psum), so the incumbent is always the config
        # actually running
        self.idx = {"streams": _seed(self.grids["streams"], max(1, int(streams))),
                    "chunk_mb": _seed(self.grids["chunk_mb"],
                                      max(0.0625, float(chunk_mb))),
                    "pacing": _seed(self.grids["pacing"],
                                    max(0.0, min(1.0, float(pacing)))),
                    "algo": _seed(self.grids["algo"], str(algo)),
                    "bucket_mb": _seed(self.grids["bucket_mb"],
                                       max(0.0, float(bucket_mb)))}
        self.window = max(1, int(window))
        self.warmup = max(0, int(warmup))
        self.rel = float(rel_improvement)
        self.tune_pacing = tune_pacing
        self.tune_algo = tune_algo
        self.tune_bucket = tune_bucket
        self.best_idx = dict(self.idx)
        self.best_cost: Optional[float] = None
        self.converged = False
        self.history: list[tuple[dict, float]] = []   # (config, window cost)
        self._samples: list[float] = []
        self._skip = self.warmup      # drop compile/post-change cost spikes
        self._moves: list[dict] = []
        self._probe: Optional[dict] = None   # move applied but not yet judged

    # -- public -------------------------------------------------------------
    def _active(self) -> tuple:
        # pinned knobs (tune_algo=False: ppermute-shift hops; tune_bucket=
        # False: file paths) are not reported — returned configs stay knobs
        # the caller's cost signal can actually move
        out = []
        for k in self.KNOBS:
            if k == "algo" and not self.tune_algo:
                continue
            if k == "bucket_mb" and not self.tune_bucket:
                continue
            out.append(k)
        return tuple(out)

    def config(self) -> dict:
        return {k: self.grids[k][self.idx[k]] for k in self._active()}

    def best_config(self) -> dict:
        return {k: self.grids[k][self.best_idx[k]] for k in self._active()}

    def pin_algo(self) -> None:
        """Stop probing the `algo` knob, keeping streams/chunk/pacing live.

        Callers whose cost samples carry no information about the
        collective algorithm (file transfers: algo is a no-op for file
        bytes) must pin it — otherwise a cost-neutral algo move can look
        like a noise-driven "improvement" and silently switch the path's
        collective.  Any in-flight algo probe reverts to the incumbent.
        """
        self._pin("algo", "tune_algo")

    def pin_bucket(self) -> None:
        """Stop probing the `bucket_mb` knob (same rationale as
        :meth:`pin_algo`: file transfers ignore the sync bucket size, so
        bucket probes on a file path are noise-driven)."""
        self._pin("bucket_mb", "tune_bucket")

    def _pin(self, knob: str, flag: str) -> None:
        if not getattr(self, flag):
            return
        setattr(self, flag, False)
        self.idx[knob] = self.best_idx[knob]
        self._moves = [m for m in self._moves if knob not in m]

    def observe(self, seconds: float) -> Optional[dict]:
        """Feed one measured cost sample; returns knobs to apply or None."""
        if self.converged:
            return None
        if self._skip > 0:
            self._skip -= 1
            return None
        self._samples.append(float(seconds))
        if len(self._samples) < self.window:
            return None
        cost = median(self._samples)
        self._samples.clear()
        return self._decide(cost)

    def abort_probe(self) -> Optional[dict]:
        """Revert an in-flight probe after a path fault.

        A probe's cost window measured on a dying path says nothing about
        the probed config: without this revert, a fault mid-probe leaves
        the (possibly losing) probed knobs pinned on the path while the
        tuner's incumbent still points at the old config.  Clears the
        corrupted samples, re-queues the aborted move for a clean re-probe
        after recovery, and returns the incumbent knobs to re-apply — or
        None when the path is already running the incumbent."""
        self._samples.clear()
        self._skip = self.warmup
        if self._probe is not None:
            self._moves.insert(0, self._probe)
            self._probe = None
        if self.idx == self.best_idx:
            return None
        self.idx = dict(self.best_idx)
        return self.config()

    # -- climb mechanics ----------------------------------------------------
    def _decide(self, cost: float) -> Optional[dict]:
        self._probe = None            # the probe's window completed cleanly
        self.history.append((self.config(), cost))
        improved = (self.best_cost is None
                    or cost < self.best_cost * (1.0 - self.rel))
        if improved:
            self.best_cost = cost
            self.best_idx = dict(self.idx)
            self._moves = self._gen_moves()
        return self._try_next()

    def _gen_moves(self) -> list[dict]:
        g = self.grids
        moves = [{"streams": +1, "chunk_mb": -1},   # coupled diagonals first
                 {"streams": +1}, {"chunk_mb": -1}, {"chunk_mb": +1},
                 {"streams": -1}, {"streams": -1, "chunk_mb": +1}]
        if self.tune_pacing:
            moves += [{"pacing": -1}, {"pacing": +1}]
        if self.tune_algo:
            moves += [{"algo": +1}, {"algo": -1}]
        if self.tune_bucket:
            moves += [{"bucket_mb": -1}, {"bucket_mb": +1}]
        ok = []
        for mv in moves:
            if all(0 <= self.best_idx[k] + d < len(g[k]) for k, d in mv.items()):
                ok.append(mv)
        return ok

    def _try_next(self) -> Optional[dict]:
        if self._moves:
            mv = self._moves.pop(0)
            self.idx = dict(self.best_idx)
            for k, d in mv.items():
                self.idx[k] += d
            self._skip = self.warmup
            self._probe = mv
            return self.config()
        # no untried neighbour beats the incumbent: settle on it
        self.converged = True
        if self.idx != self.best_idx:
            self.idx = dict(self.best_idx)
            return self.config()
        return None


# ---------------------------------------------------------------------------
# per-hop tuning: one controller per leg of a multi-hop route
# ---------------------------------------------------------------------------

def hop_shares(route, nbytes: float = 0.0) -> list:
    """Each hop's fraction of a store-and-forward relay's wall time, from
    the alpha-beta model (hop times add, so shares are alpha + bytes/bw,
    normalized).  The one split used both to attribute end-to-end
    measurements to hops (telemetry) and to feed per-hop controllers."""
    shares = [h.link.transfer_s(max(0.0, float(nbytes))) for h in route]
    total = sum(shares) or 1.0
    return [s / total for s in shares]

class RouteTuner:
    """One :class:`OnlineTuner` per hop of a multi-hop path.

    The paper tunes every path leg separately (>=32 streams on the WAN leg,
    1 on the LAN leg of the same Forwarder route); a single controller over
    the whole route would conflate the legs' very different optima.  Feed
    per-hop wall seconds via :meth:`observe`; when only an end-to-end relay
    time is measurable, :meth:`observe_total` splits it across hops by each
    hop's modeled share (store-and-forward: hop times add, so the split is
    proportional to alpha + bytes/bw per hop).
    """

    def __init__(self, path, *, window: int = 5, warmup: int = 1) -> None:
        self.route = path.route
        # tune_algo/tune_bucket=False: hop legs are ppermute shifts, where
        # neither the all-reduce algorithm nor the gradient-sync bucket
        # size applies
        self.tuners = [OnlineTuner(streams=h.streams,
                                   chunk_mb=h.comm.chunk_mb,
                                   pacing=h.comm.pacing, algo=h.comm.algo,
                                   window=window, warmup=warmup,
                                   tune_algo=False, tune_bucket=False)
                       for h in self.route]

    @property
    def converged(self) -> bool:
        return all(t.converged for t in self.tuners)

    def observe(self, hop: int, seconds: float) -> Optional[dict]:
        """One measured sample for hop `hop`; returns knobs for that hop or
        None (exactly :meth:`OnlineTuner.observe` semantics)."""
        return self.tuners[hop].observe(seconds)

    def observe_total(self, seconds: float, nbytes: float = 0.0) -> dict:
        """Split an end-to-end relay time across hops by modeled share and
        feed every hop's controller.  Returns {hop index: new knobs} for the
        hops that want a config change (empty dict: keep going)."""
        shares = hop_shares(self.route, nbytes)
        out: dict[int, dict] = {}
        for i, t in enumerate(self.tuners):
            cfg = t.observe(seconds * shares[i])
            if cfg is not None:
                out[i] = cfg
        return out

    def abort_probe(self) -> dict:
        """Revert any in-flight probe on every hop (a route fault corrupts
        every hop's attributed cost window, not just the dead hop's).
        Returns {hop index: incumbent knobs} for hops that were probing."""
        out: dict[int, dict] = {}
        for i, t in enumerate(self.tuners):
            cfg = t.abort_probe()
            if cfg is not None:
                out[i] = cfg
        return out


# ---------------------------------------------------------------------------
# synthetic link: a measurement generator for convergence tests/benchmarks
# ---------------------------------------------------------------------------

def simulate_transfer_s(nbytes: float, link: LinkSpec, *, streams: int,
                        chunk_bytes: float, pacing: float = 1.0,
                        algo: str = "psum", world: int = 1,
                        compress: str = "none",
                        stream_setup_s: float = 1.5e-4,
                        compute_s: float = 0.0,
                        jitter: float = 0.0, seed: int = 0) -> float:
    """Wall seconds to ship `nbytes` over `link` with the given knobs.

    The landscape has the couplings real paths have: per-stream window caps
    (too few streams starve a WAN), per-stream setup cost (too many streams
    pay host overhead), per-chunk launch latency serialized within a stream
    (too-small chunks), and streams starved when the payload yields fewer
    chunks than streams (too-large chunks).  `jitter` adds deterministic
    pseudo-noise (LCG on `seed`) so tuner tests exercise the median filter.

    With `world > 1` the payload is an all-reduce over `world` pods and
    `nbytes` is replaced by the modeled per-pod wire bytes of
    (`algo`, `compress`) — the landscape the algo knob climbs; `world=1`
    (default) keeps the plain point-to-point transfer landscape.
    """
    if world > 1:
        from repro.core.ring import wire_bytes_per_pod
        nbytes = wire_bytes_per_pod(nbytes, world, algo=algo,
                                    compress=compress)
    chunk_bytes = max(1.0, float(chunk_bytes))
    n_chunks = max(1, math.ceil(nbytes / chunk_bytes))
    streams_used = max(1, min(int(streams), n_chunks))
    in_flight = max(1, int(round(streams_used * min(1.0, max(0.0, pacing)))))
    waves = math.ceil(streams_used / in_flight)
    per_stream = (link.window / (2 * link.latency_s) if link.window
                  else link.bandwidth_Bps)
    agg = min(link.bandwidth_Bps, in_flight * per_stream)
    wire = nbytes / agg + (waves - 1) * 2 * link.latency_s
    chunks_per_stream = math.ceil(n_chunks / streams_used)
    overhead = chunks_per_stream * link.latency_s + streams_used * stream_setup_s
    t = wire + overhead + compute_s
    if jitter:
        t *= 1.0 + jitter * (_lcg01(seed) - 0.5)
    return t


def _lcg01(seed: int) -> float:
    """Deterministic uniform [0,1) from an integer seed."""
    return ((1103515245 * (seed + 12345) + 12345) % (1 << 31)) / float(1 << 31)


def simulate_hop_s(nbytes: float, profile, step: int, *,
                   streams: Optional[int] = None,
                   chunk_bytes: Optional[float] = None,
                   pacing: Optional[float] = None,
                   timeout_s: float = 30.0,
                   jitter: float = 0.0, seed: int = 0) -> float:
    """Fault-aware wall seconds for one hop of a route at training `step`.

    Applies the :class:`~repro.core.topology.LinkProfile` fault schedule to
    the synthetic landscape: a dead link models as a transfer that hangs
    until `timeout_s` (what the watchdog on a real socket would report); a
    degraded link as proportionally less capacity.  This is how scheduled
    faults surface as *telemetry* — achieved-GB/s collapse the chaos
    detector can see — rather than as out-of-band flags."""
    health = profile.health(step)
    if not health.alive:
        return float(timeout_s)
    link = profile.spec
    if health.bandwidth_factor < 1.0:
        link = LinkSpec(link.name, link.latency_s,
                        max(1.0, link.bandwidth_Bps * health.bandwidth_factor),
                        link.window)
    return simulate_transfer_s(
        float(nbytes), link,
        streams=profile.streams if streams is None else streams,
        chunk_bytes=(profile.chunk_mb * (1 << 20) if chunk_bytes is None
                     else chunk_bytes),
        pacing=profile.pacing if pacing is None else pacing,
        jitter=jitter, seed=seed + step)
