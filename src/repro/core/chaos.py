"""Chaos layer: fault injection, detection, and self-healing routing.

MPWide's motivating deployments (CosmoGrid: four supercomputers on two
continents) ran for days over WAN links that flap, degrade, and partition
mid-run.  PRs 1-5 built the healthy-path machinery — topology routing,
online tuning, bucketed overlap, checkpoint replication; this module closes
the loop when links are *not* healthy:

  * :class:`IncidentLog` — a process-global, step-ordered record of every
    fault event and every automatic response (inject -> detect -> replan /
    failover -> recover), with recovery latency.  ``MPW.Report`` appends it
    as the incident timeline; ``MPW.Incidents`` returns the raw rows.
  * :class:`ChaosDetector` — telemetry-side anomaly detection: a per-key
    baseline (median of healthy samples) plus a consecutive-sample window;
    a hop whose modeled seconds collapse by ``collapse``x (or hit the
    absolute timeout — a dead link) for ``window`` samples in a row fires
    once.
  * :class:`ChaosMonitor` — the trainer-side controller.  Hooked into the
    Trainer between steps, it simulates each route hop under the fault
    schedule (:func:`repro.core.autotune.simulate_hop_s`), records the
    result as real telemetry, and on detection: reverts any in-flight
    tuner probe, takes the dead link out of the topology, replans the
    route (``Trainer.apply_route`` — re-tune restarts on the new route) or,
    when the far site is unreachable on any route, fails the trainer over
    to its checkpoint replica (``Trainer.failover_to_replica``).
  * :func:`healing_transfer` / :func:`link_fault_hook` — the file-transfer
    side: chunks crossing a faulty hop fail their CRC; when retries
    exhaust, the engine's reroute callback replans around the hop and
    requeues the remaining chunks.

Determinism: every fault is a :class:`repro.core.topology.Fault` schedule
(step ranges + integer seeds), the simulator is seeded, and events are
stamped with *steps*, not wall time — a chaos scenario replays
bit-identically from its script, which is what makes golden-timeline tests
possible.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from statistics import median
from typing import Callable, Optional

from repro.core import telemetry as tel
from repro.core.autotune import _lcg01, simulate_hop_s
from repro.core.retry import RetryPolicy
from repro.core.topology import Route, Topology


# ---------------------------------------------------------------------------
# incident timeline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Incident:
    """One timeline row: what happened, to which link/route, at which step."""
    step: int
    kind: str
    subject: str                  # "a->b" link or route the event is about
    detail: dict = field(default_factory=dict)
    seq: int = 0                  # global arrival order (capped-log merge key)


class IncidentLog:
    """Step-ordered, thread-safe record of faults and responses.

    Event kinds (the timeline's vocabulary):
      * ``inject``   — a scheduled fault became active
      * ``detect``   — the detector (throughput collapse / timeout) or the
                       transfer engine (checksum exhaustion) flagged a hop
      * ``replan``   — the topology found a detour; new route in `detail`
      * ``retune``   — tuners restarted on the replanned route
      * ``requeue``  — a file job moved its remaining chunks to the new route
      * ``failover`` — no route left: the trainer fell back to its replica
      * ``recover``  — the system has been healthy for the post-heal window;
                       `detail["latency_steps"]` is recover - inject
      * ``evict``    — a site's liveness lease expired: removed from the
                       membership (``core/membership.py``)
      * ``join``     — a site (re)joined the membership
      * ``leave``    — a site left gracefully (drained, not evicted)
      * ``resize``   — the trainer re-formed its world on an epoch change
      * ``catchup``  — a rejoining site restored state from the replica
      * ``timeout``  — a serving request blew its ``deadline_steps`` and was
                       terminated (``core/serving.py``)
      * ``shed``     — admission control rejected a request (queue full, or
                       the modeled completion already blows the deadline)
      * ``reship``   — a KV ship failed on a faulted hop and is being
                       retried on the same route after a seeded backoff
      * ``reroute``  — KV shipping exhausted ``max_reships`` and replanned
                       over the topology's surviving links
      * ``serve_failover`` — the batcher moved its prefill/decode role off
                       an evicted site; in-flight requests drained to QUEUED
      * ``degrade``  — no cross-site route survives: the serving tier fell
                       back to collocated mono-site serving

    Storage is a capped ring buffer *per kind*: the first `keep_first` and
    last `keep_last` events of each kind are retained, the middle is
    dropped (counted in :meth:`dropped`).  A million-step run with a
    flapping link keeps ``MPW.Report(formatted=True)`` O(1) instead of
    accumulating one row per flap; short runs (fewer than
    ``keep_first + keep_last`` events per kind — every golden-timeline
    test) see the identical, complete timeline.
    """

    KINDS = ("inject", "detect", "replan", "retune", "requeue", "failover",
             "recover", "evict", "join", "leave", "resize", "catchup",
             "timeout", "shed", "reship", "reroute", "serve_failover",
             "degrade")

    def __init__(self, keep_first: int = 64, keep_last: int = 64) -> None:
        self._lock = threading.Lock()
        self.keep_first = max(1, int(keep_first))
        self.keep_last = max(1, int(keep_last))
        self._seq = 0
        self._head: dict[str, list] = {}
        self._tail: dict[str, deque] = {}
        self._dropped: dict[str, int] = {}

    def add(self, step: int, kind: str, subject: str,
            detail: Optional[dict] = None) -> Incident:
        if kind not in self.KINDS:
            raise ValueError(f"unknown incident kind {kind!r}")
        with self._lock:
            self._seq += 1
            ev = Incident(int(step), kind, subject, dict(detail or {}),
                          self._seq)
            head = self._head.setdefault(kind, [])
            if len(head) < self.keep_first:
                head.append(ev)
            else:
                tail = self._tail.setdefault(
                    kind, deque(maxlen=self.keep_last))
                if len(tail) == self.keep_last:
                    self._dropped[kind] = self._dropped.get(kind, 0) + 1
                tail.append(ev)
        return ev

    def events(self, kind: Optional[str] = None) -> list:
        with self._lock:
            evs = []
            for k, head in self._head.items():
                evs.extend(head)
                evs.extend(self._tail.get(k, ()))
        evs.sort(key=lambda e: e.seq)      # global arrival order
        return [e for e in evs if e.kind == kind] if kind else evs

    def dropped(self, kind: Optional[str] = None) -> int:
        """Events elided by the ring buffer (0 on any short run)."""
        with self._lock:
            if kind is not None:
                return self._dropped.get(kind, 0)
            return sum(self._dropped.values())

    def timeline(self) -> list[dict]:
        """JSON-friendly rows (what ``MPW.Incidents()`` returns and the CI
        chaos job uploads as its artifact)."""
        return [{"step": e.step, "event": e.kind, "subject": e.subject,
                 "detail": dict(e.detail)} for e in self.events()]

    def recovery_latencies(self) -> list[tuple[str, int]]:
        """(subject, latency in steps) per completed incident."""
        return [(e.subject, int(e.detail.get("latency_steps", 0)))
                for e in self.events("recover")]

    def format_timeline(self) -> str:
        """Markdown table of the timeline (the `MPW.Report` appendix)."""
        evs = self.events()
        if not evs:
            return "(no incidents)"
        rows = ["| step | event | subject | detail |",
                "|---|---|---|---|"]
        for e in evs:
            det = " ".join(f"{k}={e.detail[k]}" for k in sorted(e.detail))
            rows.append(f"| {e.step} | {e.kind} | {e.subject} | {det} |")
        n_drop = self.dropped()
        if n_drop:
            rows.append(f"| … | (elided) | — | {n_drop} events dropped by "
                        f"the ring buffer |")
        return "\n".join(rows)

    def clear(self) -> None:
        with self._lock:
            self._seq = 0
            self._head.clear()
            self._tail.clear()
            self._dropped.clear()


_LOG = IncidentLog()


def get_incident_log() -> IncidentLog:
    return _LOG


# ---------------------------------------------------------------------------
# detection
# ---------------------------------------------------------------------------

class ChaosDetector:
    """Per-key throughput-collapse detector over telemetry samples.

    A key's *baseline* is the median of its healthy samples (available once
    `min_baseline` have arrived).  A sample is anomalous when it exceeds
    ``collapse * baseline`` — or ``abs_timeout_s`` regardless of baseline
    (a dead link models as the watchdog timeout, which must be detectable
    even before a baseline exists).  `window` consecutive anomalies fire
    the detector once per key (re-arm with :meth:`reset`).

    A mild degrade below the collapse factor deliberately does *not* fire:
    slow-but-alive links are the online tuner's job; re-routing is reserved
    for collapse and death.

    Hysteresis: a fired key stays latched while samples are unhealthy, but
    `rearm_after` *consecutive healthy* samples un-latch it — a path that
    healed (link restored, detour absorbed the traffic) can alarm again on
    a later, distinct fault instead of going permanently blind.
    """

    def __init__(self, collapse: float = 8.0, window: int = 3,
                 min_baseline: int = 2,
                 abs_timeout_s: Optional[float] = None,
                 rearm_after: int = 8) -> None:
        self.collapse = float(collapse)
        self.window = max(1, int(window))
        self.min_baseline = max(1, int(min_baseline))
        self.abs_timeout_s = abs_timeout_s
        self.rearm_after = max(1, int(rearm_after))
        self._state: dict[str, dict] = {}

    def _anomalous(self, st: dict, seconds: float) -> bool:
        if self.abs_timeout_s is not None and seconds >= self.abs_timeout_s:
            return True
        if len(st["good"]) >= self.min_baseline:
            return seconds >= self.collapse * max(median(st["good"]), 1e-12)
        return False

    def observe(self, key: str, seconds: float) -> bool:
        """Feed one sample; True exactly when the key trips the detector."""
        st = self._state.setdefault(
            key, {"good": [], "bad": 0, "fired": False, "heal": 0})
        seconds = float(seconds)
        bad = self._anomalous(st, seconds)
        if st["fired"]:
            # latched: never re-fire on the *same* incident, but count
            # healthy samples toward re-arming (hysteresis)
            if bad:
                st["heal"] = 0
                return False
            st["heal"] += 1
            st["good"].append(seconds)
            del st["good"][:-32]
            if st["heal"] >= self.rearm_after:
                st["fired"] = False
                st["bad"] = 0
                st["heal"] = 0
            return False
        if bad:
            st["bad"] += 1
            if st["bad"] >= self.window:
                st["fired"] = True
                st["heal"] = 0
                return True
        else:
            st["bad"] = 0
            st["good"].append(seconds)
            del st["good"][:-32]          # rolling healthy window
        return False

    def baseline(self, key: str) -> Optional[float]:
        st = self._state.get(key)
        if not st or len(st["good"]) < self.min_baseline:
            return None
        return median(st["good"])

    def reset(self, key: Optional[str] = None) -> None:
        if key is None:
            self._state.clear()
        else:
            self._state.pop(key, None)


# ---------------------------------------------------------------------------
# trainer-side monitor: watch -> detect -> heal
# ---------------------------------------------------------------------------

class ChaosMonitor:
    """Self-healing controller for a routed :class:`~repro.runtime.
    train_loop.Trainer` (pass as ``Trainer(chaos=...)``).

    Once per executed step (between steps — mid-step-safe by construction)
    it simulates every hop of the live route under the fault schedule,
    records the modeled seconds as hop telemetry (a dead link shows up as
    the watchdog timeout; a degraded one as achieved-GB/s collapse), and
    feeds the detector.  On detection it responds in order:

      1. revert any in-flight autotuner probe (its cost window measured a
         dying path — the satellite-3 fix);
      2. take the dead link (and any partitioned site) out of the topology;
      3. replan ``src -> dst``: a detour found means ``apply_route`` (the
         tuner restarts its climb on the new route = re-tune); no route
         left means ``failover_to_replica``;
      4. after `recover_after` consecutive healthy steps, record the
         ``recover`` event with the incident's latency in steps.

    With a :class:`~repro.core.membership.SiteMembership` attached
    (``membership=``), the monitor also escalates: every detected fault
    marks the sites behind the dead hop *suspect* (their lease clock
    starts), and the membership's own per-step probing evicts them when
    the fault outlives the lease — the trainer then resizes its world
    instead of hammering a dead site forever.
    """

    def __init__(self, topo: Topology, src: str, dst: str, *,
                 metric: str = "latency",
                 detector: Optional[ChaosDetector] = None,
                 log: Optional[IncidentLog] = None,
                 payload_bytes: Optional[int] = None,
                 timeout_s: float = 30.0, recover_after: int = 2,
                 membership=None,
                 seed: int = 0) -> None:
        self.topo = topo
        self.src, self.dst = src, dst
        self.metric = metric
        self.timeout_s = float(timeout_s)
        self.detector = detector or ChaosDetector(abs_timeout_s=self.timeout_s)
        if self.detector.abs_timeout_s is None:
            self.detector.abs_timeout_s = self.timeout_s
        self.log = log or get_incident_log()
        self.payload_bytes = payload_bytes
        self.recover_after = max(1, int(recover_after))
        self.membership = membership
        self.seed = int(seed)
        self._injected: set[tuple] = set()
        self._inject_ticks: dict[str, tuple] = {}   # subject -> (step, tick)
        # guards _pending/_tick: the mirror/file-transfer threads call back
        # into the monitor while the trainer thread drives on_step
        self._state_lock = threading.Lock()
        self._pending: Optional[dict] = None   # incident awaiting recovery
        # monotonic count of on_step calls: latency is measured on this, not
        # on trainer.step, which rolls BACK when a failover restores an
        # older checkpoint (a latency of recover_step - inject_step could
        # go negative across a rollback; elapsed ticks cannot)
        self._tick = 0

    # -- the per-step hook ---------------------------------------------------
    def on_step(self, trainer, log: Callable[[str], None] = print) -> None:
        with self._state_lock:
            self._tick += 1
        step = trainer.step
        if self.membership is not None:
            # liveness probing runs even while failed over (route None):
            # lease expiry and rejoin detection must not stall with the
            # data plane
            self.membership.on_step(step)
        self._heal_progress(trainer, step)
        route = trainer.route
        if route is None:                 # failed over: nothing to watch
            return
        path = trainer.bundle.path
        t = tel.get_telemetry()
        nbytes = self.payload_bytes
        if nbytes is None:
            plan = t.path(path.key).plan
            nbytes = ((plan.wire_bytes or plan.payload_bytes) if plan
                      else 64 << 20)
        bad: Optional[int] = None
        for i, prof in enumerate(route.profiles):
            a, b = route.sites[i], route.sites[i + 1]
            self._note_injections(prof, a, b, step)
            secs = simulate_hop_s(nbytes, prof, step,
                                  timeout_s=self.timeout_s, seed=self.seed)
            key = path.hop_key(i)
            t.record(key, secs, step=step)
            if self.detector.observe(key, secs) and bad is None:
                bad = i
        if bad is not None:
            self._respond(trainer, route, bad, step, log)

    # -- mechanics -----------------------------------------------------------
    def _note_injections(self, prof, a: str, b: str, step: int) -> None:
        for f in prof.faults:
            fkey = (a, b, f.kind, f.start, f.stop)
            if f.active(step) and fkey not in self._injected:
                self._injected.add(fkey)
                self._inject_ticks.setdefault(f"{a}->{b}", (step, self._tick))
                detail = {"kind": f.kind, "link": prof.name, "start": f.start}
                if f.site:
                    detail["site"] = f.site
                if f.kind == "degrade":
                    detail["factor"] = f.factor
                    detail["error_rate"] = f.error_rate
                self.log.add(step, "inject", f"{a}->{b}", detail)

    def _respond(self, trainer, route: Route, hop: int, step: int,
                 log: Callable[[str], None]) -> None:
        a, b = route.sites[hop], route.sites[hop + 1]
        subject = f"{a}->{b}"
        health = route.profiles[hop].health(step)
        self.log.add(step, "detect", subject, {
            "hop": hop, "link": route.profiles[hop].name,
            "signal": "timeout" if not health.alive else "collapse",
            "window": self.detector.window})
        if trainer.tuner is not None:
            reverted = trainer.tuner.abort_probe()
            if reverted is not None:
                trainer._retune(reverted, log)   # re-pin the incumbent
        try:
            self.topo.fail_link(a, b)
        except KeyError:
            pass
        for site in health.partitioned:
            self.topo.fail_site(site)
        new_route: Optional[Route] = None
        if self.src not in health.partitioned \
                and self.dst not in health.partitioned:
            try:
                new_route = self.topo.route(self.src, self.dst, self.metric)
            except (KeyError, ValueError):
                new_route = None
        if self.membership is not None:
            # escalate: the far endpoint and every partitioned site start
            # their lease clock; membership probing evicts them if the
            # fault outlives the lease
            for site in {b, *health.partitioned} - {self.src}:
                self.membership.suspect(site, step, reason="route-fault")
        inject_step, inject_tick = self._inject_ticks.get(
            subject, (step, self._tick))
        if new_route is not None:
            self.log.add(step, "replan", f"{self.src}->{self.dst}",
                         {"route": new_route.describe()})
            trainer.apply_route(new_route, log=log)
            knobs = (trainer.tuner.config() if trainer.tuner is not None
                     else {"hops": new_route.n_hops})
            tel.get_telemetry().path(trainer.bundle.path.key).note_retune(
                step, dict(knobs))
            self.log.add(step, "retune", f"{self.src}->{self.dst}",
                         {"knobs": knobs})
            mode = "reroute"
        else:
            outcome = trainer.failover_to_replica(log=log)
            self.log.add(step, "failover", self.dst,
                         {"outcome": outcome, "resume_step": trainer.step})
            mode = "failover"
        with self._state_lock:
            self._pending = {"subject": subject, "inject_step": inject_step,
                             "inject_tick": inject_tick, "detect_step": step,
                             "streak": 0, "mode": mode}

    def _heal_progress(self, trainer, step: int) -> None:
        p = self._pending
        if p is None:
            return
        route = trainer.route
        healthy = True
        if route is not None:
            healthy = all(not prof.health(step).faulty
                          for prof in route.profiles)
        if not healthy:
            p["streak"] = 0
            return
        p["streak"] += 1
        if p["streak"] >= self.recover_after:
            self.log.add(step, "recover", p["subject"],
                         {"inject_step": p["inject_step"],
                          "detect_step": p["detect_step"],
                          "latency_steps": self._tick - p["inject_tick"],
                          "mode": p["mode"]})
            with self._state_lock:
                self._pending = None


# ---------------------------------------------------------------------------
# file-transfer-side healing
# ---------------------------------------------------------------------------

def _flip(payload: bytes) -> bytes:
    """Deterministically corrupt a chunk payload (first byte inverted)."""
    if not payload:
        return b"\xff"
    return bytes([payload[0] ^ 0xFF]) + payload[1:]


def link_fault_hook(route: Route, clock: Callable[[], int],
                    log: Optional[IncidentLog] = None) -> Callable:
    """``FileTransfer.fault_hook`` applying a route's fault schedules.

    A chunk crossing a hop whose link is dead at ``clock()`` is corrupted
    (its CRC fails at the relay — exactly how a dead socket surfaces to the
    data plane); a degraded hop corrupts a deterministic ``error_rate``
    fraction of chunks, keyed by the fault seed and the chunk index.  The
    first corruption per hop records the ``inject`` incident.
    """
    ilog = log or get_incident_log()
    injected: set[str] = set()

    def hook(chunk, hop_index: int, payload: bytes) -> bytes:
        if hop_index >= len(route.profiles):
            return payload
        step = clock()
        health = route.profiles[hop_index].health(step)
        corrupt = (not health.alive
                   or (health.error_rate > 0.0
                       and _lcg01(health.seed + 7919 * chunk.leaf)
                       < health.error_rate))
        if not corrupt:
            return payload
        subject = f"{route.sites[hop_index]}->{route.sites[hop_index + 1]}"
        if subject not in injected:
            injected.add(subject)
            ilog.add(step, "inject", subject,
                     {"kind": "drop" if not health.alive else "degrade",
                      "link": route.profiles[hop_index].name})
        return _flip(payload)

    return hook


def healing_transfer(topo: Topology, src: str, dst: str, *,
                     comm=None, metric: str = "latency",
                     clock: Optional[Callable[[], int]] = None,
                     log: Optional[IncidentLog] = None,
                     retry: Optional[RetryPolicy] = None, **engine_kw):
    """A self-healing mpw-cp engine over ``topo``'s ``src -> dst`` route.

    The engine's ``fault_hook`` applies the route profiles' fault schedules
    at ``clock()`` and its ``reroute`` callback closes the healing loop:
    when a chunk exhausts its CRC retries on a hop, the hop's link is taken
    out of the topology, the route is replanned, the engine's path and
    fault hook move to the detour, and the job requeues its remaining
    chunks — each stage recorded in the incident log (detect via checksum
    exhaustion -> replan -> requeue).  When no detour exists the callback
    declines and :class:`~repro.core.filetransfer.ChecksumError` propagates
    as before.

    Retry behavior (per-chunk CRC re-reads *and* the pause before a
    requeue lands on the replanned route) follows one
    :class:`~repro.core.retry.RetryPolicy` — exponential backoff instead
    of the old immediate-requeue hammering of a degraded link; the
    modeled backoff seconds appear in the ``requeue`` incident detail.
    """
    from repro.configs.base import CommConfig
    from repro.core.filetransfer import FileTransfer
    from repro.core.path import WidePath

    ilog = log or get_incident_log()
    clock = clock or (lambda: 0)
    retry = retry or RetryPolicy(
        max_attempts=engine_kw.pop("max_retries", 3) + 1)
    route = topo.route(src, dst, metric)
    base = WidePath(axis="pod", comm=comm or CommConfig(),
                    name=f"heal-{src}-{dst}")
    state = {"route": route, "reroute_n": 0}

    def reroute(engine, failed_hop: int) -> bool:
        r = state["route"]
        if failed_hop >= len(r.profiles):
            return False
        a, b = r.sites[failed_hop], r.sites[failed_hop + 1]
        step = clock()
        errors = tel.get_telemetry().path(
            engine.path.hop_key(failed_hop)).checksum_errors
        ilog.add(step, "detect", f"{a}->{b}",
                 {"signal": "checksum", "errors": errors,
                  "link": r.profiles[failed_hop].name})
        try:
            topo.fail_link(a, b)
            new_route = topo.route(src, dst, metric)
        except (KeyError, ValueError):
            return False
        ilog.add(step, "replan", f"{src}->{dst}",
                 {"route": new_route.describe()})
        state["route"] = new_route
        engine.path = base.with_hops(new_route.as_hops(base_comm=comm))
        engine.fault_hook = link_fault_hook(new_route, clock, log=ilog)
        if engine.tuner is not None:
            engine.tuner.abort_probe()
        state["reroute_n"] += 1
        backoff = retry.delay_s(state["reroute_n"], key=failed_hop)
        ilog.add(step, "requeue", f"{src}->{dst}",
                 {"hops": new_route.n_hops,
                  "backoff_s": round(backoff, 4)})
        return True

    engine = FileTransfer(base.with_hops(route.as_hops(base_comm=comm)),
                          reroute=reroute, retry=retry, **engine_kw)
    engine.fault_hook = link_fault_hook(route, clock, log=ilog)
    return engine
