"""mpw-cp / DataGather transport: WAN file transfer over a :class:`WidePath`.

MPWide advertises three capabilities: message passing, fast client-server
connections, and *moving files* (the ``mpw-cp`` tool and the DataGather
service, arXiv:1312.0910).  The paper treats file movement as the same
problem as message passing — split the byte stream into chunks, ship the
chunks over S parallel streams, tune streams/chunk/pacing per link — so this
module routes file bytes through the existing path machinery instead of
around it:

  * a :class:`FileJob` maps one file onto the *chunk planner*
    (:func:`plan_file_chunks` emits ``streams.Chunk`` byte ranges) and onto
    the path's parallel streams (``streams.assign_streams``, greedy LPT —
    identical plumbing to a gradient all-reduce payload);
  * chunks are optionally **compressed per chunk** on the wire (lossless
    ``zlib`` whenever ``CommConfig.compress != "none"`` — files must
    round-trip bit-exact, so the lossy int8/bf16 array codecs do not apply);
  * every chunk carries a CRC32 **checksum**, verified after every hop; a
    mismatch re-queues the chunk from the source (bounded retries);
  * transfers are **resumable**: a JSON *sidecar manifest*
    (``<dst>.mpwcp.json``) records completed chunks as they land in the
    partial file (``<dst>.part``), so an interrupted transfer restarts
    without re-sending finished chunks;
  * a multi-hop path (a Forwarder route from :class:`~repro.core.topology.
    Topology`) relays **store-and-forward**: each chunk crosses the hops in
    order, held in the relay's buffer between legs, with per-hop wire bytes
    and modeled seconds recorded under the path's per-hop telemetry keys
    (``{key}/hop{i}:{leg}``) — `MPW.Report()` shows each leg of a file
    transfer just like each leg of a relay;
  * an attached :class:`~repro.core.autotune.OnlineTuner` tunes file
    transfers with the same knobs as collectives (streams, chunk_mb,
    pacing), fed by the modeled end-to-end seconds of each job.

Timing model: the container has no real WAN, so recorded *seconds* are
modeled (``autotune.simulate_transfer_s`` per hop — streams-, window- and
pacing-aware — summed store-and-forward), while *bytes* are the real
post-compression wire bytes.  On a deployment with a real network, feed the
measured wall time to ``MPW.Observe`` instead; the engine's data plane
(chunking, checksums, resume) is identical either way.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from hashlib import sha256
from typing import Callable, Optional

from repro.core import streams as st
from repro.core import telemetry as tel
from repro.core.autotune import OnlineTuner, simulate_transfer_s
from repro.core.path import WidePath
from repro.core.retry import RetryPolicy
from repro.core.streams import Chunk

PART_SUFFIX = ".part"
SIDECAR_SUFFIX = ".mpwcp.json"
#: file names the mirror prune and directory walks must treat as transient
TRANSIENT_SUFFIXES = (PART_SUFFIX, SIDECAR_SUFFIX, ".tmp")


class ChecksumError(RuntimeError):
    """A chunk failed its CRC after exhausting retries."""


def plan_file_chunks(nbytes: int, chunk_bytes: int) -> list[Chunk]:
    """Cut a file of `nbytes` into byte-range chunks of <= chunk_bytes.

    Reuses the collective chunk descriptor (:class:`streams.Chunk`): `leaf`
    is the chunk index, `start` the byte offset, `size`/`nbytes` the byte
    count — so stream assignment and plan summaries are the same code path
    a gradient payload takes.
    """
    chunk_bytes = max(1 << 16, int(chunk_bytes))
    if nbytes <= 0:
        return [Chunk(0, 0, 0, 0, 0)]
    out: list[Chunk] = []
    off = 0
    while off < nbytes:
        sz = min(chunk_bytes, nbytes - off)
        out.append(Chunk(len(out), 0, off, sz, sz))
        off += sz
    return out


def file_sha256(path: str, bufsize: int = 1 << 20) -> str:
    h = sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(bufsize)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


@dataclass(frozen=True)
class FileJob:
    """One file mapped onto a path's chunk plan (the unit mpw-cp ships)."""
    src: str
    dst: str
    nbytes: int
    mtime: float
    chunks: tuple                 # tuple[Chunk, ...] byte ranges
    buckets: tuple                # tuple[tuple[Chunk, ...], ...] per stream

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)


@dataclass
class FileResult:
    """What one executed :class:`FileJob` did."""
    src: str
    dst: str
    nbytes: int                   # logical file bytes
    n_chunks: int
    sent: int = 0                 # chunks shipped this run
    skipped: int = 0              # chunks already complete (resume)
    retries: int = 0              # checksum-mismatch re-queues
    backoff_s: float = 0.0        # modeled RetryPolicy delay before re-sends
    wire_bytes: int = 0           # post-compression bytes, summed over hops
    hop_wire_bytes: list = field(default_factory=list)
    modeled_s: float = 0.0        # store-and-forward sum of hop times
    hop_modeled_s: list = field(default_factory=list)
    sha256: str = ""              # destination digest ("" when digest=False)
    reroutes: int = 0             # mid-job route replans (chaos healing)
    # one entry per abandoned route: {"route", "hop_wire_bytes",
    # "failed_hop"} — wire bytes spent on a route that died mid-job still
    # count toward wire_bytes (the link carried them)
    reroute_history: list = field(default_factory=list)

    @property
    def resumed(self) -> bool:
        return self.skipped > 0


class FileTransfer:
    """The mpw-cp engine: executes :class:`FileJob`s over one WidePath.

    `fault_hook(chunk, hop_index, payload) -> payload` intercepts every
    chunk on arrival at each hop (tests inject corruption or raise to
    simulate an interrupt); `tuner` attaches an online controller that
    re-tunes ``self.path`` from modeled job times; `record=False` silences
    telemetry (the local mirror fallback).

    `reroute(engine, failed_hop) -> bool` is the self-healing hook: when a
    chunk exhausts its CRC retries (a hop is corrupting or dead), the
    engine calls it once per failure epoch.  The callback may replan the
    route — mutate ``engine.path`` (and ``engine.fault_hook``) to the new
    route — and return True; the failing chunk and every not-yet-shipped
    chunk then requeue onto the replanned route (in-flight chunks finish
    their current attempt and requeue on their next failure).  Returning
    False, or `reroute=None`, propagates :class:`ChecksumError` as before.
    At most `max_reroutes` replans per job.  Reroute is not supported for
    ``reverse`` transfers.

    `retry` is the chunk re-queue schedule (a :class:`~repro.core.retry.
    RetryPolicy`): a chunk that fails its CRC backs off per the policy's
    modeled delays (accounted in ``FileResult.backoff_s``) instead of
    hammering the degraded link with an immediate re-send.  When omitted,
    one is derived from `max_retries` (``max_attempts = max_retries + 1``);
    when given, it wins and `max_retries` is ignored.
    """

    def __init__(self, path: WidePath, *, tuner: Optional[OnlineTuner] = None,
                 compress: Optional[str] = None, max_retries: int = 3,
                 record: bool = True, digest: bool = True,
                 fault_hook: Optional[Callable] = None,
                 reroute: Optional[Callable] = None,
                 max_reroutes: int = 2,
                 retry: Optional[RetryPolicy] = None) -> None:
        self.path = path
        self.tuner = tuner
        self.reroute = reroute
        self.max_reroutes = max(0, int(max_reroutes))
        self.retry = retry or RetryPolicy(
            max_attempts=max(0, int(max_retries)) + 1)
        # kept consistent with the policy for callers that read it
        self.max_retries = self.retry.max_attempts - 1
        self.record = record
        # guards post-job path retunes: the DataGather mirror thread and a
        # caller-driven replicate_now() can drive the same engine
        self._path_lock = threading.Lock()
        # digest=False skips the whole-file sha256 re-read at finalize
        # (FileResult.sha256 stays ""): per-chunk CRCs already verify
        # integrity, so callers that discard the result — the DataGather
        # mirror loop — should not pay a second full read per file
        self.digest = digest
        self.fault_hook = fault_hook
        # "zlib" | "none"; default derives from the path's compress knob
        # (any lossy array codec selects the lossless byte codec here)
        self._compress = (compress if compress is not None
                          else ("zlib" if path.comm.compress != "none"
                                else "none"))
        if self._compress not in ("zlib", "none"):
            raise ValueError(f"unknown file codec {self._compress!r}")

    # -- planning -----------------------------------------------------------
    def plan(self, src: str, dst: str) -> FileJob:
        s = os.stat(src)
        chunks = plan_file_chunks(s.st_size, self.path.chunk_bytes)
        buckets = st.assign_streams(chunks, self.path.streams)
        return FileJob(src=src, dst=dst, nbytes=s.st_size, mtime=s.st_mtime,
                       chunks=tuple(chunks),
                       buckets=tuple(tuple(b) for b in buckets))

    # -- execution ----------------------------------------------------------
    def copy(self, src: str, dst: str, *, resume: bool = True,
             reverse: bool = False, record_total: bool = True) -> FileResult:
        """Ship one file src -> dst through the path's route.

        `resume=True` keeps a sidecar manifest next to the partial file and
        skips chunks it records as done (validated against source size and
        mtime — a changed source restarts from scratch).  `reverse` runs the
        route back to front (``FileRecv``: pulling along the return
        direction).  `record_total=False` leaves the end-to-end telemetry
        sample to the caller (the MPW facade records it via ``Observe`` so
        the session's tuner sees it too).
        """
        job = self.plan(src, dst)
        return self.run(job, resume=resume, reverse=reverse,
                        record_total=record_total)

    def run(self, job: FileJob, *, resume: bool = True, reverse: bool = False,
            record_total: bool = True) -> FileResult:
        route = self.path.route
        hop_order = (list(range(len(route) - 1, -1, -1)) if reverse
                     else list(range(len(route))))
        res = FileResult(src=job.src, dst=job.dst, nbytes=job.nbytes,
                         n_chunks=job.n_chunks,
                         hop_wire_bytes=[0] * len(route),
                         hop_modeled_s=[0.0] * len(route))
        done = self._load_sidecar(job) if resume else {}
        part = job.dst + PART_SUFFIX
        os.makedirs(os.path.dirname(os.path.abspath(job.dst)), exist_ok=True)
        self._ensure_part(part, job.nbytes)
        lock = threading.Lock()
        # mutable route state shared by the streams: a reroute bumps `epoch`
        # and swaps route/hop_order; chunks that fail re-read it and requeue
        ctx = {"epoch": 0, "reroutes": 0, "route": route,
               "hop_order": hop_order, "reverse": reverse}

        def ship(c: Chunk) -> None:
            while True:
                with lock:
                    my_epoch = ctx["epoch"]
                    order_now = list(ctx["hop_order"])
                    # hold the *list object*: after a reroute archives it,
                    # stragglers still account their bytes against the
                    # abandoned route rather than the fresh arrays
                    hw = res.hop_wire_bytes
                path_now = self.path
                failed_hop = order_now[0] if order_now else 0
                for _delay in self.retry.schedule(key=c.leaf):
                    if _delay:
                        with lock:      # modeled backoff before the re-send
                            res.backoff_s += _delay
                    try:
                        with open(job.src, "rb") as f:
                            f.seek(c.start)
                            payload = f.read(c.size)
                    except FileNotFoundError:
                        self._abort(job.dst)  # source vanished: no resume
                        raise
                    crc = zlib.crc32(payload)
                    ok = True
                    for i in order_now:   # store-and-forward across route
                        wire = (zlib.compress(payload, 1)
                                if self._compress == "zlib" else payload)
                        with lock:
                            hw[i] += len(wire)
                        recv = (zlib.decompress(wire)
                                if self._compress == "zlib" else wire)
                        if self.fault_hook is not None:
                            recv = self.fault_hook(c, i, recv)
                        if zlib.crc32(recv) != crc:  # relay verifies per hop
                            ok = False
                            failed_hop = i
                            with lock:
                                res.retries += 1
                            if self.record:
                                tel.note_checksum_error(path_now.hop_key(i))
                            break
                        payload = recv
                    if ok:
                        break
                else:
                    # CRC retries exhausted on this route: heal or give up
                    if self._advance_route(ctx, res, my_epoch, failed_hop,
                                           lock):
                        continue      # requeue onto the replanned route
                    raise ChecksumError(
                        f"chunk {c.leaf} of {job.src} failed CRC "
                        f"{self.max_retries + 1} times")
                break
            with open(part, "r+b") as f:
                f.seek(c.start)
                f.write(payload)
            with lock:
                res.sent += 1
                done[c.leaf] = crc
                # amortized journaling: rewriting the whole sidecar per
                # chunk is O(n_chunks^2) and serializes the streams on the
                # shared lock — flush at most ~64 times per job (small jobs
                # still flush per chunk); the except path below flushes the
                # final state, so an *interrupt* loses nothing and a hard
                # kill re-sends at most flush_every chunks on resume
                if resume and len(done) % flush_every == 0:
                    self._flush_sidecar(job, done)

        def run_bucket(bucket) -> None:
            for c in bucket:              # ordered within a stream
                if c.leaf in done:
                    with lock:
                        res.skipped += 1
                    continue
                ship(c)

        buckets = list(job.buckets)
        pace = max(0.0, min(1.0, float(self.path.comm.pacing)))
        per_wave = max(1, int(round(len(buckets) * pace))) if buckets else 1
        flush_every = max(1, job.n_chunks // 64)
        # an exception out of any bucket (interrupt, vanished source,
        # ChecksumError) propagates after the journal is flushed, so the
        # next copy() resumes from exactly the chunks that completed
        try:
            with ThreadPoolExecutor(max_workers=per_wave) as pool:
                for w0 in range(0, len(buckets), per_wave):
                    futs = [pool.submit(run_bucket, b)
                            for b in buckets[w0:w0 + per_wave]]
                    for f in futs:
                        f.result()
        except BaseException:
            if resume and os.path.exists(part):   # vanished src: no state
                with lock:
                    self._flush_sidecar(job, done)
            raise

        if self.digest:
            res.sha256 = file_sha256(part)
        os.replace(part, job.dst)         # atomic publish
        try:
            shutil.copystat(job.src, job.dst)   # mirror diffs compare mtime
        except OSError:
            pass
        self._remove_sidecar(job.dst)
        self._account(job, res, ctx["route"], ctx["hop_order"], record_total)
        return res

    def _advance_route(self, ctx: dict, res: FileResult, my_epoch: int,
                       failed_hop: int, lock) -> bool:
        """A chunk exhausted its CRC retries: requeue it onto a healed route.

        Returns True when a newer route is in place — either this call's
        `reroute` callback replanned one, or a concurrent stream already
        did (their chunk hit the same dead hop first).  False means no
        heal is possible and the ChecksumError should propagate."""
        with lock:
            if ctx["epoch"] != my_epoch:
                return True           # another stream already healed
            if (self.reroute is None or ctx["reverse"]
                    or ctx["reroutes"] >= self.max_reroutes):
                return False
            if not self.reroute(self, failed_hop):
                return False
            new_route = self.path.route
            res.reroutes += 1
            res.reroute_history.append(
                {"route": [h.name for h in ctx["route"]],
                 "failed_hop": failed_hop,
                 "hop_wire_bytes": res.hop_wire_bytes})
            res.hop_wire_bytes = [0] * len(new_route)
            res.hop_modeled_s = [0.0] * len(new_route)
            ctx["reroutes"] += 1
            ctx["epoch"] += 1
            ctx["route"] = new_route
            ctx["hop_order"] = list(range(len(new_route)))
            return True

    def copy_tree(self, src_dir: str, dst_dir: str, *, resume: bool = True,
                  record_total: bool = True) -> list[FileResult]:
        """Directory manifest walk -> one FileJob per file (mpw-cp -r)."""
        out: list[FileResult] = []
        for root, _, files in os.walk(src_dir):
            rel = os.path.relpath(root, src_dir)
            troot = os.path.join(dst_dir, rel) if rel != "." else dst_dir
            os.makedirs(troot, exist_ok=True)
            for fn in sorted(files):
                if fn.endswith(TRANSIENT_SUFFIXES):
                    continue
                out.append(self.copy(os.path.join(root, fn),
                                     os.path.join(troot, fn), resume=resume,
                                     record_total=record_total))
        return out

    # -- accounting ---------------------------------------------------------
    def _account(self, job: FileJob, res: FileResult, route, hop_order,
                 record_total: bool) -> None:
        # the job is chunked ONCE (path/bottleneck chunk size) and every hop
        # relays those same chunks — so per-hop models and plans use the
        # hop's own stream count (per-leg tuning) with the job's chunking
        for i in hop_order:
            hop = route[i]
            res.hop_modeled_s[i] = simulate_transfer_s(
                res.hop_wire_bytes[i], hop.link, streams=hop.streams,
                chunk_bytes=self.path.chunk_bytes, pacing=hop.comm.pacing)
        res.wire_bytes = sum(res.hop_wire_bytes) + sum(
            sum(h["hop_wire_bytes"]) for h in res.reroute_history)
        res.modeled_s = sum(res.hop_modeled_s)   # store-and-forward: hops add
        if self.record:
            chunks, buckets = list(job.chunks), [list(b) for b in job.buckets]
            tel.note_plan(self.path.key, **st.plan_summary(
                chunks, buckets, self.path.streams, self.path.chunk_bytes,
                self.path.comm.pacing, algo="file",
                wire_bytes=res.wire_bytes))
            for i in hop_order:
                hop = route[i]
                tel.note_plan(self.path.hop_key(i), **st.plan_summary(
                    chunks, st.assign_streams(chunks, hop.streams),
                    hop.streams, self.path.chunk_bytes, hop.comm.pacing,
                    algo="file", wire_bytes=res.hop_wire_bytes[i]))
                tel.record(self.path.hop_key(i), res.hop_modeled_s[i],
                           nbytes=res.hop_wire_bytes[i])
            if record_total:
                tel.record(self.path.key, res.modeled_s,
                           nbytes=res.wire_bytes)
        if self.tuner is not None:
            cfg = self.tuner.observe(res.modeled_s)
            if cfg is not None:
                with self._path_lock:
                    self.path = self.path.with_(**cfg)
                if self.record:
                    tel.get_telemetry().path(self.path.key).note_retune(
                        None, cfg)

    # -- sidecar manifest ---------------------------------------------------
    @staticmethod
    def _sidecar_path(dst: str) -> str:
        return dst + SIDECAR_SUFFIX

    def _load_sidecar(self, job: FileJob) -> dict:
        """{chunk index: crc} of completed chunks, if the sidecar matches the
        current source (size + mtime) and chunking; else a fresh transfer."""
        try:
            with open(self._sidecar_path(job.dst)) as f:
                side = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {}
        if (side.get("size") != job.nbytes
                or side.get("mtime") != job.mtime
                or side.get("chunk_bytes") != self.path.chunk_bytes
                or not os.path.exists(job.dst + PART_SUFFIX)):
            self._remove_sidecar(job.dst)
            return {}
        return {int(k): v for k, v in side.get("done", {}).items()}

    def _flush_sidecar(self, job: FileJob, done: dict) -> None:
        side = {"src": job.src, "size": job.nbytes, "mtime": job.mtime,
                "chunk_bytes": self.path.chunk_bytes,
                "done": {str(k): v for k, v in done.items()}}
        path = self._sidecar_path(job.dst)
        with open(path + ".tmp", "w") as f:
            json.dump(side, f)
        os.replace(path + ".tmp", path)

    def _remove_sidecar(self, dst: str) -> None:
        try:
            os.remove(self._sidecar_path(dst))
        except FileNotFoundError:
            pass

    def _abort(self, dst: str) -> None:
        """Drop partial state (vanished source: nothing to resume toward)."""
        self._remove_sidecar(dst)
        try:
            os.remove(dst + PART_SUFFIX)
        except FileNotFoundError:
            pass

    @staticmethod
    def _ensure_part(part: str, nbytes: int) -> None:
        """Pre-size the partial file so chunk writes land at their offsets."""
        if not os.path.exists(part) or os.path.getsize(part) != nbytes:
            with open(part, "wb") as f:
                if nbytes:
                    f.seek(nbytes - 1)
                    f.write(b"\0")


def local_transfer() -> FileTransfer:
    """Single-host fallback engine (the mirror default): local-fabric path,
    no compression, telemetry off, no finalize digest (the mirror discards
    the result; per-chunk CRCs still verify every byte)."""
    from repro.core.path import local_path
    return FileTransfer(local_path(), record=False, digest=False)
