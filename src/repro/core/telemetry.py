"""Per-path transfer telemetry (the `mpwtest` diagnostics, made persistent).

MPWide ships a runtime diagnostic (`mpwtest`) that measures what each path
actually achieves so operators can tune stream counts and chunk sizes.  This
module is that feedback channel for WideJAX: every :class:`WidePath` gets a
:class:`PathTelemetry` slot in a process-global registry keyed by
``path.key``, holding

  * the **static plan** of the traffic the path carries (payload bytes per
    transfer, chunk count, streams actually used vs. configured, pacing) —
    recorded at trace/build time by ``streamed_psum`` / ``pod_shift`` /
    ``build_train_step``, which is the honest place to capture it: inside a
    jitted step individual transfers cannot be timed from the host;
  * **measured samples** (wall seconds per executed step, bytes moved) —
    recorded by the host-side loops (`runtime/train_loop.py`,
    `runtime/serve_loop.py`, the benchmarks, or `MPW.Observe`), from which
    achieved GB/s and step-time statistics derive;
  * the **retune history** the online autotuner produced for the path.

The registry is what `MPW.PathStats` / `MPW.Report` read, and what the
:class:`~repro.core.autotune.OnlineTuner` consumes as its cost signal.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field
from typing import Any, Optional


@dataclass(frozen=True)
class PlanInfo:
    """Static shape of one transfer over a path (trace-time knowledge)."""
    payload_bytes: int            # bytes the transfer delivers (logical)
    n_chunks: int                 # chunks the payload is cut into
    streams_used: int             # non-empty stream buckets
    streams_configured: int       # path.streams (the knob)
    chunk_bytes: int              # path.chunk_bytes (the knob)
    pacing: float                 # fraction of streams in flight per wave
    load_balance: float = 1.0     # max bucket load / mean bucket load
    algo: str = "psum"            # collective algorithm (psum|ring|ring2|shift)
    wire_bytes: int = 0           # modeled per-pod link bytes (0 = unknown)

    @property
    def stream_utilization(self) -> float:
        """Fraction of configured streams the plan can actually feed."""
        if self.streams_configured <= 0:
            return 1.0
        return min(1.0, self.streams_used / self.streams_configured)


@dataclass
class PathTelemetry:
    """Rolling stats for one path.  Mutators and readers synchronize on a
    per-path lock: the train loop records while other threads (async
    checkpoint writer, a monitoring thread calling MPW.Report) read."""
    key: str
    window: int = 256
    plan: Optional[PlanInfo] = None
    transfers: int = 0
    total_bytes: int = 0
    total_seconds: float = 0.0
    # modeled per-step exposure split (repro.core.overlap.modeled_exposure):
    # exposed_s = cross-pod seconds left on the critical path, overlapped_s
    # = seconds hidden under compute.  Noted at build/retune time by the
    # step builder; None until a step with a compute window was built.
    exposed_s: Optional[float] = None
    overlapped_s: Optional[float] = None
    samples: deque = field(default_factory=deque)   # (step, seconds, bytes)
    retunes: list = field(default_factory=list)     # (step, {knob: value})
    checksum_errors: int = 0      # per-hop CRC failures (chaos signal)
    reships: int = 0              # KV ship retries on the same route
    reroutes: int = 0             # KV ships replanned over backup links
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def note_plan(self, **kw) -> None:
        with self._lock:
            self.plan = PlanInfo(**kw)

    def note_overlap(self, exposed_s: float, overlapped_s: float) -> None:
        with self._lock:
            self.exposed_s = float(exposed_s)
            self.overlapped_s = float(overlapped_s)

    def note_retune(self, step: Optional[int], config: dict) -> None:
        with self._lock:
            self.retunes.append((step, dict(config)))

    def note_checksum_error(self, n: int = 1) -> None:
        """Count a failed per-chunk CRC verification (file transfers check
        every chunk per hop; a corrupting link shows up here before it
        shows up as throughput collapse)."""
        with self._lock:
            self.checksum_errors += int(n)

    def note_ship_retry(self, reships: int = 0, reroutes: int = 0) -> None:
        """Count KV-ship fault responses (core/serving.py): retries of a
        failed hop on the same route and reroutes over backup links."""
        with self._lock:
            self.reships += int(reships)
            self.reroutes += int(reroutes)

    def record(self, seconds: float, nbytes: Optional[int] = None,
               step: Optional[int] = None) -> None:
        with self._lock:
            if nbytes is None:
                # prefer the modeled wire bytes when the plan knows them:
                # achieved GB/s then measures what the link carried, not the
                # logical payload (a site-hierarchical WAN stage carries far
                # fewer bytes than the payload it delivers)
                nbytes = ((self.plan.wire_bytes or self.plan.payload_bytes)
                          if self.plan else 0)
            self.transfers += 1
            self.total_bytes += int(nbytes)
            self.total_seconds += float(seconds)
            self.samples.append((step, float(seconds), int(nbytes)))
            while len(self.samples) > self.window:
                self.samples.popleft()

    # -- derived ------------------------------------------------------------
    def achieved_Bps(self) -> float:
        """Bytes/s over the rolling window (0 when nothing was timed)."""
        with self._lock:
            samples = list(self.samples)
        secs = sum(s for _, s, _ in samples)
        byts = sum(b for _, _, b in samples)
        return byts / secs if secs > 0 else 0.0

    def mean_seconds(self) -> float:
        with self._lock:
            samples = list(self.samples)
        if not samples:
            return 0.0
        return sum(s for _, s, _ in samples) / len(samples)

    def summary(self) -> dict[str, Any]:
        with self._lock:
            samples = list(self.samples)
            out: dict[str, Any] = {
                "key": self.key,
                "transfers": self.transfers,
                "total_bytes": self.total_bytes,
                "total_seconds": self.total_seconds,
                "retunes": list(self.retunes),
                "checksum_errors": self.checksum_errors,
                "reships": self.reships,
                "reroutes": self.reroutes,
            }
            plan = self.plan
            exposed, overlapped = self.exposed_s, self.overlapped_s
        secs = sum(s for _, s, _ in samples)
        byts = sum(b for _, _, b in samples)
        out["window_mean_s"] = secs / len(samples) if samples else 0.0
        out["achieved_GBps"] = (byts / secs if secs > 0 else 0.0) / 1e9
        if plan is not None:
            out["plan"] = asdict(plan)
            out["stream_utilization"] = plan.stream_utilization
        if exposed is not None:
            out["exposed_s"] = exposed
            out["overlapped_s"] = overlapped
            total = exposed + (overlapped or 0.0)
            out["overlap_efficiency"] = ((overlapped or 0.0) / total
                                         if total > 0 else 0.0)
        return out


class Telemetry:
    """Process-global registry of :class:`PathTelemetry`, keyed by path key.

    Thread-safe: the async checkpoint writer and benchmark subprocesses may
    record concurrently with the train loop.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._paths: dict[str, PathTelemetry] = {}

    def path(self, key: str) -> PathTelemetry:
        with self._lock:
            if key not in self._paths:
                self._paths[key] = PathTelemetry(key=key)
            return self._paths[key]

    def note_plan(self, key: str, **kw) -> None:
        self.path(key).note_plan(**kw)

    def record(self, key: str, seconds: float, nbytes: Optional[int] = None,
               step: Optional[int] = None) -> None:
        self.path(key).record(seconds, nbytes=nbytes, step=step)

    @contextmanager
    def timed(self, key: str, nbytes: Optional[int] = None,
              step: Optional[int] = None):
        """Time a host-side block and record it against a path.

        The wall-clock read is the point of this helper — it measures host
        time by design, so it carries the one justified R5 waiver in core/
        (deterministic replays record modeled seconds, never `timed`).
        """
        t0 = time.perf_counter()    # mpwlint: disable=R5
        yield
        self.record(key, time.perf_counter() - t0,   # mpwlint: disable=R5
                    nbytes=nbytes, step=step)

    def report(self, prefix: Optional[str] = None) -> dict[str, dict]:
        """{path key: summary dict} for every path seen this process.

        `prefix` filters to one path and its hops: a multi-hop path records
        under its own key plus one slot per hop (``{key}/hop{i}:{link}``) or
        per hierarchical stage (``{key}/intra``, ``{key}/wan``), so
        ``report(prefix=path.key)`` returns the whole per-hop breakdown."""
        with self._lock:
            paths = list(self._paths.items())   # snapshot: reset() may race
        if prefix is not None:
            paths = [(k, p) for k, p in paths
                     if k == prefix or k.startswith(prefix + "/")]
        return {k: p.summary() for k, p in paths}

    def format_report(self) -> str:
        """Markdown table of the report (human-facing `MPW.Report`)."""
        rep = self.report()
        if not rep:
            return "(no paths recorded)"
        rows = ["| path | transfers | bytes/xfer | wire/pod (algo) | "
                "streams used/conf | chunk | window mean | achieved "
                "| exposed | overlap |",
                "|---|---|---|---|---|---|---|---|---|---|"]
        for key in sorted(rep):
            s = rep[key]
            plan = s.get("plan")
            if plan:
                per = plan["payload_bytes"]
                wire = (f"{_fmt_bytes(plan['wire_bytes'])} ({plan['algo']})"
                        if plan.get("wire_bytes") else "-")
                streams = f"{plan['streams_used']}/{plan['streams_configured']}"
                chunk = _fmt_bytes(plan["chunk_bytes"])
            else:
                per = s["total_bytes"] / max(s["transfers"], 1)
                wire, streams, chunk = "-", "-", "-"
            if "exposed_s" in s:
                exposed = f"{s['exposed_s']*1e3:.1f} ms"
                overlap = f"{s['overlap_efficiency']*100:.0f}%"
            else:
                exposed, overlap = "-", "-"
            rows.append(
                f"| {key} | {s['transfers']} | {_fmt_bytes(per)} | {wire} "
                f"| {streams} | {chunk} | {s['window_mean_s']*1e3:.1f} ms "
                f"| {s['achieved_GBps']:.3f} GB/s | {exposed} | {overlap} |")
        return "\n".join(rows)

    def reset(self, key: Optional[str] = None) -> None:
        with self._lock:
            if key is None:
                self._paths.clear()
            else:
                self._paths.pop(key, None)


def _fmt_bytes(n: float) -> str:
    for unit, div in (("GiB", 1 << 30), ("MiB", 1 << 20), ("KiB", 1 << 10)):
        if n >= div:
            return f"{n / div:.1f} {unit}"
    return f"{int(n)} B"


_GLOBAL = Telemetry()


def get_telemetry() -> Telemetry:
    return _GLOBAL


# module-level conveniences (hot-path call sites stay one line)
def note_plan(key: str, **kw) -> None:
    _GLOBAL.note_plan(key, **kw)


def note_overlap(key: str, exposed_s: float, overlapped_s: float) -> None:
    _GLOBAL.path(key).note_overlap(exposed_s, overlapped_s)


def record(key: str, seconds: float, nbytes: Optional[int] = None,
           step: Optional[int] = None) -> None:
    _GLOBAL.record(key, seconds, nbytes=nbytes, step=step)


def note_checksum_error(key: str, n: int = 1) -> None:
    _GLOBAL.path(key).note_checksum_error(n)


def note_ship_retry(key: str, reships: int = 0, reroutes: int = 0) -> None:
    _GLOBAL.path(key).note_ship_retry(reships=reships, reroutes=reroutes)
