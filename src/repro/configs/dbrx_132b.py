"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) head_dim=128 d_ff=10752 vocab=100352,
MoE 16 experts top-4.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4),
))
