"""Architecture config registry.

``load_all()`` imports every per-arch module exactly once; each module calls
``base.register(...)`` at import time with the exact published dimensions.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    CommConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    cell_applicable,
    get_config,
    list_archs,
    smoke_config,
)

_ARCH_MODULES = [
    "pixtral_12b",
    "h2o_danube3_4b",
    "llama3_2_3b",
    "qwen1_5_0_5b",
    "qwen2_5_14b",
    "dbrx_132b",
    "phi3_5_moe",
    "zamba2_1_2b",
    "mamba2_780m",
    "whisper_medium",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
