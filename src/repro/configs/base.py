"""Config system for WideJAX.

Every assigned architecture registers a :class:`ModelConfig` here (exact
published dimensions) plus a reduced smoke variant derived by
:func:`smoke_config`.  Input shapes are global (arch × shape) cells; the
launcher cross-products them with meshes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # "fine-grained" MoE (dbrx) keeps d_ff per expert as given; capacity factor
    # is only used by the dropping router variant.
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD hyper-parameters."""
    state_dim: int          # N (ssm_state)
    head_dim: int = 64      # P
    expand: int = 2         # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256        # SSD chunk length
    ngroups: int = 1        # B/C groups


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # 0 => attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default: d_model // num_heads
    qkv_bias: bool = False
    sliding_window: Optional[int] = None # SWA window (danube)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every `attn_every`
    # mamba blocks (weights shared, LoRA-free simplification).
    attn_every: int = 0
    # enc-dec (whisper): encoder depth; decoder depth = num_layers.
    encoder_layers: int = 0
    source_len: int = 1500         # whisper: frames after conv frontend stub
    # vlm (pixtral): input_specs feeds precomputed patch embeddings of this
    # many positions prepended to the token stream.
    vision_tokens: int = 0
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM state or SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d          # q,k,v,o
        if self.qkv_bias:
            attn += n_q + 2 * n_kv
        mlp = 3 * d * f                                   # swiglu: gate,up,down
        if self.moe is not None:
            mlp = self.moe.num_experts * 3 * d * f + d * self.moe.num_experts
        norms = 2 * d
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            blk = (d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)  # in_proj
                   + s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)  # conv
                   + nheads                                               # A, dt_bias -> 2*nheads
                   + nheads
                   + d_in * d                                             # out_proj
                   + d)                                                   # norm
            total = self.num_layers * blk
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            mamba_blk = (d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
                         + s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)
                         + 2 * nheads + d_in * d + d)
            shared_attn = attn + 3 * d * f + norms  # one shared block
            total = self.num_layers * mamba_blk + shared_attn
        else:
            total = self.num_layers * (attn + mlp + norms)
            if self.encoder_layers:
                # encoder blocks + decoder cross-attention
                total += self.encoder_layers * (attn + mlp + norms)
                total += self.num_layers * (attn + d)
        total += v * d                                   # embed
        if not self.tie_embeddings:
            total += v * d                               # lm head
        total += d                                       # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.moe.num_experts * 3 * d * f
        active_experts = self.moe.top_k * 3 * d * f
        return self.param_count() - self.num_layers * (dense_experts - active_experts)


# ---------------------------------------------------------------------------
# shapes (assigned cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("full quadratic attention: 500k KV cache does not fit "
                       "and prefill is O(L^2); skipped per assignment rules")
    return True, ""


# ---------------------------------------------------------------------------
# comm / mesh / train configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CommConfig:
    """MPWide path configuration (paper §1.3.1)."""
    mode: str = "hierarchical"   # flat | hierarchical | gateway
    streams: int = 32            # paper: 1 local, >=32 WAN, <=256 efficient
    chunk_mb: float = 8.0        # MPW_setChunkSize analogue
    compress: str = "none"       # none | bf16 | int8   (beyond-paper)
    autotune: bool = True        # MPW_setAutoTuning (default on, like paper)
    pacing: float = 1.0          # MPW_setPacingRate: fraction in flight
    # cross-pod all-reduce algorithm (beyond-paper): "psum" lowers each
    # chunk to one collective (gather-based when compressed: per-pod bytes
    # grow linearly in pod count); "ring"/"ring2" are bandwidth-optimal
    # ppermute rings with per-step requantization (ring2: bidirectional,
    # half the latency-step depth) — see repro/core/ring.py
    algo: str = "psum"           # psum | ring | ring2
    # gradient-sync bucket size (beyond-paper latency hiding): > 0 splits
    # the gradient tree into ~bucket_mb buckets along the stacked `layers`
    # dim so each bucket's cross-pod sync can flush during backprop and the
    # exposed tail is consumed bucket-by-bucket interleaved with the
    # optimizer — see repro/core/buckets.py.  0 disables bucketing (one
    # whole-tree sync, the pre-bucketing behaviour).
    bucket_mb: float = 0.0
    # local-SGD cadence (beyond-paper elasticity, see repro/core/localsgd.py):
    # K > 1 keeps each step's gradient sync site-local and ships a model
    # *delta* across the WAN only every K-th step.  1 (default) is fully
    # synchronous — bit-identical to the pre-elastic behaviour.
    local_steps: int = 1


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # production shapes are fixed by the assignment:
    #   single-pod (16,16) ("data","model"); multi-pod (2,16,16) ("pod",...)
    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    zero1: bool = True           # shard optimizer state over data axis
    microbatches: int = 1        # gradient accumulation steps
    loss_dtype: str = "float32"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    comm: CommConfig = field(default_factory=CommConfig)
    train: TrainConfig = field(default_factory=TrainConfig)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import arch modules lazily so `configs.base` has no import cycle
    from repro import configs as _pkg  # noqa: F401
    _pkg.load_all()


# ---------------------------------------------------------------------------
# smoke reduction
# ---------------------------------------------------------------------------

def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests.

    Keeps every structural feature (GQA ratio shape, bias, SWA, MoE top-k,
    SSD, shared-attn interleave, enc-dec, vision stub) while shrinking width,
    depth and tables so a forward+train step runs on one CPU device.
    """
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=32 if cfg.num_heads else None,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=0,
        sliding_window=64 if cfg.sliding_window else None,
        vision_tokens=16 if cfg.vision_tokens else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        source_len=24 if cfg.encoder_layers else cfg.source_len,
        remat=False,
    )
    if cfg.num_heads:
        # preserve the GQA grouping style: MHA stays MHA, GQA stays grouped
        if cfg.num_kv_heads == cfg.num_heads:
            kw["num_kv_heads"] = kw["num_heads"]
        else:
            kw["num_kv_heads"] = max(1, kw["num_heads"] // max(1, cfg.num_heads // cfg.num_kv_heads))
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(num_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=32, chunk=16)
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["num_layers"] = 4
    return dataclasses.replace(cfg, **kw)
