"""whisper-medium — encoder-decoder with conv frontend (stub).

[arXiv:2212.04356; unverified]
24L (decoder) + 24L (encoder) d_model=1024 16H (MHA kv=16) head_dim=64
d_ff=4096 vocab=51865.  The conv1d+log-mel frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings (1500 frames).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    encoder_layers=24,
    source_len=1500,
    rope_theta=0.0,        # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
))
