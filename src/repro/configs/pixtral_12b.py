"""pixtral-12b — Pixtral-ViT frontend (stub) + Mistral-Nemo-style backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072.
The vision frontend is a STUB per assignment: ``input_specs()`` provides
precomputed patch embeddings (1024 patch tokens) prepended to the text stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    vision_tokens=1024,
))
