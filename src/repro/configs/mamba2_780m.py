"""mamba2-780m — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]
48L d_model=1536 (attn-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64 => 48 SSD heads per block.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
))
