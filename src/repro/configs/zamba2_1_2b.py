"""zamba2-1.2b — Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]
38L d_model=2048 32H (MHA kv=32) head_dim=64 d_ff=8192 vocab=32000,
ssm_state=64.  One shared (weight-tied) attention+MLP block is interleaved
every 6 mamba blocks (Zamba-style shared block).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    attn_every=6,
))
