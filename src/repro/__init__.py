"""WideJAX: MPWide's wide-area communication model reproduced on JAX.

Importing the package installs the JAX compatibility adapters (new-style
``jax.shard_map`` / ``jax.set_mesh`` API on older jaxlib) before any
submodule touches them.
"""
from repro import compat  # noqa: F401  (side effect: compat.install())
