"""ServingEngine: disaggregated prefill/decode with continuous batching.

Glues the three serving pieces together with *real* model work:

* `core.serving.ContinuousBatcher` — the slot scheduler (virtual step clock,
  deterministic event timeline);
* `core.kvship` — the prefilled KV cache crossing the WAN as chunked leaves
  over a `WidePath` (``mode="disagg"``), with exact per-hop wire bytes under
  ``serve/req{rid}/kv`` telemetry keys;
* `runtime.serve_loop.Server` — the decode StepBundle, driven here with
  per-sequence ``(B,)`` positions so every slot sits at its own depth.

Engine semantics: one engine step == one batcher step == one decode token
per occupied slot.  Prefill and KV-ship execute synchronously at their
transition step (the batcher runs with ``ship_steps=0``), so a monolithic
engine (``mode="mono"``) and a disaggregated one replay the *same* schedule
— the parity test asserts their tokens are bit-identical, because decode is
row-local and the ``none`` codec ships bytes unchanged.  Modeled WAN
seconds still land in telemetry via the shipper.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core.kvship import KVShipPlan, ShipError, plan_kv_ship, ship_kv
from repro.core.path import WidePath
from repro.core.serving import ContinuousBatcher
from repro.runtime.serve_loop import Server


class ServingEngine:
    """Continuous-batching serving with optional prefill/decode split.

    Parameters
    ----------
    rc: run config; ``rc.shape.global_batch`` is the decode slot count and
        ``rc.shape.seq_len`` the decode cache length.
    mesh: decode-site mesh (prefill runs on the same process here; the
        disaggregation is in the KV bytes crossing `path`).
    mode: ``"mono"`` (prefill feeds decode in-memory) or ``"disagg"``
        (prefill KV is shipped over `path` before decode may start).
    path: the WAN `WidePath` KV caches cross when ``mode="disagg"``.
    route / topo: the :class:`~repro.core.topology.Route` the path was
        compiled from plus its topology — with these, each real KV ship
        runs under the route's `LinkProfile` fault schedules (reship on a
        failed hop through `retry`, reroute over `topo` after
        `max_reships`); a :class:`~repro.core.kvship.ShipError` (no route
        left) degrades the engine to in-memory KV handoff (collocated
        mono fallback, ``stats()["degraded"]``).
    deadline_steps / membership / prefill_site / decode_site / log: passed
        to the batcher — per-request SLOs with shedding, serve failover
        off evicted sites, incidents into `log`.
    """

    def __init__(self, rc: RunConfig, mesh, *, mode: str = "mono",
                 path: Optional[WidePath] = None, params=None, seed: int = 0,
                 queue_limit: int = 64, step_s: float = 1e-2,
                 route=None, topo=None, retry=None, max_reships: int = 2,
                 ship_timeout_s: float = 30.0, deadline_steps=None,
                 shed: bool = True, membership=None,
                 prefill_site: Optional[str] = None,
                 decode_site: Optional[str] = None, log=None):
        if mode not in ("mono", "disagg"):
            raise ValueError(f"mode must be 'mono' or 'disagg', got {mode!r}")
        if mode == "disagg" and path is None:
            raise ValueError(f"mode='disagg' needs a WidePath to ship KV "
                             f"over, got path={path!r}")
        if rc.model.encoder_layers:
            raise ValueError(
                f"ServingEngine is decoder-only; {rc.model.name!r} has "
                f"{rc.model.encoder_layers} encoder layers")
        self.rc = rc
        self.mode = mode
        self.path = path
        self.route = route
        self.topo = topo
        self.retry = retry
        self.max_reships = int(max_reships)
        self.ship_timeout_s = float(ship_timeout_s)
        self.log = log
        self._degraded = False
        self.server = Server(rc, mesh, params=params, seed=seed)
        self.model = self.server.bundle.model
        self.max_slots = rc.shape.global_batch
        self.max_len = rc.shape.seq_len
        self.batcher = ContinuousBatcher(
            self.max_slots, queue_limit, prefill_steps=1, ship_steps=0,
            step_s=step_s, deadline_steps=deadline_steps, shed=shed,
            log=log, membership=membership, prefill_site=prefill_site,
            decode_site=decode_site)
        self.cache = self.server.init_cache()
        self._pos = np.zeros(self.max_slots, np.int32)
        self._tok = np.zeros((self.max_slots, 1), np.int32)
        self._decoding: dict[int, int] = {}     # slot -> rid
        self._prompts: dict[int, np.ndarray] = {}
        self._outputs: dict[int, list] = {}
        self.results: dict[int, np.ndarray] = {}   # rid -> generated tokens
        self._n_events = 0
        self._ship_plans: dict[tuple, KVShipPlan] = {}
        self._prefill_fn = jax.jit(
            lambda p, toks: self.model.prefill(p, {"tokens": toks}))

    # -- request intake -----------------------------------------------------
    def submit(self, prompt_tokens: np.ndarray, max_new: int,
               deadline_steps: Optional[int] = None) -> Optional[int]:
        """Admit one request (or None when admission control rejects or
        sheds it)."""
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        S_p = prompt.shape[0]
        w = self.rc.model.sliding_window
        if S_p + max_new > self.max_len or (w and S_p > w):
            raise ValueError(
                f"prompt_len={S_p} + max_new={max_new} exceeds the decode "
                f"cache (max_len={self.max_len}, window={w})")
        rid = self.batcher.submit(S_p, max_new, deadline_steps=deadline_steps)
        if rid is not None:
            self._prompts[rid] = prompt
        return rid

    # -- engine step --------------------------------------------------------
    def step(self) -> int:
        """One engine step: batcher transition + the real work it implies."""
        pre = dict(self._decoding)   # slots decoding before this step
        self.batcher.step_once()
        tl = self.batcher.timeline()
        events = tl[self._n_events:]
        self._n_events = len(tl)
        if pre:
            self._decode_tick(pre)   # batcher rule (3): pre-existing slots
        for kind, tag, _step in events:
            rid = int(tag[3:])
            if kind == "decode":
                self._on_decode_start(rid)
            elif kind == "complete":
                self._on_complete(rid)
            elif kind == "timeout":
                self._on_abort(rid, keep_prompt=False)
            elif kind == "requeue":
                self._on_abort(rid, keep_prompt=True)
            elif kind in ("shed", "reject"):
                self._prompts.pop(rid, None)
        return len(events)

    def run_to_completion(self, max_steps: int = 100_000) -> dict:
        """Step until every submitted request is terminal; returns stats."""
        steps = 0
        while self.batcher.active() > 0:
            if steps >= max_steps:
                raise RuntimeError(
                    f"engine did not drain within {max_steps} steps: "
                    f"{self.batcher.active()} request(s) still live")
            self.step()
            steps += 1
        return self.batcher.stats()

    # -- internals ----------------------------------------------------------
    def _decode_tick(self, slots: dict) -> None:
        """One real batched decode step; only `slots` rows advance."""
        bundle = self.server.bundle
        logits, self.cache = bundle.fn(
            self.server.params, self.cache, jnp.asarray(self._pos),
            jnp.asarray(self._tok))
        toks = np.asarray(jnp.argmax(logits[:, -1:, :], axis=-1))[:, 0]
        for slot, rid in slots.items():
            self._outputs[rid].append(int(toks[slot]))
            self._pos[slot] += 1
            self._tok[slot, 0] = toks[slot]

    def _on_decode_start(self, rid: int) -> None:
        """Prefill the request's prompt, ship its KV if disaggregated, land
        it in the decode cache, and bank the first token."""
        slot = self.batcher.slot_of(rid)
        prompt = self._prompts[rid]
        S_p = prompt.shape[0]
        logits, pcache = self._prefill_fn(self.server.params, prompt[None, :])
        kv = {n: np.asarray(pcache[n][:, 0]) for n in ("k", "v")}
        if self.mode == "disagg" and not self._degraded:
            geom = tuple(sorted((n, tuple(a.shape)) for n, a in kv.items()))
            if geom not in self._ship_plans:
                self._ship_plans[geom] = plan_kv_ship(kv, self.path)
            try:
                kv, res = ship_kv(kv, self._ship_plans[geom], rid,
                                  step=self.batcher.now(), route=self.route,
                                  retry=self.retry,
                                  max_reships=self.max_reships,
                                  topo=self.topo, log=self.log,
                                  timeout_s=self.ship_timeout_s)
                self.batcher.note_ship(rid, reships=res.reships,
                                       reroutes=res.reroutes)
            except ShipError as e:
                # no surviving route: hand the KV over in memory from here
                # on (collocated mono fallback) and flag it
                self._degraded = True
                self.batcher.degrade(reason=str(e))
        cache = dict(self.cache)
        for n, leaf in kv.items():
            cache[n] = self.cache[n].at[:, slot, :S_p].set(
                jnp.asarray(leaf).astype(self.cache[n].dtype))
        self.cache = cache
        first = int(np.asarray(jnp.argmax(logits[0, -1])))
        self._pos[slot] = S_p
        self._tok[slot, 0] = first
        self._outputs[rid] = [first]
        self._decoding[slot] = rid

    def _on_complete(self, rid: int) -> None:
        slot = None
        for s, r in self._decoding.items():
            if r == rid:
                slot = s
                break
        if slot is not None:
            del self._decoding[slot]
        self.results[rid] = np.asarray(self._outputs.pop(rid), np.int64)

    def _on_abort(self, rid: int, *, keep_prompt: bool) -> None:
        """A request left the pipeline without completing: `timeout` drops
        it for good, `requeue` (serve failover) keeps the prompt so the
        re-queued request prefills again from scratch."""
        slot = None
        for s, r in self._decoding.items():
            if r == rid:
                slot = s
                break
        if slot is not None:
            del self._decoding[slot]
        self._outputs.pop(rid, None)
        if not keep_prompt:
            self._prompts.pop(rid, None)
