"""Server: batched prefill + decode serving loop.

The decode loop supports two position modes:

* scalar ``pos`` — every row of the batch sits at the same depth (the
  original fixed-batch path; one traced program, unchanged semantics);
* per-sequence ``(B,)`` positions — rows sit at different depths, as the
  continuous batcher requires (each slot's request prefilled a different
  prompt length).  Finished rows (EOS or per-row budget) stop counting
  toward output lengths and the loop exits as soon as every row is done,
  so freed slots return to the scheduler instead of idling to ``max_new``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core.telemetry import get_telemetry
from repro.models.param import tree_init
from repro.runtime.step import build_serve_step


@dataclass
class ServeResult:
    tokens: np.ndarray            # (B, steps); rows padded after they finish
    steps: int
    lengths: Optional[np.ndarray] = None   # (B,) tokens generated per row


class Server:
    """Greedy batched decoding against the decode StepBundle.

    Production serving layers the continuous batcher (`core.serving`) and
    the KV shipper (`core.kvship`) on top — see `runtime.serving`.  This
    loop is the per-step engine both modes share.
    """

    def __init__(self, rc: RunConfig, mesh, params=None, seed: int = 0):
        self.rc = rc
        self.mesh = mesh
        self.bundle = build_serve_step(rc, mesh, kind="decode")
        sh = self._sh(self.bundle.state_specs["params"])
        params = params if params is not None else tree_init(self.bundle.param_defs, seed)
        self.params = jax.device_put(params, sh)
        # signatures bundle.fn has compiled: (B, pos kind, cache geometry).
        # A new cache geometry (e.g. a longer max_len cache swapped in) or a
        # switch between scalar and vector pos recompiles just like a new
        # batch size does — all three must be excluded from timings.
        self._warm_shapes: set = set()

    def _sh(self, specs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def init_cache(self):
        from repro.models.param import tree_init as ti
        cache = ti(self.bundle.cache_defs, 0)      # zeros
        return jax.device_put(cache, self._sh(self.bundle.state_specs["cache"]))

    @staticmethod
    def _compile_sig(B: int, vec: bool, cache) -> tuple:
        geom = tuple(sorted((n, tuple(x.shape), str(x.dtype))
                            for n, x in cache.items()))
        return (B, "vec" if vec else "scalar", geom)

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16,
                 prefill_pos: Optional[Any] = None, *,
                 eos_id: Optional[int] = None,
                 max_new_per_seq: Optional[np.ndarray] = None,
                 cache=None, pad_id: int = 0) -> ServeResult:
        """prompt_tokens: (B, 1) last prompt token per sequence.

        `prefill_pos` is a scalar (all rows at one depth) or a (B,) vector
        of per-row depths; pass `cache=` to decode against a prefilled cache
        (the default zero cache exercises the step shape only).  `eos_id`
        and `max_new_per_seq` finish rows early; the loop stops once every
        row is done and `ServeResult.lengths` reports per-row token counts.
        """
        B = prompt_tokens.shape[0]
        if cache is None:
            cache = self.init_cache()
        vec = (max_new_per_seq is not None
               or (prefill_pos is not None and np.ndim(prefill_pos) >= 1))
        sig = self._compile_sig(B, vec, cache)
        if vec:
            pos0 = (np.zeros(B, np.int32) if prefill_pos is None
                    else np.asarray(prefill_pos, np.int32).reshape(B))
            pos_base = jnp.asarray(pos0)
        else:
            pos_base = jnp.int32(prefill_pos if prefill_pos is not None else 0)
        budget = (np.full(B, max_new, np.int64) if max_new_per_seq is None
                  else np.asarray(max_new_per_seq, np.int64).reshape(B))
        tok = jax.device_put(jnp.asarray(prompt_tokens, jnp.int32),
                             self._sh(self.bundle.batch_specs["tokens"]))
        out = []
        lengths = np.zeros(B, np.int64)
        done = lengths >= budget
        tele = get_telemetry()
        path_key = self.bundle.path.key
        steps = 0
        for i in range(int(budget.max(initial=0))):
            if done.all():
                break
            t0 = time.perf_counter()
            logits, cache = self.bundle.fn(self.params, cache, pos_base + i, tok)
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            step_tok = np.asarray(tok)[:, 0]          # blocks on the step
            if sig in self._warm_shapes:
                tele.record(path_key, time.perf_counter() - t0, step=i)
            else:   # first call per compile signature is compile-dominated
                self._warm_shapes.add(sig)
            active = ~done
            lengths += active
            if eos_id is not None:
                done = done | (active & (step_tok == eos_id))
            done = done | (lengths >= budget)
            out.append(np.where(active, step_tok, pad_id))
            steps += 1
        tokens = (np.stack(out, axis=1) if out
                  else np.zeros((B, 0), np.int64))
        return ServeResult(tokens=tokens, steps=steps, lengths=lengths)
