"""Server: batched prefill + decode serving loop."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core.telemetry import get_telemetry
from repro.models.param import tree_init
from repro.runtime.step import build_serve_step


@dataclass
class ServeResult:
    tokens: np.ndarray            # (B, generated)
    steps: int


class Server:
    """Greedy batched decoding against the decode StepBundle.

    Production serving would add continuous batching and paged caches; this
    server exercises the assigned decode cells (one-token steps against a
    seq_len cache) and the examples.
    """

    def __init__(self, rc: RunConfig, mesh, params=None, seed: int = 0):
        self.rc = rc
        self.mesh = mesh
        self.bundle = build_serve_step(rc, mesh, kind="decode")
        sh = self._sh(self.bundle.state_specs["params"])
        params = params if params is not None else tree_init(self.bundle.param_defs, seed)
        self.params = jax.device_put(params, sh)
        self._warm_shapes: set = set()   # batch sizes bundle.fn has compiled

    def _sh(self, specs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def init_cache(self):
        from repro.models.param import tree_init as ti
        cache = ti(self.bundle.cache_defs, 0)      # zeros
        return jax.device_put(cache, self._sh(self.bundle.state_specs["cache"]))

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16,
                 prefill_pos: Optional[int] = None) -> ServeResult:
        """prompt_tokens: (B, 1) last prompt token per sequence (the cache is
        zeros here — real deployments prefill; see examples/serve_decode.py)."""
        B = prompt_tokens.shape[0]
        cache = self.init_cache()
        pos = jnp.int32(prefill_pos if prefill_pos is not None else 0)
        tok = jax.device_put(jnp.asarray(prompt_tokens, jnp.int32),
                             self._sh(self.bundle.batch_specs["tokens"]))
        out = []
        tele = get_telemetry()
        path_key = self.bundle.path.key
        for i in range(max_new):
            t0 = time.perf_counter()
            logits, cache = self.bundle.fn(self.params, cache, pos + i, tok)
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            step_tok = np.asarray(tok)[:, 0]          # blocks on the step
            if B in self._warm_shapes:
                tele.record(path_key, time.perf_counter() - t0, step=i)
            else:   # first call per batch shape is compile-dominated: skip
                self._warm_shapes.add(B)
            out.append(step_tok)
        return ServeResult(tokens=np.stack(out, axis=1), steps=max_new)
