from repro.runtime.serve_loop import Server, ServeResult  # noqa: F401
from repro.runtime.serving import ServingEngine  # noqa: F401
from repro.runtime.step import StepBundle, build_serve_step, build_train_step  # noqa: F401
from repro.runtime.train_loop import (InjectedFault, StragglerDetector,  # noqa: F401
                                      Trainer, elastic_restart)
