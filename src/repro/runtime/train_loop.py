"""Trainer: the production loop — checkpoint/restart, straggler detection,
fault injection for tests, elastic restart on a resized mesh.

Fault-tolerance model (1000+-node design, §DESIGN.md):
  * async chunked checkpoints every `ckpt_every` steps (mpw-cp store) with
    DataGather replication to a peer location;
  * on step failure (device error, injected fault) the loop restores the
    latest checkpoint and continues — the restore path is identical to a
    cold elastic restart on a different mesh because the store reshards;
  * per-step wall-time EWMA with z-score outlier detection flags straggler
    steps; the policy hook can rebalance or exclude hosts (here: recorded
    and surfaced in metrics — the decision layer on real clusters lives in
    the cluster scheduler).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.runtime.step import StepBundle, build_train_step


@dataclass
class StragglerDetector:
    """EWMA + z-score step-time anomaly detector."""
    alpha: float = 0.1
    z_thresh: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 5:
            sd = max(self.var ** 0.5, 1e-9)
            z = (dt - self.mean) / sd
            is_straggler = z > self.z_thresh
        else:
            is_straggler = False
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


class Trainer:
    def __init__(self, rc: RunConfig, mesh, *, ckpt_dir: Optional[str] = None,
                 replica_dir: Optional[str] = None, ckpt_every: int = 50,
                 keep: int = 3, fault_hook: Optional[Callable[[int], None]] = None):
        self.rc = rc
        self.mesh = mesh
        self.bundle: StepBundle = build_train_step(rc, mesh)
        self.ckpt_every = ckpt_every
        self.fault_hook = fault_hook
        self.detector = StragglerDetector()
        self.manager = (CheckpointManager(ckpt_dir, keep=keep,
                                          replica_dir=replica_dir)
                        if ckpt_dir else None)
        self.state = None
        self.step = 0
        self.history: list[dict] = []

    # -- state management ----------------------------------------------------
    def _shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.bundle.state_specs,
                            is_leaf=lambda x: isinstance(x, P))

    def init_or_restore(self, seed: int = 0):
        if self.manager and self.manager.latest_step() is not None:
            like = self.bundle.abstract_state()
            self.state, manifest = self.manager.restore(
                like, shardings=self._shardings())
            self.step = manifest["step"]
            return "restored"
        state = self.bundle.init_state(seed)
        self.state = jax.device_put(state, self._shardings())
        return "initialized"

    def _place_batch(self, batch_np) -> Any:
        sh = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                          self.bundle.batch_specs,
                          is_leaf=lambda x: isinstance(x, P))
        if not isinstance(batch_np, dict):
            batch_np = {"tokens": batch_np}
        return jax.device_put(batch_np, sh)

    # -- the loop -------------------------------------------------------------
    def run(self, data_iter, num_steps: int, *, log_every: int = 10,
            log: Callable[[str], None] = print) -> list[dict]:
        assert self.state is not None, "call init_or_restore() first"
        target = self.step + num_steps
        while self.step < target:
            batch = self._place_batch(next(data_iter))
            t0 = time.perf_counter()
            try:
                if self.fault_hook:
                    self.fault_hook(self.step)
                self.state, metrics = self.bundle.fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            except _RECOVERABLE as e:  # noqa: PERF203
                log(f"[fault] step {self.step}: {type(e).__name__}: {e}; "
                    f"restoring latest checkpoint")
                self._recover()
                continue
            dt = time.perf_counter() - t0
            straggler = self.detector.observe(self.step, dt)
            rec = {"step": self.step,
                   "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]),
                   "time_s": dt,
                   "straggler": straggler}
            self.history.append(rec)
            if log_every and self.step % log_every == 0:
                log(f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                    f"gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f}ms"
                    + (" [straggler]" if straggler else ""))
            self.step += 1
            if self.manager and self.step % self.ckpt_every == 0:
                self.manager.save(self.step, self.state, block=False)
        if self.manager:
            self.manager.save(self.step, self.state, block=True)
        return self.history

    def _recover(self):
        if not self.manager or self.manager.latest_step() is None:
            raise RuntimeError("fault with no checkpoint to restore from")
        like = self.bundle.abstract_state()
        self.state, manifest = self.manager.restore(
            like, shardings=self._shardings())
        self.step = manifest["step"]

    def close(self):
        if self.manager:
            self.manager.close()


class InjectedFault(RuntimeError):
    """Raised by test fault hooks to simulate node failure."""


_RECOVERABLE = (InjectedFault,)


def elastic_restart(rc: RunConfig, old_trainer: Trainer, new_mesh,
                    **kw) -> Trainer:
    """Restart training on a different mesh (node loss / scale-down):
    a new Trainer restores the old checkpoints with new shardings."""
    old_trainer.close()
    t = Trainer(rc, new_mesh, ckpt_dir=old_trainer.manager.dir if old_trainer.manager else None,
                **kw)
    t.init_or_restore()
    return t
