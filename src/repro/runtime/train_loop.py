"""Trainer: the production loop — checkpoint/restart, straggler detection,
fault injection for tests, elastic restart on a resized mesh.

Fault-tolerance model (1000+-node design, §DESIGN.md):
  * async chunked checkpoints every `ckpt_every` steps (mpw-cp store) with
    DataGather replication to a peer location;
  * on step failure (device error, injected fault) the loop restores the
    latest checkpoint and continues — the restore path is identical to a
    cold elastic restart on a different mesh because the store reshards;
  * per-step wall-time EWMA with z-score outlier detection flags straggler
    steps; the policy hook can rebalance or exclude hosts (here: recorded
    and surfaced in metrics — the decision layer on real clusters lives in
    the cluster scheduler).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.core.autotune import OnlineTuner, hop_shares
from repro.core.localsgd import LocalSGDController
from repro.core.retry import RetryPolicy, RetryState
from repro.core.telemetry import get_telemetry
from repro.runtime.step import (StepBundle, build_catchup, build_delta_sync,
                                build_train_step)


@dataclass
class StragglerDetector:
    """EWMA + z-score step-time anomaly detector."""
    alpha: float = 0.1
    z_thresh: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 5:
            sd = max(self.var ** 0.5, 1e-9)
            z = (dt - self.mean) / sd
            is_straggler = z > self.z_thresh
        else:
            is_straggler = False
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


class Trainer:
    def __init__(self, rc: RunConfig, mesh, *, ckpt_dir: Optional[str] = None,
                 replica_dir: Optional[str] = None, ckpt_every: int = 50,
                 keep: int = 3, fault_hook: Optional[Callable[[int], None]] = None,
                 autotune_every: int = 0, route=None, site_groups=None,
                 chaos=None, membership=None,
                 retry: Optional[RetryPolicy] = None):
        self.rc = rc
        self.mesh = mesh
        # multi-site wiring: `route` makes the cross-pod path a multi-hop
        # Forwarder chain (per-hop knobs + telemetry); `site_groups` makes
        # the cross-pod psum reduce intra-site before the slow hop
        self.route = route
        self.site_groups = site_groups
        # self-healing: a repro.core.chaos.ChaosMonitor gets one host-side
        # hook per executed step (between steps — never mid-step), from
        # which it watches the route's links and drives re-route/failover
        self.chaos = chaos
        # elastic membership: a repro.core.membership.SiteMembership whose
        # epoch this loop watches; a bump re-forms the local-SGD subgroup,
        # re-tunes, and resyncs the surviving world (see
        # _reconcile_membership).  An attached ChaosMonitor drives its
        # liveness probes (and escalates detected faults to suspicion);
        # without one the loop ticks the probes itself.
        self.membership = membership
        if (chaos is not None and membership is not None
                and getattr(chaos, "membership", None) is None):
            chaos.membership = membership
        # fault-recovery budget: bounded checkpoint-restore attempts per
        # incident streak (a successful step resets the schedule)
        self.retry = retry or RetryPolicy(max_attempts=8)
        # local-SGD cadence (CommConfig.local_steps): K > 1 builds the
        # site-local step and ships a model delta every K-th step; K = 1
        # *is* the synchronous path (bit-identical by construction)
        self.localsgd = LocalSGDController(rc.comm.local_steps)
        self.bundle: StepBundle = build_train_step(
            rc, mesh, route=route, site_groups=site_groups,
            local_only=self.localsgd.enabled)
        self._dsync = None           # jitted delta sync for this epoch
        self._dsync_built = False
        self._anchor = None          # params snapshot at the last delta sync
        self._epoch_seen = membership.epoch if membership is not None else 0
        self._members_seen = (set(membership.members())
                              if membership is not None else set())
        self.ckpt_every = ckpt_every
        self.fault_hook = fault_hook
        self.detector = StragglerDetector()
        self.manager = (CheckpointManager(
            ckpt_dir, keep=keep, replica_dir=replica_dir,
            transfer=self._ckpt_transfer(replica_dir))
            if ckpt_dir else None)
        self.state = None
        self.step = 0
        self.history: list[dict] = []
        # online autotuning: every `autotune_every` steps the controller
        # digests measured step times and may re-tune the WidePath (the step
        # is rebuilt; compiled executables are cached per knob setting, so
        # revisiting a config is free — the paper's "cache is the compiled
        # executable" idiom applied to tuning).
        self.tuner: Optional[OnlineTuner] = None
        self._bundles: dict[tuple, StepBundle] = {}
        # True whenever the *next* executed step pays XLA compilation — the
        # initial build included; such samples are excluded from the
        # straggler EWMA and telemetry
        self._fresh_compile = True
        if autotune_every and rc.comm.autotune and rc.comm.mode != "flat":
            p = self.bundle.path
            # probe bucket_mb only when this config can actually bucket
            # (hierarchical + ZeRO + stacked blocks — bundle built a plan
            # or would on a nonzero knob); otherwise every bucket probe
            # would pay a full XLA recompile for a bit-identical executable
            can_bucket = (self.bundle.bucket_plan is not None
                          or (p.comm.bucket_mb == 0.0 and self.bundle.zero
                              and p.comm.mode == "hierarchical"))
            self.tuner = OnlineTuner(streams=p.streams,
                                     chunk_mb=p.comm.chunk_mb,
                                     pacing=p.comm.pacing,
                                     algo=p.comm.algo,
                                     bucket_mb=p.comm.bucket_mb,
                                     tune_bucket=can_bucket,
                                     window=autotune_every)
            cfg0 = self.tuner.config()
            if (cfg0["streams"] == p.streams
                    and cfg0["chunk_mb"] == p.comm.chunk_mb
                    and cfg0["pacing"] == p.comm.pacing
                    and cfg0["algo"] == p.comm.algo
                    and cfg0.get("bucket_mb", p.comm.bucket_mb) == p.comm.bucket_mb):
                self._bundles[self._cfg_key(cfg0)] = self.bundle

    def _ckpt_transfer(self, replica_dir):
        """Checkpoint shipping engine: when this trainer spans sites (a
        topology `route` was given), replicas travel the same multi-hop WAN
        route the gradients do — mpw-cp chunked/compressed transfers with
        per-hop telemetry under the ``ckpt:*`` keys — instead of a local
        copy.  Single-site trainers keep the local mirror fallback (None)."""
        if not replica_dir or self.route is None:
            return None
        from repro.core.filetransfer import FileTransfer
        from repro.core.path import WidePath
        path = WidePath(axis="pod", comm=self.rc.comm, name="ckpt")
        # digest=False: the mirror loop discards FileResults, so the
        # finalize sha256 would be a second full read of every shard for
        # nothing (per-chunk CRCs already verify the bytes end to end)
        return FileTransfer(path.with_hops(
            self.route.as_hops(base_comm=self.rc.comm)), digest=False)

    # -- state management ----------------------------------------------------
    def _shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.bundle.state_specs,
                            is_leaf=lambda x: isinstance(x, P))

    def init_or_restore(self, seed: int = 0):
        if self.manager and self.manager.has_checkpoint():
            like = self.bundle.abstract_state()
            self.state, manifest = self.manager.restore(
                like, shardings=self._shardings())
            self.step = manifest["step"]
            return "restored"
        state = self.bundle.init_state(seed)
        self.state = jax.device_put(state, self._shardings())
        return "initialized"

    def _place_batch(self, batch_np) -> Any:
        sh = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                          self.bundle.batch_specs,
                          is_leaf=lambda x: isinstance(x, P))
        if not isinstance(batch_np, dict):
            batch_np = {"tokens": batch_np}
        return jax.device_put(batch_np, sh)

    # -- the loop -------------------------------------------------------------
    def run(self, data_iter, num_steps: int, *, log_every: int = 10,
            log: Callable[[str], None] = print) -> list[dict]:
        if self.state is None:
            raise RuntimeError("Trainer.state is unset — call "
                               "init_or_restore() before run()")
        target = self.step + num_steps
        # bounded recovery: restores are paced by the RetryPolicy schedule
        # (modeled backoff; a successful step resets the incident streak)
        retry = RetryState(self.retry)
        if self.localsgd.enabled and self._anchor is None:
            # the first K local steps diverge from *this* snapshot
            self._anchor = jax.tree.map(lambda x: x.copy(),
                                        self.state["params"])
        while self.step < target:
            batch = self._place_batch(next(data_iter))
            t0 = time.perf_counter()
            try:
                if self.fault_hook:
                    self.fault_hook(self.step)
                self.state, metrics = self.bundle.fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            except _RECOVERABLE as e:  # noqa: PERF203
                delay = retry.next_delay_s()
                if delay is None:
                    log(f"[fault] step {self.step}: {type(e).__name__}: {e}; "
                        f"recovery budget exhausted "
                        f"({self.retry.max_attempts} attempts)")
                    raise
                log(f"[fault] step {self.step}: {type(e).__name__}: {e}; "
                    f"restoring latest checkpoint "
                    f"(backoff {delay*1e3:.0f}ms modeled)")
                self._recover()
                continue
            retry.reset()
            dt = time.perf_counter() - t0
            if self._fresh_compile:
                # first step on a newly built executable: dt is dominated by
                # XLA compilation.  The tuner already discards it (warmup);
                # keep it out of the straggler EWMA and telemetry too, or it
                # both fires a bogus flag and inflates the variance enough
                # to mask real stragglers afterwards.
                self._fresh_compile = False
                straggler = False
            else:
                straggler = self.detector.observe(self.step, dt)
                if self.rc.comm.mode != "flat":   # flat: path carries nothing
                    get_telemetry().record(self.bundle.path.key, dt,
                                           step=self.step)
                    self._record_hop_samples(dt)
            if self.tuner is not None:
                new_cfg = self.tuner.observe(dt)
                if new_cfg is not None:
                    self._retune(new_cfg, log)
            if self.chaos is not None:
                # between steps (the step above is fully retired), so a
                # route swap or failover here is mid-step-safe by
                # construction: the next step launches on the new bundle
                self.chaos.on_step(self, log=log)
            elif self.membership is not None:
                # no monitor attached: the loop ticks the liveness probes
                self.membership.on_step(self.step)
            if self.membership is not None:
                self._reconcile_membership(log)
            if self.localsgd.enabled and self.localsgd.is_sync_step(self.step):
                self._delta_sync(log)
            rec = {"step": self.step,
                   "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]),
                   "time_s": dt,
                   "straggler": straggler}
            self.history.append(rec)
            if log_every and self.step % log_every == 0:
                log(f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                    f"gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f}ms"
                    + (" [straggler]" if straggler else ""))
            self.step += 1
            if self.manager and self.step % self.ckpt_every == 0:
                self.manager.save(self.step, self.state, block=False)
        if self.manager:
            self.manager.save(self.step, self.state, block=True)
            # ship the final checkpoint to the replica site now, not at the
            # background gatherer's next tick (the run may be over by then)
            self.manager.replicate_now()
        return self.history

    def _record_hop_samples(self, dt: float) -> None:
        """Per-hop telemetry for a multi-hop train path: split the step's
        wall time across hops by `autotune.hop_shares` (the same modeled
        split RouteTuner feeds its controllers with).  The per-hop GB/s in
        MPW.Report() then reflects which leg dominates."""
        path = self.bundle.path
        if not path.hops:
            return
        tel = get_telemetry()
        plan = tel.path(path.key).plan
        shares = hop_shares(path.route, plan.payload_bytes if plan else 0)
        for i in range(path.n_hops):
            tel.record(path.hop_key(i), dt * shares[i], step=self.step)

    # -- local-SGD / elastic membership --------------------------------------
    def _member_groups(self) -> Optional[list]:
        """Pod groups of the current epoch's live sites (all sites when no
        membership is attached)."""
        if self.site_groups is None:
            return None
        if self.membership is not None:
            return [list(g) for g in self.membership.member_pod_groups()]
        return [list(g) for g in self.site_groups]

    def _delta_sync(self, log: Callable[[str], None] = print,
                    full: bool = False) -> None:
        """Run one cross-site reconciliation (every K-th step).

        `full=True` averages the raw params (delta against a zero anchor)
        — the world-resize resync, which also re-establishes the invariant
        the incremental sync needs: every member pod holds the same anchor.
        """
        if not self._dsync_built:
            self._dsync_built = True
            groups = self._member_groups()
            if groups is not None and len(groups) >= 2:
                member_pods = [p for g in groups for p in g]
                self._dsync = build_delta_sync(
                    self.rc, self.mesh, self.bundle,
                    site_groups=self.site_groups,
                    member_pods=member_pods,
                    member_gateways=[g[0] for g in groups])
                if self._dsync is not None:
                    self._fresh_compile = True
        if self._dsync is None:
            return
        params = self.state["params"]
        anchor = (jax.tree.map(lambda x: (x * 0).astype(x.dtype), params)
                  if full else self._anchor)
        if anchor is None:
            return
        new_p = self._dsync(params, anchor)
        self.state["params"] = new_p
        self._anchor = jax.tree.map(lambda x: x.copy(), new_p)

    def _reconcile_membership(self, log: Callable[[str], None] = print) -> None:
        """React to a membership epoch bump: re-form the delta-sync
        subgroup, catch rejoined sites up from a survivor, re-tune for the
        resized world, and resync the members (evict → resize → retune →
        recover in the incident timeline)."""
        mem = self.membership
        if mem is None or mem.epoch == self._epoch_seen:
            return
        prev, self._epoch_seen = self._epoch_seen, mem.epoch
        members = mem.members()
        log(f"[elastic] step {self.step}: membership epoch {prev} -> "
            f"{mem.epoch}; members {members}")
        mem.log.add(self.step, "resize", ",".join(members),
                    {"epoch": mem.epoch, "from_epoch": prev,
                     "members": members})
        # rejoined sites first: clone a survivor gateway's params onto
        # their pods (the emulated form of the replica catch-up restore)
        joined = [s for s in members if s not in self._members_seen]
        survivors = [s for s in members if s in self._members_seen]
        if (joined and survivors and self.site_groups is not None
                and "pod" in self.mesh.axis_names):
            topo = mem.topo
            names = [s.name for s in topo.sites]
            pg = [list(g) for g in topo.pod_groups()]
            targets = [p for n, g in zip(names, pg) if n in joined for p in g]
            cu = build_catchup(self.mesh, self.bundle,
                               source_pod=topo.site(survivors[0]).gateway,
                               target_pods=targets)
            if cu is not None:
                self.state["params"] = cu(self.state["params"])
                self._fresh_compile = True
                mem.log.add(self.step, "catchup", ",".join(joined),
                            {"source": survivors[0], "pods": targets})
        self._members_seen = set(members)
        # the old subgroup's executable and cost landscape are gone
        self._dsync = None
        self._dsync_built = False
        if self.tuner is not None:
            self.tuner.abort_probe()
            self.tuner.converged = False
            self.tuner.best_cost = None
        mem.log.add(self.step, "retune", self.bundle.path.key,
                    {"epoch": mem.epoch})
        if self.localsgd.enabled:
            # full resync: every member pod leaves with identical params
            # *and* an identical anchor — without this, per-site anchors
            # would diverge and the incremental merge would never converge
            self._delta_sync(log, full=True)
        mem.log.add(self.step, "recover", ",".join(members),
                    {"epoch": mem.epoch})

    # -- online autotuning ----------------------------------------------------
    @staticmethod
    def _cfg_key(cfg: dict) -> tuple:
        return (cfg["streams"], cfg["chunk_mb"], cfg["pacing"],
                cfg.get("algo", "psum"), cfg.get("bucket_mb", 0.0))

    def _retune(self, cfg: dict, log: Callable[[str], None] = print) -> None:
        """Apply a controller-proposed path config: swap to the (cached or
        freshly built) step executable for those knobs.

        Only streams/chunk/pacing change, so state shardings are identical
        across bundles and the live state tensors carry over untouched.
        """
        comm = dataclasses.replace(self.rc.comm, autotune=False, **cfg)
        self.rc = dataclasses.replace(self.rc, comm=comm)
        key = self._cfg_key(cfg)
        if key not in self._bundles:
            self._bundles[key] = build_train_step(
                self.rc, self.mesh, route=self.route,
                site_groups=self.site_groups,
                local_only=self.localsgd.enabled)
            self._fresh_compile = True   # next step pays XLA compilation
        self.bundle = self._bundles[key]
        # the delta sync inherits the path knobs: rebuild on next sync step
        self._dsync = None
        self._dsync_built = False
        if self.bundle.replan is not None:
            # cache hit: building already noted the plan; a swap back to a
            # cached config must re-note it or PathStats would keep
            # describing the rejected (last-built) config
            self.bundle.replan()
        get_telemetry().path(self.bundle.path.key).note_retune(self.step, cfg)
        log(f"[autotune] step {self.step}: trying streams={cfg['streams']} "
            f"chunk={cfg['chunk_mb']}MiB pacing={cfg['pacing']}"
            + (f" algo={cfg['algo']}" if "algo" in cfg else "")
            + (f" bucket={cfg['bucket_mb']}MiB" if "bucket_mb" in cfg else ""))

    # -- self-healing (driven by repro.core.chaos.ChaosMonitor) --------------
    def apply_route(self, new_route, log: Callable[[str], None] = print) -> None:
        """Swap the training path onto a replanned route (a hop died and
        the topology found a detour).  Runs between steps: the live state
        tensors carry over untouched — only streams/chunk/algo wiring
        changes, so state shardings are identical across bundles — and the
        next step pays one XLA compile on the new route."""
        self.route = new_route
        self._bundles.clear()        # keyed by knobs, not route: invalidate
        self.bundle = build_train_step(self.rc, self.mesh, route=new_route,
                                       site_groups=self.site_groups,
                                       local_only=self.localsgd.enabled)
        self._fresh_compile = True
        self._dsync = None
        self._dsync_built = False
        if self.tuner is not None:
            # the old route's cost landscape is gone: revert any in-flight
            # probe and restart the climb from the incumbent on fresh moves
            self.tuner.abort_probe()
            self.tuner.converged = False
            self.tuner.best_cost = None
        log(f"[chaos] step {self.step}: route replanned -> "
            + " -> ".join(str(s) for s in getattr(new_route, 'sites', ())))

    def failover_to_replica(self, log: Callable[[str], None] = print) -> str:
        """Whole-site loss: the remote site is unreachable on *any* route.
        Drop the cross-site path (train on with the surviving site's pods)
        and restore from the newest restorable checkpoint — the replica
        mirror when the primary directory died with the site.  Runs
        between steps, so the swap is mid-step-safe."""
        self.route = None
        self._bundles.clear()
        self.bundle = build_train_step(self.rc, self.mesh, route=None,
                                       site_groups=self.site_groups,
                                       local_only=self.localsgd.enabled)
        self._fresh_compile = True
        self._dsync = None
        self._dsync_built = False
        outcome = "degraded"
        if self.manager and self.manager.has_checkpoint():
            self._recover()
            outcome = "restored"
        log(f"[chaos] step {self.step}: site lost; failover ({outcome})")
        return outcome

    def _recover(self):
        # has_checkpoint, not latest_step: mid-run recovery may also restore
        # from the replica mirror when the primary directory is gone
        if not self.manager or not self.manager.has_checkpoint():
            raise RuntimeError("fault with no checkpoint to restore from")
        like = self.bundle.abstract_state()
        self.state, manifest = self.manager.restore(
            like, shardings=self._shardings())
        self.step = manifest["step"]

    def close(self):
        if self.manager:
            self.manager.close()


class InjectedFault(RuntimeError):
    """Raised by test fault hooks to simulate node failure."""


_RECOVERABLE = (InjectedFault,)


def elastic_restart(rc: RunConfig, old_trainer: Trainer, new_mesh,
                    **kw) -> Trainer:
    """Restart training on a different mesh (node loss / scale-down):
    a new Trainer restores the old checkpoints with new shardings."""
    old_trainer.close()
    t = Trainer(rc, new_mesh, ckpt_dir=old_trainer.manager.dir if old_trainer.manager else None,
                **kw)
    t.init_or_restore()
    return t
